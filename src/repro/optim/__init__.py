from .adamw import (AdamWConfig, adamw_init, adamw_update, opt_state_specs,
                    cosine_lr)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "cosine_lr"]
