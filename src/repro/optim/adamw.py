"""AdamW with global-norm clipping, cosine schedule, configurable state
dtype and ZeRO-1 state sharding.

State dtype: fp32 by default; ``bf16`` halves optimizer HBM for pod-scale
models (used by grok-1-314b to fit 16 GB/chip, recorded in EXPERIMENTS.md).

ZeRO-1: optimizer moments get an *additional* data-axis sharding on their
largest unsharded dim (opt_state_specs), so m/v live partitioned across the
data-parallel group while params keep their compute-friendly layout — XLA
GSPMD inserts the reduce-scatter/all-gather pair implied by the layout
difference, which is exactly the ZeRO-1 communication schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.sharding import (ShardingPolicy, _divides, for_mesh,
                                    param_specs)
from ..models.config import ModelConfig

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" to halve optimizer HBM
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(c: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    return c.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params: Params, c: AdamWConfig) -> dict:
    dt = jnp.dtype(c.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Params, state: dict, params: Params,
                 c: AdamWConfig) -> tuple[Params, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(c, step)

    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(gf)) + 1e-30)
    scale = jnp.minimum(1.0, c.clip_norm / gnorm)
    gf = jax.tree.map(lambda g: g * scale, gf)

    bc1 = 1.0 - c.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - c.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(c.state_dtype)

    def upd(p, g, m, v):
        mf = m.astype(jnp.float32) * c.b1 + g * (1 - c.b1)
        vf = v.astype(jnp.float32) * c.b2 + jnp.square(g) * (1 - c.b2)
        mh = mf / bc1
        vh = vf / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    out = jax.tree.map(upd, params, gf, state["m"], state["v"])
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def opt_state_specs(cfg: ModelConfig, mesh: Mesh,
                    pol: Optional[ShardingPolicy] = None,
                    zero1: bool = True) -> dict:
    """Sharding specs for adamw state; ZeRO-1 adds dp sharding to moments."""
    pol = pol or for_mesh(mesh)
    pspecs = param_specs(cfg, mesh, pol)
    abstract = None
    if zero1:
        from ..models import lm
        abstract = lm.abstract_params(cfg)

    def zero_one(spec: P, leaf) -> P:
        if not zero1 or leaf.ndim == 0:
            return spec
        ent = list(spec) + [None] * (leaf.ndim - len(spec))
        dp = pol.dp_spec
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in order:
            if ent[i] is None and _divides(leaf.shape[i], mesh, dp) and \
                    leaf.shape[i] >= 1024:
                ent[i] = dp
                break
        return P(*ent)

    mspec = jax.tree.map(zero_one, pspecs, abstract) if zero1 else pspecs
    return {"m": mspec, "v": mspec, "step": P()}
