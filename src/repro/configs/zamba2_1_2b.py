"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000 ssm_state=64.  One *shared-weight* attention+MLP block is
applied every 6 mamba layers — the paper's one-definition/many-instances
pattern with literally shared weights.  (Zamba2's per-use LoRA adapters on
the shared block are omitted; noted in DESIGN.md.)
"""
from ..models.config import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64, max_seq_len=4_096,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
    hybrid=HybridConfig(attn_period=6),
)
