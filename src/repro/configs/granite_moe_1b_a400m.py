"""granite-moe-1b-a400m [moe] — 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
24L d_model=1024 16H (GQA kv=8) d_ff=512(per-expert) vocab=49155.
"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, tie_embeddings=True, max_seq_len=4_096,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
)
