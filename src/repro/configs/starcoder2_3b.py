"""starcoder2-3b [dense] — GQA, RoPE, sliding window 4096.

[arXiv:2402.19173; hf]  30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, rope_theta=999_999.4,
    sliding_window=4096, max_seq_len=16_384,
)
