"""Assigned-architecture registry: ``get_config("<arch-id>")``.

One module per architecture with the exact published dimensions
(``[source; verified-tier]`` noted per file).
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig, SHAPES, InputShape, shape_applicable

ARCH_IDS = [
    "phi_3_vision_4_2b",
    "starcoder2_3b",
    "qwen3_0_6b",
    "qwen3_4b",
    "yi_6b",
    "whisper_small",
    "zamba2_1_2b",
    "mamba2_130m",
    "granite_moe_1b_a400m",
    "grok_1_314b",
]

_ALIAS = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen3-4b": "qwen3_4b",
    "yi-6b": "yi_6b",
    "whisper-small": "whisper_small",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-130m": "mamba2_130m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "grok-1-314b": "grok_1_314b",
}


def canonical(arch: str) -> str:
    return _ALIAS.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "get_config", "all_configs", "canonical",
           "ModelConfig", "SHAPES", "InputShape", "shape_applicable"]
