"""whisper-small [audio] — enc-dec, conv frontend (stub).

[arXiv:2212.04356; unverified]  12L d_model=768 12H d_ff=3072 vocab=51865.
Decoder positions use RoPE in this adaptation (whisper uses learned
positions; noted in DESIGN.md — the backbone dims are what the assignment
fixes).  The conv frontend is a stub: input_specs() provides precomputed
frame embeddings [B, 1500, 768].
"""
from ..models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, max_seq_len=32_768,
    encdec=EncDecConfig(n_encoder_layers=12, n_audio_ctx=1500),
)
