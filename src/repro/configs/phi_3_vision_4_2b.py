"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (GQA kv=32 == MHA) d_ff=8192 vocab=32064.
The CLIP frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings projected into the backbone.
"""
from ..models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, rope_theta=10_000.0,
    max_seq_len=131_072,
    vlm=VLMConfig(n_patches=576, d_patch=1024),
)
