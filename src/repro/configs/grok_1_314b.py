"""grok-1-314b [moe] — 8 experts top-2; the multi-pod-scale arch.

[hf:xai-org/grok-1; unverified]
64L d_model=6144 48H (GQA kv=8) d_ff=32768(per-expert) vocab=131072.
"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128, max_seq_len=8_192,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
)
