"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  24L d_model=768 d_ff=0 vocab=50280
ssm_state=128.
"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, max_seq_len=1_048_576, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
)
