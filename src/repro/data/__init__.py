from .pipeline import DataConfig, TokenPipeline, make_pipeline

__all__ = ["DataConfig", "TokenPipeline", "make_pipeline"]
