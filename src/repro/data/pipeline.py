"""Token data pipeline: deterministic, host-sharded, checkpointable.

Two sources behind one interface:

* ``synthetic`` — a seeded Zipf-ish token stream (the default for examples,
  benchmarks and the train driver; no external data gate).
* ``memmap`` — a flat binary token file (np.memmap), the production path:
  each host reads only its shard's strided window.

The pipeline is a *task* in the TAPA sense: ``as_task`` returns a producer
function that streams batches into a channel with a bounded capacity, which
is exactly the paper's prefetch-queue pattern; the train driver consumes it
through the same IStream interface the simulator verifies.

State is one integer (``step``); checkpointing the pipeline is saving that
integer — restart resumes the exact batch sequence (required for
fault-tolerant training).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"            # synthetic | memmap
    path: Optional[str] = None           # memmap token file (uint16/uint32)
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Deterministic batch iterator with O(1) restart state."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self._host_batch = cfg.global_batch // cfg.n_hosts
        if cfg.source == "memmap":
            if not cfg.path:
                raise ValueError("memmap source needs cfg.path")
            dtype = np.uint32 if cfg.vocab > 65535 else np.uint16
            self._tokens = np.memmap(cfg.path, dtype=dtype, mode="r")
            if len(self._tokens) < cfg.seq_len + 1:
                raise ValueError("token file shorter than one sequence")
        elif cfg.source != "synthetic":
            raise ValueError(f"unknown source {cfg.source!r}")

    # -- state --------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    # -- batches ------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: batch content is a pure function of (seed, step,
        # host) — restart-safe, order-independent across hosts
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.cfg.host_id)

    def _synthetic(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        B, S, V = self._host_batch, self.cfg.seq_len, self.cfg.vocab
        # Zipf-ish marginal over the vocab so losses have realistic scale
        u = rng.random((B, S + 1))
        toks = np.minimum((u ** 2.2 * V).astype(np.int64), V - 1)
        return toks.astype(np.int32)

    def _memmap(self, step: int) -> np.ndarray:
        rng = self._rng(step)
        B, S = self._host_batch, self.cfg.seq_len
        hi = len(self._tokens) - (S + 1)
        starts = rng.integers(0, hi + 1, size=B)
        return np.stack([np.asarray(self._tokens[s:s + S + 1])
                         for s in starts]).astype(np.int32)

    def next_batch(self) -> dict:
        toks = (self._synthetic if self.cfg.source == "synthetic"
                else self._memmap)(self.step)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    # -- TAPA producer ------------------------------------------------------
    def as_task(self, n_batches: int, burst: int = 0):
        """A producer task streaming ``n_batches`` into a channel then
        closing the transaction (prefetch-queue pattern).

        ``burst`` > 0 prefetches that many batches at a time and moves
        them with one ``write_burst`` per group (capped at the channel
        capacity by default so prefetch memory stays bounded)."""
        def DataProducer(out):
            group = burst or out.channel.capacity
            done = 0
            while done < n_batches:
                k = min(group, n_batches - done)
                out.write_burst([self.next_batch() for _ in range(k)])
                done += k
            out.close()
        return DataProducer


def make_pipeline(vocab: int, seq_len: int, global_batch: int,
                  **kw) -> TokenPipeline:
    return TokenPipeline(DataConfig(vocab=vocab, seq_len=seq_len,
                                    global_batch=global_batch, **kw))


def write_token_file(path: str | Path, tokens: np.ndarray,
                     vocab: int) -> None:
    """Helper used by tests/examples to create a memmap corpus."""
    dtype = np.uint32 if vocab > 65535 else np.uint16
    np.asarray(tokens, dtype=dtype).tofile(str(path))
