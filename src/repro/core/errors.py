"""Exception types for the TAPA-JAX core runtime."""

from dataclasses import dataclass, field
from typing import Optional


class ReproError(Exception):
    """Base class for all repro errors."""


class Deadlock(ReproError):
    """No task can make progress, yet non-detached tasks remain unfinished.

    Raised by ThreadEngine/CoroutineEngine when every live task is blocked on
    a channel operation that can never be satisfied.
    """


@dataclass
class DeadlockReport:
    """Structured no-progress diagnostic, uniform across every engine.

    The CompiledEngine always reported its stalls this way (blocked tasks +
    channel occupancies); this extracts that shape so sequential/thread/
    coroutine deadlocks, watchdog trips and compiled stalls all carry the
    same payload (``SimReport.deadlock``).  ``reason`` is one of:

    * ``"deadlock"`` — every live task is blocked on an unsatisfiable op;
    * ``"sequential-read"`` — the sequential engine's documented failure
      (blocking read with no runnable producer);
    * ``"stall"`` — a lowered graph stopped firing before completion;
    * ``"watchdog"`` — the wall-clock watchdog expired (livelock / hang);
    * ``"tick-budget"`` — the logical-clock budget (``max_ticks``) expired.
    """

    engine: str
    reason: str
    blocked: list = field(default_factory=list)    # [(task, wait site)]
    occupancy: dict = field(default_factory=dict)  # channel name -> tokens
    clock: int = 0
    switches: int = 0
    wall_s: float = 0.0

    def format(self) -> str:
        b = "; ".join(f"{t} ({s})" for t, s in self.blocked) or "-"
        occ = {k: v for k, v in self.occupancy.items() if v}
        return (f"deadlock[{self.reason}] under {self.engine} engine: "
                f"blocked tasks: {b}; channel occupancy: {occ}; "
                f"clock={self.clock} switches={self.switches}")


class DeadlockError(Deadlock):
    """A :class:`Deadlock` carrying its :class:`DeadlockReport`."""

    def __init__(self, report: DeadlockReport):
        super().__init__(report.format())
        self.report = report


class InjectedFault(ReproError):
    """A failure injected by the chaos harness (``repro.core.faults``).

    Raised from a task body at the firing chosen by the fault plan; engines
    surface it like any other task failure (``task error: ...``), which is
    exactly the point — injected faults exercise the real error paths.
    """


class TransientFault(ReproError):
    """An injected *retryable* failure (serving step, artifact IO)."""


class CrashFault(ReproError):
    """An injected process-crash analogue (``FaultPlan.crash``).

    Unlike :class:`InjectedFault` — which models a task *dying* and is
    surfaced as a structured task failure — a ``CrashFault`` models the
    whole simulation process disappearing mid-run.  It is the fault kind
    the recovery subsystem (:mod:`repro.ft.recovery`) exists for: a
    supervisor catches it, restores the latest :class:`GraphSnapshot`
    and re-runs from the snapshot instead of from scratch.
    """


class PoisonError(ReproError):
    """A serving request whose compute step is poisoned by the fault plan.

    The scheduler quarantines the named request (retired with an error
    status) instead of dying; carries ``rid`` so batched steps can identify
    the victim inside a group call.
    """

    def __init__(self, rid: int, msg: Optional[str] = None):
        super().__init__(msg or f"poisoned request {rid}")
        self.rid = rid


class SequentialSimulationError(Deadlock):
    """The sequential engine cannot simulate this program.

    Reproduces the paper's finding (Section 3.2 / Fig. 7) that sequential
    simulators fail on programs with feedback loops in the data paths
    (e.g. Cannon's algorithm, PageRank).
    """


class ChannelMisuse(ReproError):
    """A channel is wired to something other than exactly one producer and
    one consumer instantiated in the same parent task (Section 3.1.1)."""


class GraphValidationError(ReproError):
    """Task-graph metadata failed validation."""


class SynthesisError(ReproError):
    """A task graph cannot be lowered to a single compiled program.

    Raised by :mod:`repro.core.synth` when the graph is outside the
    synthesizable subset — a task not in step-function form, a channel with
    no declared element spec, a data-dependent I/O rate, an async_mmap
    port, a read-and-written mmap, or a phase whose I/O can never fit the
    channel capacity.  The message names the offending task/channel: the
    contract is *refuse with a diagnostic, never miscompile*.
    """


class TaskKilled(BaseException):
    """Internal control-flow signal used to tear down detached tasks once all
    non-detached tasks have finished.  Derives from BaseException so that
    user-level ``except Exception`` blocks inside tasks do not swallow it.
    """


class EndOfTransaction(ReproError):
    """A blocking data read/peek encountered an EoT token.

    Matches TAPA semantics: an EoT token carries no data, so ``read()`` of a
    closed transaction is a programming error that must be surfaced, not
    silently returned.  Use ``eot()`` / ``try_read()`` to test first.
    """
