"""Exception types for the TAPA-JAX core runtime."""


class ReproError(Exception):
    """Base class for all repro errors."""


class Deadlock(ReproError):
    """No task can make progress, yet non-detached tasks remain unfinished.

    Raised by ThreadEngine/CoroutineEngine when every live task is blocked on
    a channel operation that can never be satisfied.
    """


class SequentialSimulationError(Deadlock):
    """The sequential engine cannot simulate this program.

    Reproduces the paper's finding (Section 3.2 / Fig. 7) that sequential
    simulators fail on programs with feedback loops in the data paths
    (e.g. Cannon's algorithm, PageRank).
    """


class ChannelMisuse(ReproError):
    """A channel is wired to something other than exactly one producer and
    one consumer instantiated in the same parent task (Section 3.1.1)."""


class GraphValidationError(ReproError):
    """Task-graph metadata failed validation."""


class SynthesisError(ReproError):
    """A task graph cannot be lowered to a single compiled program.

    Raised by :mod:`repro.core.synth` when the graph is outside the
    synthesizable subset — a task not in step-function form, a channel with
    no declared element spec, a data-dependent I/O rate, an async_mmap
    port, a read-and-written mmap, or a phase whose I/O can never fit the
    channel capacity.  The message names the offending task/channel: the
    contract is *refuse with a diagnostic, never miscompile*.
    """


class TaskKilled(BaseException):
    """Internal control-flow signal used to tear down detached tasks once all
    non-detached tasks have finished.  Derives from BaseException so that
    user-level ``except Exception`` blocks inside tasks do not swallow it.
    """


class EndOfTransaction(ReproError):
    """A blocking data read/peek encountered an EoT token.

    Matches TAPA semantics: an EoT token carries no data, so ``read()`` of a
    closed transaction is a programming error that must be surfaced, not
    silently returned.  Use ``eot()`` / ``try_read()`` to test first.
    """
