"""Mesh floorplanner: assign task instances to devices, price the cuts.

The analogue of TAPA/AutoBridge's floorplan pass (PAPERS.md): instead of
assigning tasks to FPGA die regions and pipelining the crossing FIFOs,
we assign :class:`~repro.core.synth.StepTask` instances to devices of a
1-D ``jax.sharding.Mesh`` and lower every *cut* channel (producer and
consumer on different devices) to a ``lax.ppermute`` exchange in the
partitioned sweep (see ``synth._build_partitioned_program``).

The placement is a real optimization, not a hash of the task name:

* per-task weights come from :mod:`repro.core.cost` — XLA's own
  ``cost_analysis`` of each firing body, converted to roofline seconds
  and multiplied by the firing budget (memoized per task definition, so
  an edit re-prices one cell);
* per-channel weights are the total bytes the channel moves over the
  whole run (statically known: every write is a full token of the
  channel's element spec, and phase tables say how many writes happen);
* the objective is ``max_device_load_seconds + cut_bytes / ici_bw`` —
  balance compute, penalize interconnect traffic — minimized by greedy
  placement in plan order followed by deterministic single-task-move
  refinement passes (first-improvement, lowest device index wins ties).

Placements are content-addressed artifacts: the JSON result is memoized
under ``Graph.structural_hash()`` + mesh size + manual overrides, so a
re-run or a sibling process pays zero re-partitioning (and, because the
owners vector feeds the compiled-program cache key, zero XLA
recompiles).  Manual placement: pass ``overrides={"task_name": device}``
— overridden tasks are pinned, the optimizer places the rest around
them, and the overrides are folded into the cache key so distinct
placements never collide.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from .compile_cache import _stable_repr, default_cache
from .cost import HW, task_cost
from .errors import SynthesisError
from .synth import _canon_dtype

FLOORPLAN_SCHEMA = "fp1"

# Ties between "one more second of max load" and "one more byte on the
# interconnect" are broken by the shared HW table, so both terms of the
# objective are in seconds.
_EPS = 1e-12


@dataclass(frozen=True)
class Placement:
    """A frozen task→device assignment plus the evidence for it."""
    n_devices: int
    owners: tuple                 # device index per plan.tasks entry
    task_names: tuple             # parallel to owners (display only)
    objective: dict               # max_load_s / loads_s / cut_bytes / ...
    source: str = "partitioned"   # "partitioned" | "memo"
    version: str = FLOORPLAN_SCHEMA

    def as_dict(self) -> dict:
        return {"version": self.version, "n_devices": self.n_devices,
                "owners": list(self.owners),
                "task_names": list(self.task_names),
                "objective": self.objective}


def placement_key(graph_hash: str, n_devices: int,
                  overrides: Optional[dict] = None) -> str:
    """Content address of a placement artifact: graph structure + mesh
    width + manual pins + schema. Same inputs ⇒ byte-identical artifact
    in any process."""
    h = hashlib.sha256()
    h.update(f"floorplan:{FLOORPLAN_SCHEMA}:{graph_hash}:"
             f"dev={int(n_devices)}:".encode())
    h.update(_stable_repr(tuple(sorted((overrides or {}).items()))).encode())
    return "place_" + h.hexdigest()


def channel_endpoints(plan) -> list:
    """``(producer_ti, consumer_ti)`` per channel (-1 when absent, e.g.
    the internal member rings of an async port, which only one task plus
    the port service touch)."""
    prod = [-1] * len(plan.channels)
    cons = [-1] * len(plan.channels)
    for ti, tp in enumerate(plan.tasks):
        for ph in tp.phases:
            for ci in ph.writes:
                prod[ci] = ti
            for ci in ph.reads:
                cons[ci] = ti
    return list(zip(prod, cons))


def channel_traffic(plan) -> list:
    """Total bytes each channel moves over the whole run.  Static: every
    push is one full token of the element spec, and the phase tables fix
    the number of pushes."""
    writes = [0] * len(plan.channels)
    for tp in plan.tasks:
        for ph in tp.phases:
            for ci, ntok in ph.writes.items():
                writes[ci] += ntok * ph.count
    out = []
    for ci, ch in enumerate(plan.channels):
        tok = int(np.prod(ch.shape, dtype=np.int64)) if ch.shape else 1
        out.append(writes[ci] * tok * _canon_dtype(ch.dtype).itemsize)
    return out


def _edges(plan) -> list:
    """Cuttable edges: ``(producer_ti, consumer_ti, bytes)`` for every
    channel with both endpoints bound to tasks."""
    traffic = channel_traffic(plan)
    return [(p, c, traffic[ci])
            for ci, (p, c) in enumerate(channel_endpoints(plan))
            if p >= 0 and c >= 0 and p != c]


def _objective(owners, costs, edges, n_devices, ici_bw):
    """Full objective over a (possibly partial) assignment; ``None``
    owners are simply not counted yet."""
    loads = [0.0] * n_devices
    for ti, c in enumerate(costs):
        if owners[ti] is not None:
            loads[owners[ti]] += c
    cut = 0
    for p, c, b in edges:
        if owners[p] is not None and owners[c] is not None \
                and owners[p] != owners[c]:
            cut += b
    return max(loads) + cut / ici_bw, loads, cut


def _validate_overrides(names, overrides, n_devices):
    known = set(names)
    unknown = sorted(set(overrides) - known)
    if unknown:
        raise SynthesisError(
            f"manual placement names unknown task(s) {unknown}; "
            f"known instances: {sorted(known)}")
    for name, dev in overrides.items():
        if not isinstance(dev, (int, np.integer)) \
                or not (0 <= int(dev) < n_devices):
            raise SynthesisError(
                f"manual placement pins task '{name}' to device {dev!r}, "
                f"outside the mesh's [0, {n_devices}) device range")


def plan_placement(plan, graph, n_devices: int, *,
                   overrides: Optional[dict] = None, cache: Any = None,
                   cost_fn: Optional[Callable] = None,
                   hw: Optional[dict] = None) -> Placement:
    """Place ``plan.tasks`` on ``n_devices`` devices.

    ``overrides`` pins named instances; ``cost_fn(plan, tp) -> seconds``
    swaps the pricing model (tests use synthetic costs to make the
    optimizer's choices assertable without touching XLA); ``cache=None``
    memoizes the artifact in the process compile cache, ``cache=False``
    disables memoization.
    """
    hw = hw or HW
    n_devices = int(n_devices)
    if n_devices < 1:
        raise SynthesisError(f"cannot floorplan onto {n_devices} devices")
    names = [tp.inst.name for tp in plan.tasks]
    overrides = dict(overrides or {})
    _validate_overrides(names, overrides, n_devices)

    cc = default_cache() if cache is None else (cache or None)
    key = placement_key(graph.structural_hash(), n_devices, overrides)
    if cc is not None:
        hit = cc.memo_get(key)
        if (hit is not None and hit.get("version") == FLOORPLAN_SCHEMA
                and hit.get("n_devices") == n_devices
                and len(hit.get("owners", ())) == len(names)):
            return Placement(n_devices=n_devices,
                             owners=tuple(int(d) for d in hit["owners"]),
                             task_names=tuple(hit["task_names"]),
                             objective=hit["objective"], source="memo")

    if cost_fn is None:
        def cost_fn(plan, tp):
            return task_cost(plan, tp, cache=cache, hw=hw)["seconds"]
    costs = [float(cost_fn(plan, tp)) for tp in plan.tasks]
    edges = _edges(plan)
    ici_bw = float(hw["ici_bw"])

    # greedy construction in plan order: pins first, then each free task
    # takes the device minimizing the partial objective (lowest index
    # wins ties, so the result is deterministic).
    owners: list = [overrides.get(name) for name in names]
    for ti in range(len(names)):
        if owners[ti] is not None:
            continue
        best_j, best_d = None, 0
        for d in range(n_devices):
            owners[ti] = d
            j, _, _ = _objective(owners, costs, edges, n_devices, ici_bw)
            if best_j is None or j < best_j - _EPS:
                best_j, best_d = j, d
        owners[ti] = best_d

    # refinement: deterministic single-task-move passes until a full
    # sweep finds no strict improvement.
    for _ in range(4):
        improved = False
        for ti in range(len(names)):
            if names[ti] in overrides:
                continue
            best_j, _, _ = _objective(owners, costs, edges, n_devices,
                                      ici_bw)
            best_d = owners[ti]
            for d in range(n_devices):
                if d == best_d:
                    continue
                owners[ti] = d
                j, _, _ = _objective(owners, costs, edges, n_devices,
                                     ici_bw)
                if j < best_j - _EPS:
                    best_j, best_d = j, d
                    improved = True
                owners[ti] = best_d
        if not improved:
            break

    owners = [int(d) for d in owners]
    j, loads, cut = _objective(owners, costs, edges, n_devices, ici_bw)
    ep = channel_endpoints(plan)
    cut_channels = sorted(
        plan.channels[ci].name
        for ci, (p, c) in enumerate(ep)
        if p >= 0 and c >= 0 and owners[p] != owners[c])
    objective = {"objective_s": j, "max_load_s": max(loads),
                 "loads_s": loads, "cut_bytes": int(cut),
                 "cut_channels": cut_channels,
                 "task_cost_s": costs}
    artifact = {"version": FLOORPLAN_SCHEMA, "n_devices": n_devices,
                "owners": owners, "task_names": names,
                "objective": objective,
                "overrides": {k: int(v) for k, v in overrides.items()}}
    # round-trip through JSON so the in-process return is byte-for-byte
    # what a sibling process will read back from the memo store
    artifact = json.loads(json.dumps(artifact))
    if cc is not None:
        cc.memo_put(key, artifact)
    return Placement(n_devices=n_devices, owners=tuple(artifact["owners"]),
                     task_names=tuple(artifact["task_names"]),
                     objective=artifact["objective"], source="partitioned")
