"""Hierarchical code generation (paper Section 3.3), adapted to XLA.

The paper's observation: HLS tools treat a task-parallel design as a
monolithic program and re-synthesize every *instance*, even when hundreds of
instances share a handful of *definitions* (gaussian: 564 instances of 15
tasks).  TAPA compiles each definition once and stitches instances, in
parallel — 6.8x faster codegen.

The XLA analogue is exact.  A stage function traced under `jax.jit` is
re-lowered and re-optimized for every call site unless the caller dedups.
This module compiles a task graph of JAX *stage definitions*:

* ``mode="monolithic"`` — one ``lower().compile()`` per *instance*
  (what a naive per-stage pipeline builder does, and what the paper's
  baseline tools do);
* ``mode="hierarchical"`` — one ``lower().compile()`` per unique
  *(definition, input-shape signature)*, run through a thread pool
  (XLA compilation releases the GIL), with every instance sharing its
  definition's executable.

Definitions are keyed by the **structural hash** from
:mod:`repro.core.compile_cache` — bytecode + constants + closure values +
aval signature — so dedup survives re-created closures and process
restarts, and compiled executables persist in the content-addressed store.
Passing the previous :class:`CompileReport` back in enables **incremental
recompilation**: only definitions whose hash changed are recompiled (the
paper's QoR-tuning loop — edit one of gaussian's 15 tasks, recompile 1/15).

For layers repeated *inside* one program the same idea appears as
``lax.scan`` over stacked weights (compile the body once) versus an
unrolled Python loop (recompile/optimize N inlined copies); see
``benchmarks/codegen_time.py`` which measures both forms.
"""

from __future__ import annotations

import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Union

import jax
import numpy as np

from .compile_cache import (CompileCache, aval_signature, default_cache,
                            instance_key, lower_spec, runtime_value,
                            structural_digest)


@dataclass
class StageInstance:
    """One instance of a JAX stage definition in a compiled dataflow graph."""
    fn: Callable
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    name: str = ""
    executable: Any = None

    @property
    def key(self) -> str:
        """Structural cache key: stable across processes and re-created
        closures (content digest, not ``id(fn)``)."""
        return instance_key(self.fn, self.args, self.kwargs)

    @property
    def definition_hash(self) -> str:
        """Digest of the definition alone (no input signature)."""
        return structural_digest(self.fn)

    @property
    def legacy_key(self) -> tuple:
        """Deprecated ``(id(fn), aval_signature)`` key.

        Object ids are reused after GC and differ across processes, causing
        both false sharing and missed dedup; use :attr:`key`.
        """
        warnings.warn("StageInstance.legacy_key is deprecated: id(fn) keys "
                      "are unstable across GC and processes; use .key",
                      DeprecationWarning, stacklevel=2)
        return (id(self.fn), aval_signature(self.args, self.kwargs))


@dataclass
class CompileReport:
    mode: str
    n_instances: int
    n_unique: int
    wall_s: float
    per_key_s: dict = field(default_factory=dict)
    # key -> "compiled" | "memory" | "disk" | "prev" (where it came from)
    sources: dict = field(default_factory=dict)
    executables: dict = field(default_factory=dict, repr=False)
    cache_stats: dict = field(default_factory=dict)

    def _count(self, *srcs: str) -> int:
        return sum(1 for s in self.sources.values() if s in srcs)

    @property
    def n_compiled(self) -> int:
        """Actual XLA compilations performed (the expensive part)."""
        return self._count("compiled")

    @property
    def n_cache_hits(self) -> int:
        return self._count("memory", "disk")

    @property
    def n_reused(self) -> int:
        """Definitions carried over unchanged from the previous report."""
        return self._count("prev")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<CompileReport {self.mode} {self.wall_s:.3f}s "
                f"instances={self.n_instances} unique={self.n_unique} "
                f"compiled={self.n_compiled} hits={self.n_cache_hits} "
                f"reused={self.n_reused}>")


def diff_definitions(prev: Optional[CompileReport],
                     instances: list[StageInstance]) -> tuple[set, set]:
    """Split the instance key-set into (clean, dirty) against ``prev``.

    A key is *clean* when the previous report compiled it (same structural
    hash — same bytecode, constants, closure values, and input signature);
    anything else — a new definition or an edited one — is *dirty*.
    """
    keys = {i.key for i in instances}
    if prev is None:
        return set(), keys
    clean = {k for k in keys if k in prev.executables}
    return clean, keys - clean


def _compile_one(fn: Callable, args: tuple, kwargs: dict) -> Any:
    # interface args lower as avals: an mmap buffer is a runtime input of
    # the executable, never a constant baked into it
    args = tuple(lower_spec(a) for a in args)
    kwargs = {k: lower_spec(v) for k, v in kwargs.items()}
    lowered = jax.jit(fn).lower(*args, **kwargs)
    return lowered.compile()


def compile_stages(instances: list[StageInstance], mode: str = "hierarchical",
                   max_workers: Optional[int] = None, *,
                   cache: Union[CompileCache, None, bool] = None,
                   prev: Optional[CompileReport] = None) -> CompileReport:
    """Compile every stage instance; attaches executables in place.

    ``cache``: a :class:`CompileCache`, ``None`` for the process default, or
    ``False`` to bypass persistence (pure in-process dedup, the seed
    behaviour).  ``prev``: a previous report — unchanged definitions reuse
    its executables without even a cache probe (incremental recompilation).
    Monolithic mode never consults the cache: it *is* the paper's baseline.
    """
    t0 = time.perf_counter()
    per_key: dict = {}
    sources: dict = {}
    executables: dict = {}
    cc: Optional[CompileCache]
    if mode == "monolithic" or cache is False:
        cc = None
    elif cache is None or cache is True:
        cc = default_cache()
    else:
        cc = cache

    # per-call digest memo: N instances of K definitions need K content
    # hashes, not N (safe within one call — the list pins the fn objects,
    # so ids can't be recycled; a cross-call memo would go stale on
    # in-place weight edits, see structural_digest)
    digests: dict[int, str] = {}

    def key_of(inst: StageInstance) -> str:
        d = digests.get(id(inst.fn))
        if d is None:
            d = digests[id(inst.fn)] = structural_digest(inst.fn)
        return instance_key(inst.fn, inst.args, inst.kwargs, digest=d)

    if mode == "monolithic":
        # paper-baseline behaviour: every instance compiled separately, "as
        # if they are completely unrelated" (S1).  Each instance gets a
        # fresh function identity so JAX's own jit cache cannot silently
        # deduplicate what the baseline tools would recompile.
        for n, inst in enumerate(instances):
            t1 = time.perf_counter()
            fresh = (lambda f: lambda *a, **k: f(*a, **k))(inst.fn)
            inst.executable = _compile_one(fresh, inst.args, inst.kwargs)
            k = f"{n}:{inst.name or 'inst'}"
            per_key[k] = time.perf_counter() - t1
            sources[k] = "compiled"
            # keyed by structural key too, so even a baseline report works
            # as prev= for an incremental follow-up
            executables[key_of(inst)] = inst.executable
        uniq = len({key_of(i) for i in instances})
    elif mode == "hierarchical":
        groups: dict[str, list[StageInstance]] = {}
        for inst in instances:
            groups.setdefault(key_of(inst), []).append(inst)
        uniq = len(groups)

        def job(key_insts):
            key, insts = key_insts
            t1 = time.perf_counter()
            rep = insts[0]
            if prev is not None and key in prev.executables:
                exe, source = prev.executables[key], "prev"
            elif cc is not None:
                exe, source = cc.compile_cached(
                    rep.fn, rep.args, rep.kwargs, key=key)
            else:
                exe, source = _compile_one(rep.fn, rep.args, rep.kwargs), \
                    "compiled"
            for i in insts:
                i.executable = exe
            return key, exe, source, time.perf_counter() - t1

        # XLA compilation drops the GIL, so a thread pool gives true
        # parallel codegen on multi-core build hosts (paper: "TAPA runs HLS
        # in parallel on multi-core machines").
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            for key, exe, source, dt in pool.map(job, groups.items()):
                per_key[key] = dt
                sources[key] = source
                executables[key] = exe
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return CompileReport(mode=mode, n_instances=len(instances),
                         n_unique=uniq, wall_s=time.perf_counter() - t0,
                         per_key_s=per_key, sources=sources,
                         executables=executables,
                         cache_stats=cc.stats.as_dict() if cc else {})


# ---------------------------------------------------------------------------
# running a compiled feed-forward dataflow graph
# ---------------------------------------------------------------------------

@dataclass
class DataflowProgram:
    """A compiled task graph: stages + channel wiring.

    ``wiring`` maps each stage to (input stage indices); stage i consumes
    the outputs of its listed predecessors (in order) plus its bound args.
    ``source_indices`` lists the stages fed by graph inputs, positionally;
    when omitted it defaults to every stage with no predecessors.  Calling
    the program with the wrong number of inputs raises — inputs are never
    silently dropped or misassigned.  The call returns the outputs of every
    *sink* stage (a stage no other stage consumes): the bare value for a
    single sink, a tuple for several.

    This executor covers feed-forward graphs (systolic arrays, stencil
    pipelines); graphs with feedback run under the simulation engines or
    the pipeline-parallel schedule in ``repro.distributed.pipeline``.
    """
    instances: list[StageInstance]
    wiring: dict = field(default_factory=dict)   # idx -> list[pred idx]
    source_indices: Optional[list] = None        # stages fed by graph inputs

    def sources(self) -> list:
        if self.source_indices is not None:
            return list(self.source_indices)
        return [i for i in range(len(self.instances))
                if not self.wiring.get(i)]

    def sinks(self) -> list:
        consumed = {p for preds in self.wiring.values() for p in preds}
        return [i for i in range(len(self.instances)) if i not in consumed]

    def __call__(self, *graph_inputs):
        srcs = self.sources()
        if len(graph_inputs) != len(srcs):
            raise ValueError(
                f"DataflowProgram: got {len(graph_inputs)} graph input(s) "
                f"for {len(srcs)} source stage(s) {srcs}; pass exactly one "
                f"input per source (or set source_indices explicitly)")
        feed = dict(zip(srcs, graph_inputs))
        outputs: dict[int, Any] = {}
        for idx, inst in enumerate(self.instances):
            ins = [outputs[p] for p in self.wiring.get(idx, [])]
            if idx in feed:
                ins = [feed[idx]] + ins
            # mmap-bound args feed their *current* device buffer at call
            # time (scalars their value); the executable was lowered
            # against avals, so fresh data needs no recompilation
            bound = tuple(runtime_value(a) for a in inst.args)
            bkw = {k: runtime_value(v) for k, v in inst.kwargs.items()}
            if inst.executable is not None:
                outputs[idx] = inst.executable(*ins, *bound, **bkw)
            else:
                outputs[idx] = inst.fn(*ins, *bound, **bkw)
        outs = [outputs[i] for i in self.sinks()]
        return outs[0] if len(outs) == 1 else tuple(outs)


def build_dataflow(instances: list[StageInstance], wiring: dict,
                   source_indices: Optional[list] = None) -> DataflowProgram:
    """Wrap compiled stage instances into a runnable DataflowProgram.

    Convention: a fed stage's *leading* bound args are compile-time
    placeholders for its runtime inputs — one per wired predecessor, plus
    one if the stage receives a graph input.  The program gets *copies*
    with those placeholders stripped (at call time the graph supplies the
    real values); the caller's instances keep their compile-time args, so
    their cache keys stay valid for a later incremental
    ``compile_stages(..., prev=report)``.
    """
    from dataclasses import replace
    prog = DataflowProgram(instances=list(instances), wiring=wiring,
                           source_indices=source_indices)
    fed = set(prog.sources())
    prog.instances = [
        replace(inst, args=inst.args[len(wiring.get(idx, ())) +
                                     (1 if idx in fed else 0):])
        for idx, inst in enumerate(instances)]
    return prog


def hashable_definition_count(instances: list[StageInstance]) -> tuple:
    return (len(instances), len({i.key for i in instances}))
