"""Hierarchical code generation (paper Section 3.3), adapted to XLA.

The paper's observation: HLS tools treat a task-parallel design as a
monolithic program and re-synthesize every *instance*, even when hundreds of
instances share a handful of *definitions* (gaussian: 564 instances of 15
tasks).  TAPA compiles each definition once and stitches instances, in
parallel — 6.8x faster codegen.

The XLA analogue is exact.  A stage function traced under `jax.jit` is
re-lowered and re-optimized for every call site unless the caller dedups.
This module compiles a task graph of JAX *stage definitions*:

* ``mode="monolithic"`` — one ``lower().compile()`` per *instance*
  (what a naive per-stage pipeline builder does, and what the paper's
  baseline tools do);
* ``mode="hierarchical"`` — one ``lower().compile()`` per unique
  *(definition, input-shape signature)*, run through a thread pool
  (XLA compilation releases the GIL), with every instance sharing its
  definition's executable.

For layers repeated *inside* one program the same idea appears as
``lax.scan`` over stacked weights (compile the body once) versus an
unrolled Python loop (recompile/optimize N inlined copies); see
``benchmarks/codegen_time.py`` which measures both forms.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np


def _aval_signature(args: tuple, kwargs: dict) -> tuple:
    """Shape/dtype signature of array-like args (ShapeDtypeStruct aware)."""
    def one(x):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return ("arr", tuple(x.shape), str(x.dtype))
        if isinstance(x, (list, tuple)):
            return ("seq", tuple(one(v) for v in x))
        if isinstance(x, dict):
            return ("map", tuple(sorted((k, one(v)) for k, v in x.items())))
        return ("lit", repr(x))
    return (tuple(one(a) for a in args),
            tuple(sorted((k, one(v)) for k, v in kwargs.items())))


@dataclass
class StageInstance:
    """One instance of a JAX stage definition in a compiled dataflow graph."""
    fn: Callable
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    name: str = ""
    executable: Any = None

    @property
    def key(self) -> tuple:
        return (id(self.fn), _aval_signature(self.args, self.kwargs))


@dataclass
class CompileReport:
    mode: str
    n_instances: int
    n_unique: int
    wall_s: float
    per_key_s: dict = field(default_factory=dict)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<CompileReport {self.mode} {self.wall_s:.3f}s "
                f"instances={self.n_instances} unique={self.n_unique}>")


def _compile_one(fn: Callable, args: tuple, kwargs: dict) -> Any:
    lowered = jax.jit(fn).lower(*args, **kwargs)
    return lowered.compile()


def compile_stages(instances: list[StageInstance], mode: str = "hierarchical",
                   max_workers: Optional[int] = None) -> CompileReport:
    """Compile every stage instance; attaches executables in place."""
    t0 = time.perf_counter()
    per_key: dict = {}
    if mode == "monolithic":
        # paper-baseline behaviour: every instance compiled separately, "as
        # if they are completely unrelated" (S1).  Each instance gets a
        # fresh function identity so JAX's own jit cache cannot silently
        # deduplicate what the baseline tools would recompile.
        for n, inst in enumerate(instances):
            t1 = time.perf_counter()
            fresh = (lambda f: lambda *a, **k: f(*a, **k))(inst.fn)
            inst.executable = _compile_one(fresh, inst.args, inst.kwargs)
            per_key[f"{n}:{inst.name or 'inst'}"] = \
                time.perf_counter() - t1
        uniq = len({i.key for i in instances})
    elif mode == "hierarchical":
        groups: dict[tuple, list[StageInstance]] = {}
        for inst in instances:
            groups.setdefault(inst.key, []).append(inst)
        uniq = len(groups)

        def job(key_insts):
            key, insts = key_insts
            t1 = time.perf_counter()
            exe = _compile_one(insts[0].fn, insts[0].args, insts[0].kwargs)
            for i in insts:
                i.executable = exe
            return key, time.perf_counter() - t1

        # XLA compilation drops the GIL, so a thread pool gives true
        # parallel codegen on multi-core build hosts (paper: "TAPA runs HLS
        # in parallel on multi-core machines").
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            for key, dt in pool.map(job, groups.items()):
                per_key[key] = dt
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return CompileReport(mode=mode, n_instances=len(instances),
                         n_unique=uniq, wall_s=time.perf_counter() - t0,
                         per_key_s=per_key)


# ---------------------------------------------------------------------------
# running a compiled feed-forward dataflow graph
# ---------------------------------------------------------------------------

@dataclass
class DataflowProgram:
    """A compiled task graph: stages + channel wiring.

    ``wiring`` maps each stage to (input stage indices); stage i consumes
    the outputs of its listed predecessors (in order) plus its bound args.
    This executor covers feed-forward graphs (systolic arrays, stencil
    pipelines); graphs with feedback run under the simulation engines or
    the pipeline-parallel schedule in ``repro.distributed.pipeline``.
    """
    instances: list[StageInstance]
    wiring: dict = field(default_factory=dict)   # idx -> list[pred idx]

    def __call__(self, *graph_inputs):
        outputs: dict[int, Any] = {}
        feed = list(graph_inputs)
        for idx, inst in enumerate(self.instances):
            preds = self.wiring.get(idx, [])
            ins = [outputs[p] for p in preds]
            if not preds and feed:
                ins = [feed.pop(0)]
            if inst.executable is not None:
                outputs[idx] = inst.executable(*ins, *inst.args,
                                               **inst.kwargs)
            else:
                outputs[idx] = inst.fn(*ins, *inst.args, **inst.kwargs)
        return outputs[len(self.instances) - 1]


def hashable_definition_count(instances: list[StageInstance]) -> tuple:
    return (len(instances), len({i.key for i in instances}))
