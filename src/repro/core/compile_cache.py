"""Persistent content-addressed compile cache (paper Section 3.3, extended).

The paper's hierarchical-codegen speedup comes from compiling each task
*definition* once and stitching instances.  The seed reproduction kept only
the in-process half of that: definitions were keyed on ``id(fn)``, so every
new process, every re-created closure, and every QoR-tuning edit recompiled
the world.  This module supplies the missing halves:

1.  **Structural definition hash** — a stable digest of a Python function's
    bytecode, constants, referenced globals, closure cell *values*, and
    defaults (plus the jax version, backend, and cache schema).  Two
    separately-created lambdas with the same body hash equal; an edited
    constant or closure weight hashes different.  The digest survives
    process restarts, which ``id(fn)`` never could.

2.  **Two-level content-addressed store** — an in-memory dict in front of an
    on-disk store (``<root>/v1/ex/<hh>/<digest>.exe``) holding serialized
    XLA executables (:mod:`jax.experimental.serialize_executable`).  Disk
    entries are LRU-evicted against a size bound, corrupt entries are
    deleted and recompiled, and a schema bump invalidates the whole layout.

3.  **Result memo store** — small JSON payloads keyed by the same digests
    (``<root>/v1/memo/<hh>/<digest>.json``), used by the QoR-tuning loop in
    ``benchmarks/perf_iter.py`` to skip re-measuring unchanged variants.

The cache is what makes the paper's edit-compile-measure cycle fast across
*runs*: edit one of gaussian's definitions and only that definition pays an
XLA compile — everything else is a digest lookup.  See ``docs/codegen.md``.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import re
import threading
import types
from dataclasses import dataclass, field
from functools import partial
from pathlib import Path
from typing import Any, Callable, Optional

import numpy as np

SCHEMA = "v1"
_ADDR_RE = re.compile(r" at 0x[0-9a-fA-F]+")

# how deep to chase functions referenced from globals/closures before
# falling back to their qualified name (keeps the hash off library innards)
_MAX_FN_DEPTH = 4


# ---------------------------------------------------------------------------
# structural hashing
# ---------------------------------------------------------------------------

def _stable_repr(v: Any) -> str:
    """``repr`` with memory addresses stripped (stable across processes)."""
    return _ADDR_RE.sub("", repr(v))


def _obj_state(v: Any) -> Optional[dict]:
    """Instance attributes of an object (``__dict__`` or ``__slots__``),
    or None when it carries no inspectable state."""
    d = getattr(v, "__dict__", None)
    if d:
        return dict(d)
    slots = getattr(type(v), "__slots__", None)
    if slots:
        return {s: getattr(v, s, None) for s in slots
                if isinstance(s, str)}
    return None


def _enc_code(h, code: types.CodeType, depth: int, seen: set) -> None:
    h.update(b"code")
    h.update(code.co_code)
    h.update(_stable_repr(code.co_names).encode())
    h.update(_stable_repr(code.co_freevars).encode())
    h.update(str(code.co_argcount).encode())
    for c in code.co_consts:
        _enc(h, c, depth, seen)


def _code_names(code: types.CodeType, acc: set) -> None:
    """All names referenced by ``code`` and every nested code object —
    a constant read inside a nested lambda is still baked into the traced
    program, so its global must be value-hashed too."""
    acc.update(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            _code_names(c, acc)


def _enc_fn(h, fn: Callable, depth: int, seen: set) -> None:
    if id(fn) in seen or depth > _MAX_FN_DEPTH:
        h.update(getattr(fn, "__qualname__", repr(type(fn))).encode())
        return
    seen.add(id(fn))
    if isinstance(fn, partial):
        h.update(b"partial")
        _enc_fn(h, fn.func, depth, seen)
        _enc(h, fn.args, depth, seen)
        _enc(h, fn.keywords, depth, seen)
        return
    code = getattr(fn, "__code__", None)
    if code is None:
        # no Python code object: unwrap before giving up — jit wrappers
        # expose __wrapped__, bound methods __func__ (+ the state their
        # behaviour depends on, __self__)
        wrapped = getattr(fn, "__wrapped__", None)
        if wrapped is not None and wrapped is not fn:
            h.update(b"wrapped")
            _enc_fn(h, wrapped, depth, seen)
            return
        inner = getattr(fn, "__func__", None)
        state = _obj_state(fn)
        if inner is not None and inner is not fn:
            _enc_fn(h, inner, depth + 1, seen)
            _enc(h, getattr(fn, "__self__", None), depth + 1, seen)
        elif state is not None:
            # callable object instance: behaviour = class __call__ code +
            # instance attributes (Scale(2.0) must never collide with
            # Scale(3.0))
            h.update(f"callable-obj:{type(fn).__qualname__}".encode())
            _enc(h, state, depth + 1, seen)
            call = getattr(type(fn), "__call__", None)
            if getattr(call, "__code__", None) is not None:
                _enc_fn(h, call, depth + 1, seen)
        elif depth == 0:
            # opaque top-level callable: a content digest is impossible,
            # so salt with the object identity — unstable keys cost a
            # recompile, shared keys would silently reuse the wrong
            # executable
            h.update(f"opaque:{type(fn).__qualname__}:{id(fn)}".encode())
        else:
            h.update(getattr(fn, "__qualname__",
                             _stable_repr(fn)).encode())
        return
    _enc_code(h, code, depth + 1, seen)
    _enc(h, getattr(fn, "__defaults__", None), depth + 1, seen)
    _enc(h, getattr(fn, "__kwdefaults__", None), depth + 1, seen)
    if getattr(fn, "__self__", None) is not None:     # bound with state
        _enc(h, fn.__self__, depth + 1, seen)
    # closure cell *values*: a re-created closure over the same data hashes
    # equal; an edited weight/constant hashes different
    closure = getattr(fn, "__closure__", None) or ()
    for name, cell in zip(code.co_freevars, closure):
        h.update(name.encode())
        try:
            _enc(h, cell.cell_contents, depth + 1, seen)
        except ValueError:          # empty cell (still being defined)
            h.update(b"<empty-cell>")
    # referenced module-level globals — including ones only nested code
    # objects touch: data is hashed by content, functions structurally,
    # modules by name (stage fns bake these into the program)
    gl = getattr(fn, "__globals__", {})
    names: set = set()
    _code_names(code, names)
    for name in sorted(names):
        if name in gl:
            v = gl[name]
            if isinstance(v, types.ModuleType):
                h.update(f"mod:{v.__name__}".encode())
            else:
                h.update(name.encode())
                _enc(h, v, depth + 1, seen)


def _enc(h, v: Any, depth: int = 0, seen: Optional[set] = None) -> None:
    seen = seen if seen is not None else set()
    iface = None if isinstance(v, type) else getattr(v, "iface_kind", None)
    if v is None or isinstance(v, (bool, int, float, complex, str, bytes)):
        h.update(f"lit:{v!r}".encode())
    elif iface in ("mmap", "async_mmap"):
        # the typed-interface contract (paper S3.1.2): an mmap argument is
        # a *runtime* device buffer, so only its aval reaches the hash —
        # two instances differing in array values share one definition.
        # Async ports fold in latency/depth: they size the lowered queue.
        h.update(f"{iface}:{v.dtype}:{tuple(v.shape)}".encode())
        if iface == "async_mmap":
            h.update(f":lat{v.latency}:d{v.depth}".encode())
    elif iface == "scalar":
        h.update(b"scalar")
        _enc(h, v.value, depth, seen)
    elif isinstance(v, types.ModuleType):
        h.update(f"mod:{v.__name__}".encode())
    elif isinstance(v, types.CodeType):
        _enc_code(h, v, depth, seen)
    elif isinstance(v, (types.FunctionType, types.MethodType, partial)) \
            or callable(v) and not isinstance(v, type):
        _enc_fn(h, v, depth, seen)
    elif isinstance(v, type):
        # classes hash by qualified name — never by their descriptor
        # attributes (a class with shape/dtype __slots__ is not an array)
        h.update(f"cls:{v.__module__}.{v.__qualname__}".encode())
    elif isinstance(v, np.ndarray):
        h.update(f"nd:{v.dtype}:{v.shape}".encode())
        h.update(np.ascontiguousarray(v).tobytes())
    elif hasattr(v, "shape") and hasattr(v, "dtype"):
        # jax arrays (hash content: constants get baked into programs) and
        # ShapeDtypeStructs (shape/dtype only — they carry no data)
        h.update(f"arr:{v.dtype}:{tuple(v.shape)}".encode())
        try:
            h.update(np.asarray(v).tobytes())
        except (TypeError, ValueError):
            pass
    elif isinstance(v, (tuple, list)):
        h.update(f"seq:{len(v)}".encode())
        for x in v:
            _enc(h, x, depth, seen)
    elif isinstance(v, dict):
        h.update(f"map:{len(v)}".encode())
        for k in sorted(v, key=_stable_repr):
            _enc(h, k, depth, seen)
            _enc(h, v[k], depth, seen)
    elif isinstance(v, (set, frozenset)):
        h.update(b"set")
        for x in sorted(v, key=_stable_repr):
            _enc(h, x, depth, seen)
    else:
        h.update(f"obj:{type(v).__qualname__}".encode())
        # default reprs are address-only: hash instance state instead (a
        # bound method's behaviour depends on __self__'s attributes)
        state = _obj_state(v)
        if state and id(v) not in seen and depth <= _MAX_FN_DEPTH:
            seen.add(id(v))
            _enc(h, state, depth + 1, seen)
        else:
            h.update(_stable_repr(v).encode())


def structural_digest(fn: Callable) -> str:
    """Stable digest of a task *definition* (no input signature).

    Contract: equal digests mean "tracing this function produces the same
    computation for the same input avals".  Covered: bytecode, constants,
    defaults, closure cell values, bound-method receiver state, referenced
    module-level globals including those read from nested functions (data
    by content, functions structurally, modules by name).  NOT covered:
    attribute chains deeper than the recursion cap and impure reads (time,
    rng, I/O) — functions doing those must bypass the cache
    (docs/codegen.md).  Deliberately NOT memoized per function object: the
    QoR loop mutates captured arrays in place, and a memo would return the
    pre-edit digest.
    """
    h = hashlib.sha256()
    _enc_fn(h, fn, 0, set())
    return h.hexdigest()


def aval_signature(args: tuple, kwargs: dict) -> tuple:
    """Shape/dtype signature of array-like args (ShapeDtypeStruct and
    interface aware: mmap/async_mmap sign by aval, scalars by value)."""
    def one(x):
        k = getattr(x, "iface_kind", None)
        if k in ("mmap", "async_mmap"):
            return (k, tuple(x.shape), str(x.dtype))
        if k == "scalar":
            return ("lit", repr(x.value))
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return ("arr", tuple(x.shape), str(x.dtype))
        if isinstance(x, (list, tuple)):
            return ("seq", tuple(one(v) for v in x))
        if isinstance(x, dict):
            return ("map", tuple(sorted((k, one(v)) for k, v in x.items())))
        return ("lit", repr(x))
    return (tuple(one(a) for a in args),
            tuple(sorted((k, one(v)) for k, v in kwargs.items())))


_aval_signature = aval_signature        # pre-rename alias


def lower_spec(v: Any) -> Any:
    """Replace interface arguments with what the XLA lowering should see:
    mmap/async_mmap become :class:`jax.ShapeDtypeStruct` placeholders (the
    buffer is a runtime input, not a baked constant) and scalars unwrap to
    their value.  Containers are converted recursively."""
    k = getattr(v, "iface_kind", None)
    if k in ("mmap", "async_mmap"):
        import jax
        return jax.ShapeDtypeStruct(v.shape, np.dtype(v.dtype))
    if k == "scalar":
        return v.value
    if isinstance(v, (list, tuple)):
        return type(v)(lower_spec(x) for x in v)
    if isinstance(v, dict):
        return {key: lower_spec(x) for key, x in v.items()}
    return v


def runtime_value(v: Any) -> Any:
    """Replace interface arguments with their runtime payload: the mmap's
    device buffer / the scalar's value — what a compiled executable is
    actually fed (mirrors :func:`lower_spec`)."""
    k = getattr(v, "iface_kind", None)
    if k in ("mmap", "async_mmap"):
        return v.data
    if k == "scalar":
        return v.value
    if isinstance(v, (list, tuple)):
        return type(v)(runtime_value(x) for x in v)
    if isinstance(v, dict):
        return {key: runtime_value(x) for key, x in v.items()}
    return v


def instance_key(fn: Callable, args: tuple = (), kwargs: Optional[dict] = None,
                 *, extra: Any = None, digest: Optional[str] = None) -> str:
    """Full cache key: definition digest + aval signature + toolchain.

    Executables are only valid for (definition, input avals, jax version,
    backend); all four are folded into the key so a toolchain upgrade or a
    backend switch is a clean miss, never a wrong hit.  ``digest``: a
    precomputed ``structural_digest(fn)`` — callers keying many instances
    of one definition pass it to skip the redundant content hash.
    """
    import jax
    h = hashlib.sha256()
    h.update((digest or structural_digest(fn)).encode())
    h.update(_stable_repr(aval_signature(args, kwargs or {})).encode())
    h.update(f"jax:{jax.__version__}:{jax.default_backend()}:{SCHEMA}"
             .encode())
    if extra is not None:
        _enc(h, extra)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

@dataclass
class CacheStats:
    mem_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    puts: int = 0
    evictions: int = 0
    corrupt: int = 0
    serialize_failures: int = 0
    memo_hits: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


def _default_root() -> Path:
    return Path(os.environ.get(
        "REPRO_COMPILE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache",
                     "repro-compile-cache")))


# Framed executable entries: magic + sha256(blob) + blob.  The digest makes
# any bit-level corruption (not just unpicklable truncation) detectable at
# read time, feeding the existing delete+recompile path.  Legacy unframed
# entries (pre-digest trees) still load.
_MAGIC = b"RCC1"
_DIGEST_LEN = 32


def _frame(blob: bytes) -> bytes:
    return _MAGIC + hashlib.sha256(blob).digest() + blob


def _unframe(data: bytes) -> bytes:
    if not data.startswith(_MAGIC):
        return data                     # legacy unframed entry
    digest = data[len(_MAGIC):len(_MAGIC) + _DIGEST_LEN]
    blob = data[len(_MAGIC) + _DIGEST_LEN:]
    if hashlib.sha256(blob).digest() != digest:
        raise ValueError("cache entry digest mismatch")
    return blob


class CompileCache:
    """Two-level (memory, disk) content-addressed executable store.

    Layout (versioned; a SCHEMA bump orphans old trees wholesale)::

        <root>/v1/ex/<digest[:2]>/<digest>.exe     pickled serialized exe
        <root>/v1/memo/<digest[:2]>/<digest>.json  memoized JSON results

    Disk entries carry their last-use time in mtime (bumped on every hit);
    eviction drops least-recently-used entries until the tree fits
    ``max_bytes``.  Any unreadable/undeserializable entry is deleted and
    counted in ``stats.corrupt`` — a corrupt cache costs a recompile, never
    an error.
    """

    def __init__(self, root: Optional[os.PathLike] = None,
                 max_bytes: int = 512 << 20, disk: bool = True,
                 faults: Any = None):
        self.root = Path(root) if root is not None else _default_root()
        self.max_bytes = max_bytes
        self.disk = disk
        # chaos harness (repro.core.faults): injected transient write
        # failures and post-write corruption; None in normal operation
        if faults is not None and not hasattr(faults, "io_error"):
            faults = faults.injector()
        self.faults = faults
        self.stats = CacheStats()
        self._mem: dict[str, Any] = {}
        self._lock = threading.RLock()
        # running estimate of on-disk bytes; None until the first full
        # walk.  Keeps the per-put cost O(1): the tree is only re-walked
        # when the estimate crosses max_bytes.
        self._approx_bytes: Optional[int] = None

    # -- paths ---------------------------------------------------------------

    def _path(self, key: str, kind: str = "ex") -> Path:
        ext = "exe" if kind == "ex" else "json"
        return self.root / SCHEMA / kind / key[:2] / f"{key}.{ext}"

    def _entries(self) -> list:
        base = self.root / SCHEMA
        if not base.exists():
            return []
        out = []
        for p in base.rglob("*"):
            if p.is_file():
                try:
                    st = p.stat()
                    out.append((st.st_mtime, st.st_size, p))
                except OSError:
                    continue
        return out

    def disk_bytes(self) -> int:
        return sum(size for _, size, _ in self._entries())

    # -- executables ---------------------------------------------------------

    def get(self, key: str) -> Optional[Any]:
        exe, _ = self.get_with_source(key)
        return exe

    def get_with_source(self, key: str):
        """Return ``(executable, source)``; source in memory/disk/None."""
        with self._lock:
            exe = self._mem.get(key)
            if exe is not None:
                self.stats.mem_hits += 1
                return exe, "memory"
        if self.disk:
            p = self._path(key)
            if p.exists():
                try:
                    from jax.experimental import serialize_executable as se
                    entry = pickle.loads(_unframe(p.read_bytes()))
                    if entry.get("schema") != SCHEMA:
                        raise ValueError("schema mismatch")
                    payload, in_tree, out_tree = entry["payload"]
                    exe = se.deserialize_and_load(payload, in_tree, out_tree)
                    os.utime(p)                       # LRU bump
                    with self._lock:
                        self._mem[key] = exe
                        self.stats.disk_hits += 1
                    return exe, "disk"
                except Exception:
                    # corrupt / truncated / stale entry: delete + recompile
                    with self._lock:
                        self.stats.corrupt += 1
                    try:
                        p.unlink()
                    except OSError:
                        pass
        with self._lock:
            self.stats.misses += 1
        return None, None

    def put(self, key: str, executable: Any, meta: Optional[dict] = None
            ) -> None:
        with self._lock:
            self._mem[key] = executable
            self.stats.puts += 1
        if not self.disk:
            return
        try:
            from jax.experimental import serialize_executable as se
            payload = se.serialize(executable)
            buf = io.BytesIO()
            pickle.dump({"schema": SCHEMA, "key": key,
                         "meta": meta or {}, "payload": payload}, buf)
        except Exception:
            # not every executable serializes (callbacks, exotic custom
            # calls); stay memory-only rather than fail the compile
            with self._lock:
                self.stats.serialize_failures += 1
            return
        path = self._path(key)
        if self._write_atomic(path, _frame(buf.getvalue()), verify=True) and \
                self.faults is not None and self.faults.corrupt_cache():
            self._corrupt_entry(path)   # chaos: prove delete+recompile works
        self._maybe_evict()

    def compile_cached(self, fn: Callable, args: tuple = (),
                       kwargs: Optional[dict] = None, *,
                       key: Optional[str] = None, extra: Any = None,
                       hash_fn: Optional[Callable] = None,
                       jit_fn: Optional[Callable] = None,
                       jit_kwargs: Optional[dict] = None):
        """``jit(fn).lower(*args).compile()`` through the cache.

        ``hash_fn`` keys the entry on a different function than is compiled
        (e.g. hash the user's stage body, compile its shard_map wrapper
        whose internals would make a noisy hash); ``jit_fn`` overrides the
        callable handed to ``jax.jit``; ``jit_kwargs`` are forwarded to
        ``jax.jit`` (e.g. ``donate_argnums`` — input/output aliasing is
        part of the compiled HLO, so it survives (de)serialization and is
        folded into the key).  Returns ``(executable, source)``.
        """
        import jax
        kwargs = kwargs or {}
        if jit_kwargs:
            extra = (extra, sorted(jit_kwargs.items()))
        key = key or instance_key(hash_fn or fn, args, kwargs, extra=extra)
        exe, source = self.get_with_source(key)
        if exe is None:
            largs = tuple(lower_spec(a) for a in args)
            lkw = {k: lower_spec(v) for k, v in kwargs.items()}
            exe = jax.jit(jit_fn or fn, **(jit_kwargs or {})) \
                .lower(*largs, **lkw).compile()
            self.put(key, exe)
            source = "compiled"
        return exe, source

    # -- memoized JSON results (QoR-tuning measurements) ---------------------

    def memo_get(self, key: str) -> Optional[Any]:
        if not self.disk:
            return None
        p = self._path(key, "memo")
        if not p.exists():
            return None
        try:
            out = json.loads(p.read_text())
            os.utime(p)
            with self._lock:
                self.stats.memo_hits += 1
            return out
        except Exception:
            with self._lock:
                self.stats.corrupt += 1
            try:
                p.unlink()
            except OSError:
                pass
            return None

    def memo_put(self, key: str, value: Any) -> None:
        if not self.disk:
            return
        self._write_atomic(self._path(key, "memo"),
                           json.dumps(value).encode())
        self._maybe_evict()

    # -- maintenance ---------------------------------------------------------

    def _write_atomic(self, path: Path, data: bytes,
                      verify: bool = False) -> bool:
        """Write-rename a disk entry; one retry on a transient ``OSError``.

        With ``verify=True`` the published entry is read back and compared
        to what was written (verify-after-write), so a torn or silently
        failed write is caught while the original data is still in hand.
        Returns False when both attempts failed (read-only FS etc.): the
        store degrades to memory-only, never errors.
        """
        for attempt in (0, 1):
            try:
                if self.faults is not None and self.faults.io_error("cache"):
                    raise OSError("injected transient cache IO failure")
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
                tmp.write_bytes(data)
                os.replace(tmp, path)   # readers never see partial entries
                if verify and path.read_bytes() != data:
                    raise OSError(f"verify-after-write mismatch for {path}")
                with self._lock:
                    if self._approx_bytes is not None:
                        self._approx_bytes += len(data)
                return True
            except OSError:
                if attempt:
                    return False        # read-only FS: memory level only
        return False

    def _corrupt_entry(self, path: Path) -> None:
        """Chaos-only: flip one byte mid-entry (inside the framed blob for
        any realistically-sized executable), making the published entry
        fail its digest check on the next read."""
        try:
            data = bytearray(path.read_bytes())
            if data:
                data[len(data) // 2] ^= 0xFF
                path.write_bytes(bytes(data))
        except OSError:
            pass

    def _maybe_evict(self) -> None:
        """Full-tree eviction only when the running estimate says the
        bound may be exceeded (a put is O(1) otherwise)."""
        with self._lock:
            approx = self._approx_bytes
        if approx is None or approx > self.max_bytes:
            self.evict_to_fit()

    def evict_to_fit(self) -> int:
        """Drop least-recently-used disk entries until under ``max_bytes``."""
        entries = self._entries()
        total = sum(size for _, size, _ in entries)
        dropped = 0
        for _, size, p in sorted(entries):          # oldest mtime first
            if total <= self.max_bytes:
                break
            try:
                p.unlink()
                total -= size
                dropped += 1
            except OSError:
                continue
        with self._lock:
            self.stats.evictions += dropped
            self._approx_bytes = total
        return dropped

    def clear_memory(self) -> None:
        """Drop the first level (what a process restart does for free)."""
        with self._lock:
            self._mem.clear()

    def clear(self) -> None:
        self.clear_memory()
        for _, _, p in self._entries():
            try:
                p.unlink()
            except OSError:
                pass
        with self._lock:
            self._approx_bytes = 0


# ---------------------------------------------------------------------------
# process-default cache
# ---------------------------------------------------------------------------

_default: Optional[CompileCache] = None
_default_lock = threading.Lock()


def default_cache() -> CompileCache:
    """Process-wide cache; root from ``$REPRO_COMPILE_CACHE`` (or
    ``~/.cache/repro-compile-cache``), bound from
    ``$REPRO_COMPILE_CACHE_MAX_MB`` (default 512)."""
    global _default
    with _default_lock:
        if _default is None:
            mb = int(os.environ.get("REPRO_COMPILE_CACHE_MAX_MB", "512"))
            _default = CompileCache(max_bytes=mb << 20)
        return _default


def set_default_cache(cache: Optional[CompileCache]) -> None:
    global _default
    with _default_lock:
        _default = cache
