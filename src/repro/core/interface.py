"""Typed task interfaces (paper Section 3.1.2, Table 2).

The paper's programming model gives a task *three* kinds of interface:

* **streams** — the bounded FIFOs of :mod:`repro.core.channel`;
* **mmap / async_mmap** — views of external (off-chip) memory; and
* **scalars** — pass-by-value run parameters.

The seed reproduction only implemented streams: every app closure-captured
its numpy arrays, so external-memory traffic was invisible to the
simulators, absent from the graph IR, and baked into the structural hash as
constants (two instances differing only in captured array *values* hashed
apart).  This module makes the other two kinds first-class:

:class:`MMap`
    Synchronous memory view: loads/stores complete immediately
    (``m[i]`` / ``m[i] = v``) plus ``read_burst``/``write_burst`` slice
    transfers — the software analogue of an AXI burst.  Many tasks may
    read one ``MMap``; at most one may write it (the one-writer rule,
    mirroring the one-producer channel rule of Section 3.1.1).

:class:`AsyncMMap`
    The paper's five-channel decomposition of a memory port
    (``read_addr`` / ``read_data`` / ``write_addr`` / ``write_data`` /
    ``write_resp``), built on ordinary :class:`~repro.core.channel.Channel`
    objects.  Requests are *accepted* into an in-flight window bounded by
    ``depth`` and *delivered* ``latency`` engine ticks later, so a task
    that pipelines its requests genuinely overlaps them — observable in
    ``max_outstanding_reads``.  Exactly one task may bind an
    ``AsyncMMap`` (it models one memory port).

:class:`Scalar`
    A declared pass-by-value argument.  Binding unwraps it — the task body
    receives the plain Python value — but the wrapper marks the parameter
    in the per-definition interface table and hashes by value.

Engines discover interfaces from task arguments exactly as they discover
channels; delivery scheduling is engine-mediated (see
``EngineBase.schedule_async``): the coroutine and thread engines deliver
responses at request-time + latency (fast-forwarding the clock when every
task is stalled on memory), while the sequential engine delivers
synchronously and *records* the violation — it cannot overlap requests,
the same documented failure mode as its channel-capacity growth.
"""

from __future__ import annotations

import itertools
from typing import Any, Optional

import numpy as np

from .channel import Channel, IStream, OStream, _rt, select
from .context import current_runtime, current_task
from .errors import ChannelMisuse

_iface_uid = itertools.count()

# Canonical interface kinds (Table 2 rows + the stream directions).
KINDS = ("istream", "ostream", "mmap", "async_mmap", "scalar")


class InterfaceBinding:
    """One (task instance, parameter) binding — a row of the per-definition
    interface table extracted into the graph IR (Section 3.4)."""

    __slots__ = ("param", "kind", "dtype", "direction", "ref", "inst")

    def __init__(self, param: str, kind: str, dtype: Any, ref: Any,
                 inst: Any, direction: Optional[set] = None):
        self.param = param
        self.kind = kind          # istream/ostream/stream/mmap/async_mmap/
        #                           scalar/null/other
        self.dtype = dtype
        self.direction = direction if direction is not None else set()
        self.ref = ref            # the Channel / Interface object (or None)
        self.inst = inst

    def resolved_kind(self) -> str:
        """Late-resolve stream direction: an unannotated (AutoStream)
        channel binding settles to istream/ostream once the simulated body
        has used it."""
        if self.kind == "stream" and isinstance(self.ref, Channel):
            if self.ref.producer is self.inst:
                return "ostream"
            if self.ref.consumer is self.inst:
                return "istream"
        return self.kind

    def resolved_direction(self) -> str:
        k = self.resolved_kind()
        if k == "istream":
            return "in"
        if k == "ostream":
            return "out"
        if k == "scalar":
            return "in"
        if self.direction >= {"read", "write"}:
            return "readwrite"
        if "write" in self.direction:
            return "write"
        if "read" in self.direction:
            return "read"
        return "unused"

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<InterfaceBinding {self.param}:{self.resolved_kind()} "
                f"{self.resolved_direction()}>")


class Interface:
    """Base class for non-stream task interfaces."""

    iface_kind = "interface"


def _is_ancestor(anc: Any, inst: Any) -> bool:
    p = getattr(inst, "parent", None)
    while p is not None:
        if p is anc:
            return True
        p = p.parent
    return False


def _dtype_of(data: Any) -> Any:
    d = getattr(data, "dtype", None)
    return str(d) if d is not None else type(data).__name__


class Scalar(Interface):
    """Declared pass-by-value argument (paper Table 2's third interface
    kind).  Binding hands the task body the raw ``value``."""

    iface_kind = "scalar"

    __slots__ = ("value", "dtype")

    def __init__(self, value: Any, dtype: Any = None):
        self.value = value
        self.dtype = dtype if dtype is not None else type(value).__name__

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Scalar({self.value!r})"


class MMap(Interface):
    """Synchronous external-memory view over an array-like buffer.

    ``m[idx]`` / ``m[idx] = v`` are single-beat load/store;
    ``read_burst(start, n)`` / ``write_burst(start, seq)`` move contiguous
    slices (rows for >1-D buffers) in one transfer.  Loads and stores are
    tracked per task instance, which is how the graph IR learns each
    binding's direction without any annotation, and how the one-writer
    rule is enforced: at most one task instance may store.

    Statistics (``loads``/``stores``/``load_elems``/``store_elems``) are
    burst-granular and only recorded under ``track_stats=True`` runs —
    same opt-in contract as channel statistics.
    """

    iface_kind = "mmap"

    __slots__ = ("uid", "name", "data", "writer", "_by_inst",
                 "loads", "stores", "load_elems", "store_elems")

    def __init__(self, data: Any, name: Optional[str] = None):
        self.uid = next(_iface_uid)
        self.name = name or f"mmap{self.uid}"
        self.data = data
        self.writer = None              # task instance holding write access
        self._by_inst: dict = {}        # inst uid -> InterfaceBinding
        self.loads = 0
        self.stores = 0
        self.load_elems = 0
        self.store_elems = 0

    # -- shape plumbing (lets the compile path treat MMaps as avals) -------
    @property
    def shape(self) -> tuple:
        return tuple(np.shape(self.data))

    @property
    def dtype(self):
        return getattr(self.data, "dtype", np.asarray(self.data).dtype)

    def __len__(self) -> int:
        return len(self.data)

    def _reset_run(self) -> None:
        """Clear run-scoped state (bindings, writer, statistics) — called
        by an engine the first time it registers this interface, so one
        host-created MMap can be re-simulated under many engines."""
        self.writer = None
        self._by_inst = {}
        self.loads = self.stores = 0
        self.load_elems = self.store_elems = 0

    # -- binding ------------------------------------------------------------
    def _bind_task(self, binding: InterfaceBinding) -> None:
        self._by_inst[binding.inst.uid] = binding

    def _note(self, op: str, n: int) -> None:
        inst = current_task()
        if inst is not None:
            b = self._by_inst.get(inst.uid)
            if b is not None:
                b.direction.add(op)
            if op == "write":
                if self.writer is None:
                    self.writer = inst
                elif self.writer is not inst:
                    raise ChannelMisuse(
                        f"mmap {self.name!r} already has writer "
                        f"{self.writer.name}; task {inst.name} may not "
                        f"also store (one-writer rule)")
        rt = current_runtime()
        if rt is not None and rt.track_stats:
            if op == "read":
                self.loads += 1
                self.load_elems += n
            else:
                self.stores += 1
                self.store_elems += n

    # -- access -------------------------------------------------------------
    def __getitem__(self, idx) -> Any:
        v = self.data[idx]
        self._note("read", int(np.size(v)))
        return v.copy() if isinstance(v, np.ndarray) else v

    def __setitem__(self, idx, value) -> None:
        # element count = payload size (a broadcast scalar store counts 1)
        self._note("write", int(np.size(value)))
        self.data[idx] = value

    def read_burst(self, start: int, n: int) -> Any:
        """Load ``n`` consecutive elements (rows, for >1-D buffers)
        starting at ``start`` in one transfer; returns a copy."""
        if n < 0:
            raise ValueError("read_burst size must be >= 0")
        out = self.data[start:start + n]
        self._note("read", int(np.size(out)))
        return out.copy() if isinstance(out, np.ndarray) else list(out)

    def write_burst(self, start: int, seq) -> None:
        """Store the elements of ``seq`` contiguously from ``start`` in one
        transfer."""
        seq = np.asarray(seq) if not isinstance(seq, np.ndarray) else seq
        self._note("write", int(np.size(seq)))
        self.data[start:start + len(seq)] = seq

    def stats(self) -> dict:
        return {"loads": self.loads, "stores": self.stores,
                "load_elems": self.load_elems,
                "store_elems": self.store_elems}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"MMap({self.name!r}, shape={self.shape})"


class _ReqStream(OStream):
    """Producer view of an ``AsyncMMap`` request channel: a plain OStream
    whose pushes immediately offer the queued requests to the memory model
    (``pump``), so acceptance — and therefore response scheduling — happens
    at issue time, not at the next engine stall."""

    __slots__ = ("_iface",)

    def __init__(self, iface: "AsyncMMap", chan: Channel):
        super().__init__(chan)
        self._iface = iface

    def write(self, v) -> None:
        super().write(v)
        _rt().iface_pump(self._iface)

    def write_burst(self, seq) -> None:
        super().write_burst(seq)
        _rt().iface_pump(self._iface)

    def try_write(self, v) -> bool:
        ok = super().try_write(v)
        if ok:
            _rt().iface_pump(self._iface)
        return ok

    def try_write_burst(self, seq) -> int:
        k = super().try_write_burst(seq)
        if k:
            _rt().iface_pump(self._iface)
        return k

    def close(self) -> None:
        raise ChannelMisuse(
            "memory request channels carry no EoT tokens; an async_mmap "
            "port has no transactions to close")

    try_close = close


class AsyncMMap(Interface):
    """Asynchronous external-memory port: the paper's five-channel
    decomposition (read_addr/read_data/write_addr/write_data/write_resp).

    A read is *issued* by writing an address to ``read_addr`` and
    *completes* when the value appears on ``read_data`` — ``latency``
    engine ticks after the request was accepted.  Up to ``depth`` requests
    may be in flight per direction; a task that issues a burst of
    addresses before draining responses overlaps the round-trips
    (``max_outstanding_reads > 1``), while a strict
    issue-one/wait-for-one loop serializes them.  Writes pair one token
    from ``write_addr`` with one from ``write_data`` and acknowledge on
    ``write_resp`` after the same latency.

    Exactly one task instance may bind an ``AsyncMMap`` — it models a
    single memory port (use one object per port, as TAPA does).
    """

    iface_kind = "async_mmap"

    __slots__ = ("uid", "name", "data", "latency", "depth", "owner",
                 "_raddr", "_rdata", "_waddr", "_wdata", "_wresp",
                 "read_addr", "read_data", "write_addr", "write_data",
                 "write_resp", "_binding",
                 "_pending_reads", "_pending_writes",
                 "_inflight_reads", "_inflight_writes",
                 "read_reqs", "write_reqs", "read_resps", "write_resps",
                 "max_outstanding_reads", "max_outstanding_writes")

    def __init__(self, data: Any, latency: int = 4,
                 depth: Optional[int] = 4, name: Optional[str] = None):
        if latency < 0:
            raise ValueError("async_mmap latency must be >= 0")
        if depth is not None and (not isinstance(depth, int)
                                  or isinstance(depth, bool) or depth < 1):
            raise ValueError(
                "async_mmap outstanding depth must be an int >= 1, or "
                "None for an unbounded in-flight window (simulation only)")
        self.uid = next(_iface_uid)
        self.name = name or f"amap{self.uid}"
        self.data = data
        self.latency = latency
        self.depth = depth
        self.owner = None
        # member channels carry a declared element spec so the synthesis
        # path (core/synth.py) can size their ring buffers: addresses are
        # int32 scalars, data tokens rows of the buffer, write acks bools
        try:
            elem_dt = np.dtype(self.dtype)
            elem_shape: Optional[tuple] = tuple(self.shape[1:])
        except TypeError:
            elem_dt, elem_shape = None, None
        cap = depth if depth is not None else \
            max(1, self.shape[0] if self.shape else 1)
        mk = lambda side, dt, shp: Channel(  # noqa: E731
            cap, f"{self.name}.{side}", dtype=dt, shape=shp)
        self._raddr = mk("read_addr", np.int32, ())
        self._rdata = mk("read_data", elem_dt, elem_shape)
        self._waddr = mk("write_addr", np.int32, ())
        self._wdata = mk("write_data", elem_dt, elem_shape)
        self._wresp = mk("write_resp", np.bool_, ())
        for ch in self.channels():
            ch.iface = self
        # task-facing views (paper Table 2's async_mmap member streams)
        self.read_addr = _ReqStream(self, self._raddr)
        self.read_data = IStream(self._rdata)
        self.write_addr = _ReqStream(self, self._waddr)
        self.write_data = _ReqStream(self, self._wdata)
        self.write_resp = IStream(self._wresp)
        # accepted-but-undelivered request counts
        self._pending_reads = 0
        self._pending_writes = 0
        # accepted-but-undelivered request *payloads*, in acceptance order
        # (delivery is FIFO per direction, see pump()).  The engines never
        # read these — they exist so a GraphSnapshot (repro.ft.recovery)
        # can re-materialize in-flight requests, which otherwise live only
        # as closures in the engine's event heap.
        self._inflight_reads: list = []
        self._inflight_writes: list = []
        self._binding: Optional[InterfaceBinding] = None
        # statistics (request-granular, always on: acceptance is not the
        # per-token hot path)
        self.read_reqs = 0
        self.write_reqs = 0
        self.read_resps = 0
        self.write_resps = 0
        self.max_outstanding_reads = 0
        self.max_outstanding_writes = 0

    @property
    def shape(self) -> tuple:
        return tuple(np.shape(self.data))

    @property
    def dtype(self):
        return getattr(self.data, "dtype", np.asarray(self.data).dtype)

    def __len__(self) -> int:
        return len(self.data)

    def channels(self) -> tuple:
        return (self._raddr, self._rdata, self._waddr, self._wdata,
                self._wresp)

    def _reset_run(self) -> None:
        """Clear run-scoped state: ownership, in-flight counters, port
        FIFOs, and statistics — a host-created port is re-simulatable
        under a fresh engine."""
        self.owner = None
        self._binding = None
        self._pending_reads = self._pending_writes = 0
        self._inflight_reads = []
        self._inflight_writes = []
        self.read_reqs = self.write_reqs = 0
        self.read_resps = self.write_resps = 0
        self.max_outstanding_reads = self.max_outstanding_writes = 0
        for ch in self.channels():
            ch._q.clear()
            ch._rwait.clear()
            ch._wwait.clear()
            ch._eot_count = 0
            ch.producer = ch.consumer = None
            ch.total_written = ch.total_read = ch.max_occupancy = 0

    # -- binding ------------------------------------------------------------
    def _bind_task(self, binding: InterfaceBinding) -> None:
        inst = binding.inst
        if self.owner is not None and self.owner is not inst and \
                not _is_ancestor(self.owner, inst):
            raise ChannelMisuse(
                f"async_mmap {self.name!r} is already bound to task "
                f"{self.owner.name}; it models one memory port and cannot "
                f"also serve {inst.name}")
        # ownership follows the hierarchy down: a parent that receives the
        # port as an argument merely forwards it — the (unique) descendant
        # that binds it last is the task driving the port
        self.owner = inst
        self._binding = binding     # direction recorded at request accept
        # endpoint registration: the task produces requests and consumes
        # responses; the memory model is the opposite endpoint
        for ch in (self._raddr, self._waddr, self._wdata):
            ch.producer, ch.consumer = inst, self
        for ch in (self._rdata, self._wresp):
            ch.producer, ch.consumer = self, inst

    # the memory endpoint masquerades as a task for channel bookkeeping
    @property
    def parent(self):
        return self.owner.parent if self.owner is not None else None

    # -- the memory model ----------------------------------------------------
    def pump(self, engine) -> None:
        """Accept queued requests into the in-flight window.

        Called by the engines (at issue time via :class:`_ReqStream`, and
        from the scheduler's service step) — never by task bodies.  Each
        accepted request schedules its delivery ``latency`` ticks ahead via
        ``engine.schedule_async``.
        """
        # the window bounds *in-flight* requests (accepted, response not
        # yet produced); a full response FIFO additionally back-pressures
        # by deferring delivery, never by refusing acceptance — matching a
        # memory controller whose completions wait for the resp FIFO
        #
        # chaos harness: a fault plan with mem_spike entries perturbs the
        # per-request latency (FaultInjector.mem_delay).  Spikes may reorder
        # responses across ports/directions — legal, nothing guarantees
        # cross-port ordering — but mem_delay clamps due times so each
        # (port, direction) response FIFO stays in issue order.
        faults = getattr(engine, "faults", None)
        if faults is not None and not faults.affects_memory:
            faults = None
        while self._raddr._q and (self.depth is None or
                                  self._pending_reads < self.depth):
            addr = engine._iface_pop(self._raddr)
            if self._binding is not None:
                self._binding.direction.add("read")
            self._pending_reads += 1
            self._inflight_reads.append(addr)
            self.read_reqs += 1
            if self._pending_reads > self.max_outstanding_reads:
                self.max_outstanding_reads = self._pending_reads
            lat = self.latency if faults is None else faults.mem_delay(
                self.name, "read", self.latency, engine.clock)
            engine.schedule_async(
                lat,
                lambda eng, a=addr: self._deliver_read(eng, a))
        while (self._waddr._q and self._wdata._q and
               (self.depth is None or
                self._pending_writes < self.depth)):
            addr = engine._iface_pop(self._waddr)
            value = engine._iface_pop(self._wdata)
            if self._binding is not None:
                self._binding.direction.add("write")
            self._pending_writes += 1
            self._inflight_writes.append((addr, value))
            self.write_reqs += 1
            if self._pending_writes > self.max_outstanding_writes:
                self.max_outstanding_writes = self._pending_writes
            lat = self.latency if faults is None else faults.mem_delay(
                self.name, "write", self.latency, engine.clock)
            engine.schedule_async(
                lat,
                lambda eng, a=addr, v=value: self._deliver_write(eng, a, v))

    def _deliver_read(self, engine, addr) -> bool:
        """Complete one read: load the buffer and publish on read_data.
        Returns False (retry later) when the response channel is full."""
        if len(self._rdata._q) >= self._rdata.capacity and \
                not engine.force_async:
            return False
        v = self.data[addr]
        if isinstance(v, np.ndarray):
            v = v.copy()
        engine._iface_deliver(self._rdata, v)
        self._pending_reads -= 1
        if self._inflight_reads:
            self._inflight_reads.pop(0)   # FIFO per direction
        self.read_resps += 1
        self.pump(engine)       # a window slot freed: accept queued requests
        return True

    def _deliver_write(self, engine, addr, value) -> bool:
        if len(self._wresp._q) >= self._wresp.capacity and \
                not engine.force_async:
            return False
        self.data[addr] = value
        engine._iface_deliver(self._wresp, True)
        self._pending_writes -= 1
        if self._inflight_writes:
            self._inflight_writes.pop(0)  # FIFO per direction
        self.write_resps += 1
        self.pump(engine)
        return True

    # -- convenience: pipelined bulk helpers ---------------------------------
    def read_pipelined(self, addrs) -> list:
        """Issue every address in ``addrs`` as early as the in-flight
        window allows while draining responses — the idiomatic
        overlapped-read loop (request/response decoupling is the whole
        point of the five-channel form).  Returns the responses in
        request order."""
        addrs = list(addrs)
        out: list = []
        i = 0
        while len(out) < len(addrs):
            if i < len(addrs):
                i += self.read_addr.try_write_burst(addrs[i:])
            got = self.read_data.try_read_burst(len(addrs) - len(out))
            if got:
                out.extend(got)
            elif i < len(addrs):
                # never commit to a single side while both may progress:
                # block until the request channel has room OR a response
                # lands (a blocking write here would deadlock against a
                # full in-flight window)
                select(self.read_addr, self.read_data)
            else:
                out.extend(self.read_data.read_burst(len(addrs) - len(out)))
        return out

    def stats(self) -> dict:
        return {"read_reqs": self.read_reqs, "read_resps": self.read_resps,
                "write_reqs": self.write_reqs,
                "write_resps": self.write_resps,
                "max_outstanding_reads": self.max_outstanding_reads,
                "max_outstanding_writes": self.max_outstanding_writes,
                "latency": self.latency, "depth": self.depth}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"AsyncMMap({self.name!r}, shape={self.shape}, "
                f"latency={self.latency}, depth={self.depth})")


# ---------------------------------------------------------------------------
# factories (mirror repro.channel)
# ---------------------------------------------------------------------------

def mmap(data: Any, name: Optional[str] = None) -> MMap:
    """Wrap an array as a synchronous memory-mapped task argument —
    ``tapa::mmap<T>``."""
    return MMap(data, name=name)


def async_mmap(data: Any, latency: int = 4, depth: Optional[int] = 4,
               name: Optional[str] = None) -> AsyncMMap:
    """Wrap an array as an asynchronous memory port — ``tapa::async_mmap``
    with a configurable response latency and outstanding-request depth.
    ``depth=None`` gives an unbounded in-flight window (simulation only;
    synthesis needs a bounded window to size the latency queue)."""
    return AsyncMMap(data, latency=latency, depth=depth, name=name)


def scalar(value: Any, dtype: Any = None) -> Scalar:
    """Declare a pass-by-value task argument (the body receives the raw
    value; the wrapper only feeds the interface table and the hash)."""
    return Scalar(value, dtype=dtype)
