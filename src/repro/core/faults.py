"""Deterministic fault injection for the simulation runtime ("chaos harness").

The paper's productivity claim is that *software simulation* lets designers
verify task-parallel programs before hardware — which is only credible if the
simulator can exercise the unhappy paths too: stalled channels, slow memory
responses, dying tasks, corrupt artifacts, poisoned serving requests.  This
module provides a declarative :class:`FaultPlan` plus a stateful
:class:`FaultInjector` that the engines, interfaces, artifact stores and the
serving scheduler consult at well-defined points.

Design rules
------------
* **Deterministic and order-independent.**  Every probabilistic decision is a
  pure hash of ``(seed, kind, site, per-site counter)`` — blake2b, no global
  RNG — so the *decision for the k-th op at a given site* is identical under
  the sequential, thread and coroutine engines regardless of interleaving.
  That is what makes cross-engine fault-matrix parity tests possible.
* **Replayable.**  Every fired fault is appended to :attr:`FaultInjector.log`;
  the same plan (same seed) over the same program yields the same log.
* **Zero overhead when disabled.**  Engines keep a ``_chan_faults`` slot that
  is ``None`` unless the plan actually targets channels/tasks, so the hot
  push/pop paths stay a single ``is None`` test and ``fast_path`` stays on.
* **Legal faults only.**  Injected behaviours stay within the runtime's
  contract: stalls delay ops but never drop tokens; memory-latency spikes may
  reorder responses *across* ports/directions but never within one
  ``(port, direction)`` FIFO; artifact corruption is always detectable by the
  digests the stores now record.

Fault sites are *task-side* channel ops (``push``/``pop``/bursts issued by
task bodies); interface-internal deliveries are never perturbed directly —
memory misbehaviour is modelled by :meth:`FaultInjector.mem_delay` instead.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .errors import CrashFault, InjectedFault, PoisonError, TransientFault

__all__ = ["FaultPlan", "FaultInjector"]


def _draw(seed: int, *key) -> float:
    """Deterministic uniform draw in [0, 1) keyed by (seed, *key)."""
    h = hashlib.blake2b(repr((seed,) + key).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big") / 2.0 ** 64


@dataclass
class FaultPlan:
    """Declarative description of the faults to inject into one run.

    All fields default to "no fault"; an empty plan's injector is a no-op
    (and engines keep their fast paths).  Sites accept ``"*"`` as a
    wildcard where noted.

    chan_stall
        ``{channel_name | "*": {"p": prob, "stall": ticks, "wake": ticks}}``.
        With probability ``p`` per op, the issuing task stalls for ``stall``
        logical ticks after the op, and any wake-up it owes the opposite
        endpoint is delayed by ``wake`` ticks (delivered via the engine's
        event queue — the token itself is never lost).
    task_raise
        ``{task_name: n}`` — the task's n-th channel op (0-based, program
        order, engine-independent) raises :class:`InjectedFault`.
    crash
        ``{task_name | "chunk": n}`` — process-crash analogue for the
        recovery subsystem.  A task-name site raises :class:`CrashFault`
        at that task's n-th channel op (same 0-based, engine-independent
        counting as ``task_raise``); the reserved site ``"chunk"`` fires
        at the n-th chunk boundary consulted via
        :meth:`FaultInjector.crash_point` (how the compiled engine, which
        has no per-op hook, gets crashed).  Each site fires at most once
        per injector, so a supervisor that reuses the injector across
        restart attempts does not re-crash the recovered run.
    mem_spike
        ``{port_name | "*": {"p": prob, "extra": ticks}}`` — AsyncMMap
        requests take ``extra`` additional ticks with probability ``p``.
        Responses may legally overtake each other across ports/directions
        but stay FIFO within one ``(port, direction)``.
    cache_corrupt
        Number of compile-cache disk entries to corrupt immediately after a
        successful verified write (proves the delete+recompile path).
    cache_io_errors / ckpt_io_errors
        Budget of injected transient ``OSError`` s for compile-cache /
        checkpoint writes (each consumed failure is retried by the store).
    ckpt_truncate
        Step numbers whose published checkpoint directory gets one data file
        truncated after publish (proves the skip-incomplete-step path).
    poison
        ``{rid: "prefill" | "decode" | "any"}`` — serving requests whose
        compute step raises :class:`PoisonError` *before* the step function
        executes (so donated buffers stay valid); the scheduler quarantines
        the request.
    cancel
        ``{rid: n}`` — request ``rid`` is cancelled once it has generated
        ``n`` tokens.
    transient
        ``{site: count}`` — the first ``count`` calls through the serving
        retry wrapper at ``site`` ("prefill"/"decode") raise
        :class:`TransientFault` (recovered by retry-with-backoff).
    arrival_burst
        ``{tenant_name | "*": {"at_s": t, "dur_s": d, "rate": r}}`` —
        traffic-shape fault: matching tenants get *extra* Poisson
        arrivals at rate ``r`` inside the window ``[at_s, at_s + dur_s)``,
        overlaid onto the trace by :func:`repro.serve.traffic.make_trace`.
        Burst draws are keyed by this plan's seed, so traffic seed and
        fault seed vary independently.  A list of burst dicts per site is
        also accepted.
    tenant_flood
        ``{tenant_name: {"rate": r, "start_s": t, "dur_s": d, "weight",
        "priority", "prompt_len", "max_new", "deadline_s"}}`` — a whole
        extra flooding tenant injected into the trace (default priority 9,
        i.e. the lowest class: fair queuing should starve the flood, not
        the victims).
    """

    seed: int = 0
    chan_stall: Dict[str, dict] = field(default_factory=dict)
    task_raise: Dict[str, int] = field(default_factory=dict)
    crash: Dict[str, int] = field(default_factory=dict)
    mem_spike: Dict[str, dict] = field(default_factory=dict)
    cache_corrupt: int = 0
    cache_io_errors: int = 0
    ckpt_io_errors: int = 0
    ckpt_truncate: Tuple[int, ...] = ()
    poison: Dict[int, str] = field(default_factory=dict)
    cancel: Dict[int, int] = field(default_factory=dict)
    transient: Dict[str, int] = field(default_factory=dict)
    arrival_burst: Dict[str, dict] = field(default_factory=dict)
    tenant_flood: Dict[str, dict] = field(default_factory=dict)

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """Stateful consumer of a :class:`FaultPlan`: per-site counters + log.

    One injector should be attached to one run; reuse across runs would
    carry counters over and change which firings trip.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.log: list = []                     # replay record of fired faults
        self._chan_ops: Dict[tuple, int] = {}   # (chan, op) -> ops seen
        self._task_ops: Dict[str, int] = {}     # task -> ops seen
        self._mem_ops: Dict[tuple, int] = {}    # (port, dir) -> requests seen
        self._mem_last_due: Dict[tuple, int] = {}
        self._io_left = {"cache": plan.cache_io_errors,
                         "ckpt": plan.ckpt_io_errors}
        self._corrupt_left = plan.cache_corrupt
        self._transient_left = dict(plan.transient)
        self._truncated: set = set()
        self._cancel_fired: set = set()
        self._crash_fired: set = set()
        self._crash_points: Dict[str, int] = {}   # site -> boundaries seen

    # -- classification (lets engines skip consults entirely) ------------
    @property
    def affects_channels(self) -> bool:
        return (bool(self.plan.chan_stall) or bool(self.plan.task_raise)
                or any(site != "chunk" for site in self.plan.crash))

    @property
    def affects_memory(self) -> bool:
        return bool(self.plan.mem_spike)

    @property
    def affects_traffic(self) -> bool:
        return bool(self.plan.arrival_burst) or bool(self.plan.tenant_flood)

    def record(self, *event) -> None:
        self.log.append(event)

    # -- channel / task faults (engines' push/pop/burst paths) ------------
    @staticmethod
    def _site_target(table: Dict[str, int], task_name: str):
        """Plan lookup with bare-definition-name fallback (a key like
        ``"Relay"`` applies to every instance ``"Relay#k"``)."""
        target = table.get(task_name)
        if target is None and "#" in task_name:
            target = table.get(task_name.split("#", 1)[0])
        return target

    def chan_op(self, chan_name: str, op: str, task_name: str):
        """One task-side channel op.  Returns ``(stall, wake)`` tick delays;
        may raise :class:`InjectedFault` (``task_raise``) or
        :class:`CrashFault` (``crash``) at the task's chosen firing."""
        tr = self.plan.task_raise
        cr = self.plan.crash
        if tr or cr:
            # counters are per *instance* (task_name is unique, e.g.
            # "Relay#2"); plan keys may use the bare definition name,
            # which then applies to every instance of it
            n = self._task_ops.get(task_name, -1) + 1
            self._task_ops[task_name] = n
            if tr and self._site_target(tr, task_name) == n:
                self.record("task_raise", task_name, n)
                raise InjectedFault(
                    f"injected failure in task {task_name!r} at channel op {n}")
            if cr:
                # fired-ness is keyed by the *plan key* that matched, not
                # the instance name: restarts re-instantiate tasks with
                # fresh uids ("Relay#82" -> "Relay#96"), and a crash site
                # must fire exactly once per injector so the supervised
                # retry survives it
                key = task_name if task_name in cr else (
                    task_name.split("#", 1)[0] if "#" in task_name else None)
                if key is not None and key not in self._crash_fired and \
                        cr.get(key) == n:
                    self._crash_fired.add(key)
                    self.record("crash", task_name, n)
                    raise CrashFault(
                        f"injected crash in task {task_name!r} "
                        f"at channel op {n}")
        spec = (self.plan.chan_stall.get(chan_name)
                or self.plan.chan_stall.get("*"))
        if not spec:
            return 0, 0
        k = self._chan_ops.get((chan_name, op), 0)
        self._chan_ops[(chan_name, op)] = k + 1
        if _draw(self.plan.seed, "chan", chan_name, op, k) >= spec.get("p", 1.0):
            return 0, 0
        stall = int(spec.get("stall", 0))
        wake = int(spec.get("wake", 0))
        self.record("chan", chan_name, op, k, stall, wake)
        return stall, wake

    def crash_point(self, site: str = "chunk") -> None:
        """One non-channel crash site (e.g. a recovery chunk boundary).

        Consulted by the supervised chunk loop between chunks — this is
        how the compiled engine, whose execution is one opaque
        ``lax.while_loop``, gets crashed at a deterministic point.
        Raises :class:`CrashFault` at the site's n-th consultation (same
        0-based counting as channel-op sites); fires at most once per
        injector so the recovered attempt runs through.
        """
        target = self.plan.crash.get(site)
        if target is None:
            return
        n = self._crash_points.get(site, -1) + 1
        self._crash_points[site] = n
        if site not in self._crash_fired and target == n:
            self._crash_fired.add(site)
            self.record("crash", site, n)
            raise CrashFault(
                f"injected crash at {site!r} boundary {n}")

    # -- memory faults (AsyncMMap.pump) -----------------------------------
    def mem_delay(self, port: str, direction: str, base: int, clock: int) -> int:
        """Latency (ticks) for one accepted memory request.

        Clamped so due times within one ``(port, direction)`` are
        monotonically non-decreasing: the response FIFO order the runtime
        guarantees (and ``read_pipelined`` depends on) is preserved, while
        cross-port / cross-direction reordering emerges naturally.
        """
        spec = (self.plan.mem_spike.get(port)
                or self.plan.mem_spike.get("*"))
        extra = 0
        if spec:
            k = self._mem_ops.get((port, direction), 0)
            self._mem_ops[(port, direction)] = k + 1
            if _draw(self.plan.seed, "mem", port, direction, k) < spec.get("p", 1.0):
                extra = int(spec.get("extra", 0))
        due = clock + base + extra
        last = self._mem_last_due.get((port, direction), -1)
        if due < last:
            due = last
        self._mem_last_due[(port, direction)] = due
        if extra:
            self.record("mem", port, direction, extra)
        return due - clock

    # -- artifact faults (compile cache / checkpoints) ---------------------
    def io_error(self, kind: str) -> bool:
        """Consume one injected transient-IO failure for ``kind`` ("cache"
        or "ckpt"); the store raises ``OSError`` and retries."""
        left = self._io_left.get(kind, 0)
        if left <= 0:
            return False
        self._io_left[kind] = left - 1
        self.record("io_error", kind, left - 1)
        return True

    def corrupt_cache(self) -> bool:
        if self._corrupt_left <= 0:
            return False
        self._corrupt_left -= 1
        self.record("cache_corrupt", self._corrupt_left)
        return True

    def truncate_step(self, step: int) -> bool:
        if step not in self.plan.ckpt_truncate or step in self._truncated:
            return False
        self._truncated.add(step)
        self.record("ckpt_truncate", step)
        return True

    # -- serving faults ----------------------------------------------------
    def serving_check(self, site: str, rids) -> None:
        """Called by the serving retry wrapper *before* the step function
        runs.  Raises :class:`PoisonError` for a poisoned rid (donated
        buffers untouched) or :class:`TransientFault` while the site's
        transient budget lasts."""
        for rid in rids:
            phase = self.plan.poison.get(rid)
            if phase is not None and phase in ("any", site):
                self.record("poison", site, rid)
                raise PoisonError(rid, f"poisoned request {rid} at {site}")
        left = self._transient_left.get(site, 0)
        if left > 0:
            self._transient_left[site] = left - 1
            self.record("transient", site, left - 1)
            raise TransientFault(f"injected transient failure at {site}")

    # -- traffic faults (consumed by repro.serve.traffic.make_trace) -------
    def traffic_bursts(self, tenant: str) -> list:
        """Arrival-burst specs that apply to ``tenant`` (exact name or
        ``"*"``).  Each plan entry may be one dict or a list of dicts."""
        out = []
        for key in (tenant, "*"):
            spec = self.plan.arrival_burst.get(key)
            if spec is None:
                continue
            out.extend(spec if isinstance(spec, list) else [spec])
        return out

    def traffic_floods(self) -> Dict[str, dict]:
        return dict(self.plan.tenant_flood)

    def cancelled(self, rid: int, n_generated: int) -> bool:
        after = self.plan.cancel.get(rid)
        if after is None or n_generated < after:
            return False
        if rid not in self._cancel_fired:
            self._cancel_fired.add(rid)
            self.record("cancel", rid, n_generated)
        return True
