"""Channels and the TAPA communication interface (paper Section 3.1.2).

A :class:`Channel` is a bounded FIFO connecting exactly one producer task to
one consumer task.  The producer holds an :class:`OStream` view, the consumer
an :class:`IStream` view; together they expose the full interface of the
paper's Table 2:

    ostream:  full()  write()  try_write()  close()  try_close()
    istream:  empty() peek()  try_peek()   read()   try_read()
              eot()  try_eot()  open()  try_open()

End-of-transaction (EoT) tokens are out-of-band: they carry no data, occupy
one slot of channel capacity, and let a consumer terminate a pipelined loop
without extending the data type (paper Listing 2).

Blocking semantics are engine-mediated: a blocking operation calls
``runtime.wait(channel, side)`` which either waits (thread engine), performs
a cooperative hand-off (coroutine engine), or raises
:class:`~repro.core.errors.SequentialSimulationError` (sequential engine,
reproducing the paper's documented failure mode).  In the coroutine engine
exactly one task runs at a time, so the channel needs **no locking** there —
this is the paper's "collaborative instead of preemptive" insight showing up
as the absence of synchronization cost.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Generic, Optional, TypeVar

from .context import current_runtime
from .errors import ChannelMisuse, EndOfTransaction

T = TypeVar("T")

_uid = itertools.count()


class _EotType:
    """Singleton end-of-transaction token."""

    _instance: Optional["_EotType"] = None

    def __new__(cls) -> "_EotType":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<EoT>"


EOT = _EotType()

# Sides, used by engines to know which waiters to wake.
READABLE = "readable"
WRITABLE = "writable"


class Channel(Generic[T]):
    """Bounded FIFO channel (paper Section 3.1.1/3.1.3).

    ``capacity`` bounds the number of in-flight tokens exactly as in TAPA's
    ``tapa::channel<T, capacity>``; the simulator reserves enough state to
    honor it precisely (Section 3.2).
    """

    __slots__ = (
        "name", "capacity", "dtype", "_q", "uid",
        "producer", "consumer", "parent",
        "total_written", "total_read", "max_occupancy",
    )

    def __init__(self, capacity: int = 2, name: Optional[str] = None,
                 dtype: Any = None):
        if capacity < 1:
            raise ValueError("channel capacity must be >= 1")
        self.uid = next(_uid)
        self.name = name or f"ch{self.uid}"
        self.capacity = capacity
        self.dtype = dtype
        self._q: deque = deque()
        # Endpoint bookkeeping for graph metadata extraction (Section 3.4).
        self.producer = None   # task instance acting as producer
        self.consumer = None   # task instance acting as consumer
        self.parent = None     # parent task that instantiated this channel
        # Statistics (used by the simulator report and the PP scheduler).
        self.total_written = 0
        self.total_read = 0
        self.max_occupancy = 0

    # -- raw state ---------------------------------------------------------
    def is_empty(self) -> bool:
        return not self._q

    def is_full(self) -> bool:
        return len(self._q) >= self.capacity

    def size(self) -> int:
        return len(self._q)

    # -- endpoint registration (one producer + one consumer, Section 3.1.1)
    def _bind(self, side: str, task: Any) -> None:
        if task is None:
            return
        cur = getattr(self, side)
        if cur is None:
            setattr(self, side, task)
        elif cur is not task:
            raise ChannelMisuse(
                f"channel {self.name!r} already has a {side} "
                f"({cur!r}); cannot also bind {task!r}")

    # -- raw queue ops (no blocking; engines guarantee exclusivity or hold
    #    the engine lock around these) ------------------------------------
    def _push(self, tok: Any) -> None:
        self._q.append(tok)
        self.total_written += 1
        if len(self._q) > self.max_occupancy:
            self.max_occupancy = len(self._q)

    def _pop(self) -> Any:
        self.total_read += 1
        return self._q.popleft()

    def _head(self) -> Any:
        return self._q[0]

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Channel({self.name!r}, cap={self.capacity}, "
                f"size={len(self._q)})")


def _rt():
    rt = current_runtime()
    if rt is None:
        raise RuntimeError(
            "stream operation outside a running task; run the program via "
            "repro.run(...)/an engine, or use Channel._push/_pop directly")
    return rt


class IStream(Generic[T]):
    """Consumer-side view of a channel (paper Table 2)."""

    __slots__ = ("_chan",)

    def __init__(self, chan: Channel):
        self._chan = chan

    @property
    def channel(self) -> Channel:
        return self._chan

    # -- non-blocking state tests -----------------------------------------
    def empty(self) -> bool:
        return self._chan.is_empty()

    # -- blocking ops ------------------------------------------------------
    def read(self) -> T:
        """Blocking read of a data token.

        Reading an EoT token is a protocol error (EoT carries no data);
        use ``eot()``/``open()`` to handle transaction boundaries.
        """
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        if c._head() is EOT:
            # do not consume: the channel state is unchanged, so the caller
            # can recover with open()/eot() after handling the error
            raise EndOfTransaction(
                f"read() reached EoT on channel {c.name!r}")
        return rt.pop(c)

    def peek(self) -> T:
        """Blocking peek: return the head token without consuming it.

        The channel state is unchanged (paper Section 3.1.2)."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        tok = c._head()
        if tok is EOT:
            raise EndOfTransaction(
                f"peek() found EoT on channel {c.name!r}")
        return tok

    def eot(self) -> bool:
        """Blocking: wait for a token, return whether it is EoT (no consume)."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        return c._head() is EOT

    def open(self) -> None:
        """Blocking read of an EoT token ("open" the channel for the next
        transaction).  Errors if the head token carries data."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        tok = rt.pop(c)
        if tok is not EOT:
            raise ChannelMisuse(
                f"open() expected EoT on channel {c.name!r}, got data")

    # -- non-blocking ops --------------------------------------------------
    def try_read(self) -> tuple[bool, Optional[T]]:
        c = self._chan
        rt = _rt()
        if c.is_empty() or c._head() is EOT:
            return False, None
        return True, rt.pop(c)

    def try_peek(self) -> tuple[bool, Optional[T]]:
        c = self._chan
        if c.is_empty() or c._head() is EOT:
            return False, None
        return True, c._head()

    def try_eot(self) -> tuple[bool, bool]:
        """Returns (token_available, head_is_eot)."""
        c = self._chan
        if c.is_empty():
            return False, False
        return True, c._head() is EOT

    def try_open(self) -> bool:
        c = self._chan
        rt = _rt()
        if c.is_empty() or c._head() is not EOT:
            return False
        rt.pop(c)
        return True

    # -- iteration sugar: drain one transaction ----------------------------
    def __iter__(self):
        """Iterate over the tokens of one transaction, then consume its EoT.

        ``for x in stream: ...`` is the idiomatic replacement for the
        paper's Listing-2 loop ``while (!in.eot()) { v = in.read(); ... }``.
        """
        while not self.eot():
            yield self.read()
        self.open()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"IStream({self._chan.name!r})"


class OStream(Generic[T]):
    """Producer-side view of a channel (paper Table 2)."""

    __slots__ = ("_chan",)

    def __init__(self, chan: Channel):
        self._chan = chan

    @property
    def channel(self) -> Channel:
        return self._chan

    def full(self) -> bool:
        return self._chan.is_full()

    def write(self, v: T) -> None:
        """Blocking write of a data token."""
        if v is EOT:
            raise ChannelMisuse("use close() to send EoT")
        c = self._chan
        rt = _rt()
        while c.is_full():
            rt.wait(c, WRITABLE)
        rt.push(c, v)

    def close(self) -> None:
        """Blocking write of an EoT token ("close" the transaction)."""
        c = self._chan
        rt = _rt()
        while c.is_full():
            rt.wait(c, WRITABLE)
        rt.push(c, EOT)

    def try_write(self, v: T) -> bool:
        c = self._chan
        rt = _rt()
        if c.is_full():
            return False
        rt.push(c, v)
        return True

    def try_close(self) -> bool:
        c = self._chan
        rt = _rt()
        if c.is_full():
            return False
        rt.push(c, EOT)
        return True

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"OStream({self._chan.name!r})"


def channel(capacity: int = 2, name: Optional[str] = None,
            dtype: Any = None) -> Channel:
    """Instantiate a channel — ``tapa::channel<T, capacity>`` (Listing 5)."""
    return Channel(capacity=capacity, name=name, dtype=dtype)


def select(*streams) -> None:
    """Block until at least one stream can make progress.

    IStream arguments wait for a readable token (data *or* EoT); OStream
    arguments wait for writable space.  This is the multi-port polling
    primitive hardware switch elements have for free (combinational
    ready/valid over all ports) and that strict KPN forbids — the paper's
    "we are not limited to KPN" extension point (Section 2.2).  Without it,
    a cooperative simulator livelocks on availability-routed designs such
    as the Omega switch: a task that must watch two inputs and two outputs
    cannot commit to blocking on any single one.

    Returns immediately if any stream is already ready.
    """
    keys = []
    for s in streams:
        if isinstance(s, IStream):
            keys.append((s.channel, READABLE))
        elif isinstance(s, OStream):
            keys.append((s.channel, WRITABLE))
        else:   # AutoStream or raw channel: direction by bound view
            chan = getattr(s, "channel", s)
            view = getattr(s, "_view", None)
            side = WRITABLE if isinstance(view, OStream) else READABLE
            keys.append((chan, side))
    for chan, side in keys:
        ok = (not chan.is_empty()) if side == READABLE else \
            (not chan.is_full())
        if ok:
            return
    _rt().wait_many(keys)
