"""Channels and the TAPA communication interface (paper Section 3.1.2).

A :class:`Channel` is a bounded FIFO connecting exactly one producer task to
one consumer task.  The producer holds an :class:`OStream` view, the consumer
an :class:`IStream` view; together they expose the full interface of the
paper's Table 2:

    ostream:  full()  write()  try_write()  close()  try_close()
    istream:  empty() peek()  try_peek()   read()   try_read()
              eot()  try_eot()  open()  try_open()

plus the burst extension (hardware FIFOs amortize per-token handshake cost
with wide/burst transfers; we do the same in software):

    ostream:  write_burst(seq)   try_write_burst(seq)
    istream:  read_burst(n)  try_read_burst(n)  read_transaction()

End-of-transaction (EoT) tokens are out-of-band: they carry no data, occupy
one slot of channel capacity, and let a consumer terminate a pipelined loop
without extending the data type (paper Listing 2).

Blocking semantics are engine-mediated: a blocking operation calls
``runtime.wait(channel, side)`` which either waits (thread engine), performs
a cooperative hand-off (coroutine engine), or raises
:class:`~repro.core.errors.SequentialSimulationError` (sequential engine,
reproducing the paper's documented failure mode).

Run-to-block fast path: in the coroutine engine exactly one task runs at a
time, so channel state needs **no locking** — the paper's "collaborative
instead of preemptive" insight.  Engines advertise this via
``runtime.fast_path``; when set, an operation on a channel that can make
progress *and has no parked waiters on the opposite side* mutates the deque
directly and never enters the engine at all.  Only a genuine stall (or a
required wakeup) pays for runtime dispatch.

Chaos-harness contract: channel-level fault injection (repro.core.faults)
hooks the *engine-side* push/pop paths, never the channel itself, so this
file stays fault-free by construction.  Engines disable ``fast_path`` only
when an armed :class:`~repro.core.faults.FaultInjector` actually targets
channels or tasks (``affects_channels``); an empty/no-op plan keeps
``fast_path`` on, which is what makes the "zero overhead when no plan"
guarantee structural rather than measured.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Generic, Optional, Sequence, TypeVar

import numpy as np

from .context import current_runtime
from .errors import ChannelMisuse, EndOfTransaction

T = TypeVar("T")

_uid = itertools.count()


class _EotType:
    """Singleton end-of-transaction token."""

    _instance: Optional["_EotType"] = None

    def __new__(cls) -> "_EotType":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<EoT>"


EOT = _EotType()

# Sides, used by engines to know which waiters to wake.
READABLE = "readable"
WRITABLE = "writable"


def _norm_dtype(dtype: Any) -> Any:
    """Normalize a declared element dtype through numpy when possible;
    anything numpy cannot interpret is kept verbatim (documentation-only,
    never enforced)."""
    if dtype is None:
        return None
    try:
        return np.dtype(dtype)
    except TypeError:
        return dtype


class Channel(Generic[T]):
    """Bounded FIFO channel (paper Section 3.1.1/3.1.3).

    ``capacity`` bounds the number of in-flight tokens exactly as in TAPA's
    ``tapa::channel<T, capacity>``; the simulator reserves enough state to
    honor it precisely (Section 3.2).

    ``_rwait``/``_wwait`` are the per-channel waiter lists: fibers parked on
    this channel's readable/writable side.  Keeping them *on the channel*
    makes wakeup O(1) (no engine-global dict lookup) and lets the stream
    fast path test "does anybody need a wakeup?" with one truthiness check.
    The thread engine keeps its own condition variables and leaves these
    empty.
    """

    __slots__ = (
        "name", "capacity", "dtype", "shape", "_q", "uid",
        "producer", "consumer", "parent", "iface",
        "total_written", "total_read", "max_occupancy",
        "_rwait", "_wwait", "_eot_count",
    )

    def __init__(self, capacity: int = 2, name: Optional[str] = None,
                 dtype: Any = None, shape: Optional[tuple] = None):
        if not isinstance(capacity, int) or isinstance(capacity, bool) \
                or capacity < 1:
            raise ValueError("channel capacity must be a static int >= 1")
        self.uid = next(_uid)
        self.name = name or f"ch{self.uid}"
        self.capacity = capacity
        # element spec (paper: tapa::channel<T, capacity> — T is part of the
        # type).  ``dtype`` is normalized when numpy understands it; a
        # non-normalizable dtype stays as documentation only.  ``shape`` is
        # the per-token array shape (() for scalar tokens); synthesis
        # requires both, simulation enforces them under track_stats.
        self.dtype = _norm_dtype(dtype)
        self.shape = tuple(shape) if shape is not None else None
        # ``_q``/``_eot_count``/waiters are also the channel's *snapshot
        # surface*: ft/recovery.py capture_channel/restore_channel freeze
        # and rebuild exactly these between runs (never mid-run), so any
        # new mutable field here needs a matching capture.
        self._q: deque = deque()
        # Per-channel waiter lists (coroutine engine: (fiber, epoch) pairs).
        self._rwait: deque = deque()
        self._wwait: deque = deque()
        # Number of EoT tokens currently in the queue: lets a burst read
        # size itself in O(1) (no head scan) on the common all-data case.
        self._eot_count = 0
        # Endpoint bookkeeping for graph metadata extraction (Section 3.4).
        self.producer = None   # task instance acting as producer
        self.consumer = None   # task instance acting as consumer
        self.parent = None     # parent task that instantiated this channel
        self.iface = None      # owning interface (async_mmap port channels)
        # Statistics (opt-in: engines update these only under
        # ``track_stats=True``, at burst granularity; the default hot path
        # does no bookkeeping).
        self.total_written = 0
        self.total_read = 0
        self.max_occupancy = 0

    # -- element spec ------------------------------------------------------
    def has_spec(self) -> bool:
        """True when this channel declares an enforceable element spec."""
        return self.shape is not None or isinstance(self.dtype, np.dtype)

    def check_token(self, tok: Any, task: Any = None) -> None:
        """Validate one data token against the declared element spec.

        Engines call this under ``track_stats`` (the debug mode) on every
        push; the error names the channel and the pushing task so a typed
        graph fails at the *write* that broke the contract, not at some
        downstream consumer."""
        if tok is EOT:
            return
        who = f" (task {task.name!r})" if task is not None else ""
        if self.shape is not None:
            got = tuple(np.shape(tok))
            if got != self.shape:
                raise ChannelMisuse(
                    f"channel {self.name!r} declares element shape "
                    f"{self.shape}; got a token of shape {got}{who}")
        if isinstance(self.dtype, np.dtype):
            got_dt = getattr(tok, "dtype", None)
            if got_dt is not None:
                if np.dtype(got_dt) != self.dtype:
                    raise ChannelMisuse(
                        f"channel {self.name!r} declares element dtype "
                        f"{self.dtype}; got a token of dtype {got_dt}{who}")
            else:
                # Python scalars are checked by kind only (an int literal
                # on an int32 channel is fine); arbitrary objects on a
                # dtype-declared channel are not
                ok = isinstance(tok, (bool, int, float, complex)) and \
                    np.dtype(type(tok)).kind == self.dtype.kind
                if not ok:
                    raise ChannelMisuse(
                        f"channel {self.name!r} declares element dtype "
                        f"{self.dtype}; got a {type(tok).__name__} "
                        f"token{who}")

    # -- raw state ---------------------------------------------------------
    def is_empty(self) -> bool:
        return not self._q

    def is_full(self) -> bool:
        return len(self._q) >= self.capacity

    def size(self) -> int:
        return len(self._q)

    # -- endpoint registration (one producer + one consumer, Section 3.1.1)
    def _bind(self, side: str, task: Any) -> None:
        if task is None:
            return
        cur = getattr(self, side)
        if cur is None:
            setattr(self, side, task)
        elif cur is not task:
            raise ChannelMisuse(
                f"channel {self.name!r} already has a {side} "
                f"({cur!r}); cannot also bind {task!r}")

    # -- raw queue ops (no blocking, no stats; engines guarantee
    #    exclusivity or hold the engine lock around these) -----------------
    def _push(self, tok: Any) -> None:
        if tok is EOT:
            self._eot_count += 1
        self._q.append(tok)

    def _pop(self) -> Any:
        tok = self._q.popleft()
        if tok is EOT:
            self._eot_count -= 1
        return tok

    def _head(self) -> Any:
        return self._q[0]

    def _data_run(self, limit: int) -> int:
        """Length of the run of consecutive *data* tokens at the head,
        capped at ``limit`` — how many tokens a burst read may consume
        without crossing an EoT.  O(1) when no EoT is in flight."""
        q = self._q
        if not self._eot_count:
            n = len(q)
            return n if n < limit else limit
        k = 0
        for tok in q:
            if k >= limit or tok is EOT:
                break
            k += 1
        return k

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Channel({self.name!r}, cap={self.capacity}, "
                f"size={len(self._q)})")


def _rt():
    rt = current_runtime()
    if rt is None:
        raise RuntimeError(
            "stream operation outside a running task; run the program via "
            "repro.run(...)/an engine, or use Channel._push/_pop directly")
    return rt


class IStream(Generic[T]):
    """Consumer-side view of a channel (paper Table 2 + burst extension)."""

    __slots__ = ("_chan",)

    def __init__(self, chan: Channel):
        self._chan = chan

    @property
    def channel(self) -> Channel:
        return self._chan

    # -- non-blocking state tests -----------------------------------------
    def empty(self) -> bool:
        return self._chan.is_empty()

    # -- blocking ops ------------------------------------------------------
    def read(self) -> T:
        """Blocking read of a data token.

        Reading an EoT token is a protocol error (EoT carries no data);
        use ``eot()``/``open()`` to handle transaction boundaries.
        """
        c = self._chan
        rt = _rt()
        q = c._q
        if q and rt.fast_path and not c._wwait:
            # run-to-block fast path: token available, no parked writer to
            # wake — consume without entering the engine
            tok = q[0]
            if tok is EOT:
                raise EndOfTransaction(
                    f"read() reached EoT on channel {c.name!r}")
            return q.popleft()
        while c.is_empty():
            rt.wait(c, READABLE)
        if c._head() is EOT:
            # do not consume: the channel state is unchanged, so the caller
            # can recover with open()/eot() after handling the error
            raise EndOfTransaction(
                f"read() reached EoT on channel {c.name!r}")
        return rt.pop(c)

    def read_burst(self, n: int) -> list:
        """Blocking burst read: consume and return ``n`` data tokens.

        Equivalent to ``n`` scalar ``read()`` calls, except that an EoT
        terminates the burst instead of raising: tokens are consumed from
        the head in batches as they become available, and the burst stops
        early — *without* consuming the EoT — if the transaction ends
        first.  Returns a list of length ``n``, or shorter iff an EoT was
        reached (empty iff the head token already is EoT).

        One runtime interaction per batch, not per token: this is the
        software analogue of a hardware FIFO burst transfer.
        """
        if n < 0:
            raise ValueError("read_burst size must be >= 0")
        c = self._chan
        rt = _rt()
        q = c._q
        out: list = []
        while len(out) < n:
            if not q:
                rt.wait(c, READABLE)
                continue
            want = n - len(out)
            k = c._data_run(want) if rt.fast_path else rt.data_run(c, want)
            if k == 0:
                break                       # head is EoT: burst ends early
            if rt.fast_path and not c._wwait:
                if k == len(q):             # drain-all: one C-level copy
                    out.extend(q)
                    q.clear()
                else:
                    out.extend(q.popleft() for _ in range(k))
            else:
                out.extend(rt.pop_burst(c, k))
        return out

    def read_transaction(self) -> list:
        """Blocking read of one whole transaction: every data token up to
        the next EoT, *consuming* the EoT.  Equivalent to draining
        ``for v in stream`` into a list, at burst granularity."""
        c = self._chan
        n = max(c.capacity, 32)
        out: list = []
        while True:
            chunk = self.read_burst(n)
            out.extend(chunk)
            if len(chunk) < n:              # short burst <=> EoT at head
                self.open()
                return out

    def peek(self) -> T:
        """Blocking peek: return the head token without consuming it.

        The channel state is unchanged (paper Section 3.1.2)."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        tok = c._head()
        if tok is EOT:
            raise EndOfTransaction(
                f"peek() found EoT on channel {c.name!r}")
        return tok

    def eot(self) -> bool:
        """Blocking: wait for a token, return whether it is EoT (no consume)."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        return c._head() is EOT

    def open(self) -> None:
        """Blocking read of an EoT token ("open" the channel for the next
        transaction).  Errors if the head token carries data."""
        c = self._chan
        rt = _rt()
        while c.is_empty():
            rt.wait(c, READABLE)
        if rt.fast_path and not c._wwait:
            tok = c._pop()
        else:
            tok = rt.pop(c)
        if tok is not EOT:
            raise ChannelMisuse(
                f"open() expected EoT on channel {c.name!r}, got data")

    # -- non-blocking ops --------------------------------------------------
    def try_read(self) -> tuple[bool, Optional[T]]:
        c = self._chan
        rt = _rt()
        q = c._q
        if not q or q[0] is EOT:
            return False, None
        if rt.fast_path and not c._wwait:
            return True, q.popleft()
        return True, rt.pop(c)

    def try_read_burst(self, n: int) -> list:
        """Non-blocking burst read: consume and return the up-to-``n`` data
        tokens available right now (empty list when none, or when the head
        is EoT)."""
        if n < 0:
            raise ValueError("try_read_burst size must be >= 0")
        c = self._chan
        rt = _rt()
        k = c._data_run(n) if rt.fast_path else rt.data_run(c, n)
        if k == 0:
            return []
        q = c._q
        if rt.fast_path and not c._wwait:
            return [q.popleft() for _ in range(k)]
        return rt.pop_burst(c, k)

    def try_peek(self) -> tuple[bool, Optional[T]]:
        c = self._chan
        if c.is_empty() or c._head() is EOT:
            return False, None
        return True, c._head()

    def try_eot(self) -> tuple[bool, bool]:
        """Returns (token_available, head_is_eot)."""
        c = self._chan
        if c.is_empty():
            return False, False
        return True, c._head() is EOT

    def try_open(self) -> bool:
        c = self._chan
        rt = _rt()
        if c.is_empty() or c._head() is not EOT:
            return False
        if rt.fast_path and not c._wwait:
            c._pop()
        else:
            rt.pop(c)
        return True

    # -- iteration sugar: drain one transaction ----------------------------
    def __iter__(self):
        """Iterate over the tokens of one transaction, then consume its EoT.

        ``for x in stream: ...`` is the idiomatic replacement for the
        paper's Listing-2 loop ``while (!in.eot()) { v = in.read(); ... }``.
        """
        while not self.eot():
            yield self.read()
        self.open()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"IStream({self._chan.name!r})"


class OStream(Generic[T]):
    """Producer-side view of a channel (paper Table 2 + burst extension)."""

    __slots__ = ("_chan",)

    def __init__(self, chan: Channel):
        self._chan = chan

    @property
    def channel(self) -> Channel:
        return self._chan

    def full(self) -> bool:
        return self._chan.is_full()

    def write(self, v: T) -> None:
        """Blocking write of a data token."""
        if v is EOT:
            raise ChannelMisuse("use close() to send EoT")
        c = self._chan
        rt = _rt()
        q = c._q
        if rt.fast_path and len(q) < c.capacity and not c._rwait:
            # run-to-block fast path: space available, no parked reader to
            # wake — enqueue without entering the engine
            q.append(v)
            return
        while c.is_full():
            rt.wait(c, WRITABLE)
        rt.push(c, v)

    def write_burst(self, seq: Sequence[T]) -> None:
        """Blocking burst write of every token in ``seq``, in order.

        Equivalent to scalar ``write()`` per token, but tokens move in
        capacity-sized batches (``deque.extend``) and the runtime is
        entered once per batch — or not at all when the channel has room
        and no parked reader.  Capacity is still honored exactly: a batch
        never exceeds the free slots, and the call blocks between batches
        when the channel is full.
        """
        toks = list(seq)
        for v in toks:
            if v is EOT:
                raise ChannelMisuse("use close() to send EoT")
        c = self._chan
        rt = _rt()
        q = c._q
        i, n = 0, len(toks)
        while i < n:
            room = c.capacity - len(q)
            if room <= 0:
                rt.wait(c, WRITABLE)
                continue
            j = min(i + room, n)
            if rt.fast_path and not c._rwait:
                q.extend(toks[i:j])
            else:
                rt.push_burst(c, toks[i:j])
            i = j

    def close(self) -> None:
        """Blocking write of an EoT token ("close" the transaction)."""
        c = self._chan
        rt = _rt()
        if rt.fast_path and len(c._q) < c.capacity and not c._rwait:
            c._push(EOT)
            return
        while c.is_full():
            rt.wait(c, WRITABLE)
        rt.push(c, EOT)

    def try_write(self, v: T) -> bool:
        if v is EOT:
            raise ChannelMisuse("use close() to send EoT")
        c = self._chan
        rt = _rt()
        if c.is_full():
            return False
        if rt.fast_path and not c._rwait:
            c._q.append(v)
            return True
        rt.push(c, v)
        return True

    def try_write_burst(self, seq: Sequence[T]) -> int:
        """Non-blocking burst write: enqueue as many leading tokens of
        ``seq`` as fit right now; returns the number written."""
        toks = list(seq)
        for v in toks:
            if v is EOT:
                raise ChannelMisuse("use close() to send EoT")
        c = self._chan
        rt = _rt()
        k = min(c.capacity - len(c._q), len(toks))
        if k <= 0:
            return 0
        if rt.fast_path and not c._rwait:
            c._q.extend(toks[:k])
        else:
            rt.push_burst(c, toks[:k])
        return k

    def try_close(self) -> bool:
        c = self._chan
        rt = _rt()
        if c.is_full():
            return False
        if rt.fast_path and not c._rwait:
            c._push(EOT)
            return True
        rt.push(c, EOT)
        return True

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"OStream({self._chan.name!r})"


def channel(capacity: int = 2, name: Optional[str] = None,
            dtype: Any = None, shape: Optional[tuple] = None) -> Channel:
    """Instantiate a channel — ``tapa::channel<T, capacity>`` (Listing 5).

    ``dtype``/``shape`` declare the element spec (the ``T``): engines
    enforce it on every push under ``track_stats``, and synthesis
    (:mod:`repro.core.synth`) requires it to size the on-device ring
    buffer."""
    return Channel(capacity=capacity, name=name, dtype=dtype, shape=shape)


def select(*streams) -> None:
    """Block until at least one stream can make progress.

    IStream arguments wait for a readable token (data *or* EoT); OStream
    arguments wait for writable space.  This is the multi-port polling
    primitive hardware switch elements have for free (combinational
    ready/valid over all ports) and that strict KPN forbids — the paper's
    "we are not limited to KPN" extension point (Section 2.2).  Without it,
    a cooperative simulator livelocks on availability-routed designs such
    as the Omega switch: a task that must watch two inputs and two outputs
    cannot commit to blocking on any single one.

    Returns immediately if any stream is already ready.
    """
    keys = []
    for s in streams:
        if isinstance(s, IStream):
            keys.append((s.channel, READABLE))
        elif isinstance(s, OStream):
            keys.append((s.channel, WRITABLE))
        else:   # AutoStream or raw channel: direction by bound view
            chan = getattr(s, "channel", s)
            view = getattr(s, "_view", None)
            side = WRITABLE if isinstance(view, OStream) else READABLE
            keys.append((chan, side))
    for chan, side in keys:
        ok = (not chan.is_empty()) if side == READABLE else \
            (not chan.is_full())
        if ok:
            return
    _rt().wait_many(keys)
