"""Simulation engines (paper Section 3.2).

Three interchangeable engines run the same task bodies:

* :class:`SequentialEngine` — the Vivado-HLS-dataflow baseline: each task
  runs to completion at its invocation point.  Fast, but (a) channel
  capacity is not honored (writes never block — violations are *recorded*)
  and (b) a blocking read from a channel whose producer has not run yet
  fails.  This reproduces the paper's finding that sequential simulators
  cannot simulate feedback loops (cannon, page_rank).

* :class:`ThreadEngine` — the multi-thread baseline: one preemptive OS
  thread per task instance, condition-variable blocking.  Correct, but pays
  lock contention and OS/GIL context switches on every token.

* :class:`CoroutineEngine` — the paper's contribution: collaborative
  scheduling.  Exactly one task runs at a time; a task runs until *no
  progress can be made* (a channel op blocks), then control is handed to
  the next ready task (run-to-block).  Channel data structures need **no
  locking**, scheduling is deterministic (FIFO ready queue), and switches
  happen only at genuine dataflow stalls instead of at arbitrary
  preemption points.

All engines implement the runtime protocol used by streams::

    wait(chan, side)        block current task until side may be satisfiable
    push(chan, tok)         enqueue + wake readers
    pop(chan)               dequeue + wake writers
    push_burst(chan, toks)  enqueue a batch + one reader wake
    pop_burst(chan, n)      dequeue a batch + one writer wake
    spawn(inst)             launch a child task instance
    join(insts)             wait for non-detached children

plus two attributes streams read on the hot path:

    fast_path    True iff a stream op on a channel that can make progress
                 (and has no parked opposite-side waiter) may mutate the
                 deque directly, skipping engine dispatch entirely.  Safe
                 exactly when at most one task mutates channels at a time:
                 coroutine (one fiber runs) and sequential (one thread).
                 The thread engine must keep its lock, so never.
    track_stats  opt-in per-channel statistics (``total_written``/
                 ``total_read``/``max_occupancy``), aggregated at burst
                 granularity.  Enabling it disables ``fast_path`` so every
                 token is observed; the default leaves the hot path free of
                 bookkeeping.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .channel import Channel, READABLE, WRITABLE
from .context import clear_context, current_task, set_context
from .errors import (CrashFault, Deadlock, DeadlockError, DeadlockReport,
                     InjectedFault, SequentialSimulationError, TaskKilled)
from .faults import FaultInjector, FaultPlan
from .interface import AsyncMMap, MMap
from .task import (TaskInstance, bind_streams, builder_stack_depth,
                   join_pending_builders)


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclass
class SimReport:
    """Outcome of one simulation run (consumed by benchmarks/sim_time.py).

    ``tokens`` and the per-channel tuples are only populated when the
    engine ran with ``track_stats=True``; the default run reports zeros.
    """
    engine: str
    ok: bool
    wall_s: float
    switches: int
    n_instances: int
    n_channels: int
    tokens: int
    capacity_violations: int = 0
    async_violations: int = 0   # sequential engine: sync-delivered requests
    error: Optional[str] = None
    instances: list = field(default_factory=list)
    channels: list = field(default_factory=list)
    # (name, kind, stats dict) per mmap/async_mmap interface; async_mmap
    # request counters (incl. max_outstanding_*) are always recorded, MMap
    # load/store counters only under track_stats
    interfaces: list = field(default_factory=list)
    result: Any = None      # return value of the top-level task body
    # structured no-progress diagnostic (DeadlockReport), populated whenever
    # the run failed with a deadlock / stall / watchdog trip; the legacy
    # ``error`` string is preserved unchanged for existing consumers
    deadlock: Any = None
    # the exception object behind a task-failure ``error`` string, when the
    # engine still holds it; lets supervisors (repro.ft.recovery) classify
    # failures — e.g. CrashFault vs. a genuine bug — without string matching
    failure: Optional[BaseException] = None

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        s = "ok" if self.ok else f"FAILED({self.error})"
        return (f"<SimReport {self.engine} {s} wall={self.wall_s*1e3:.2f}ms "
                f"switches={self.switches} insts={self.n_instances} "
                f"tokens={self.tokens}>")


def _find_channels(obj: Any, acc: set,
                   ifaces: Optional[set] = None) -> None:
    if isinstance(obj, Channel):
        acc.add(obj)
    elif isinstance(obj, AsyncMMap):
        if ifaces is not None:
            ifaces.add(obj)
        acc.update(obj.channels())
    elif isinstance(obj, MMap):
        if ifaces is not None:
            ifaces.add(obj)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _find_channels(v, acc, ifaces)
    elif isinstance(obj, dict):
        for v in obj.values():
            _find_channels(v, acc, ifaces)


class EngineBase:
    name = "base"

    def __init__(self, track_stats: bool = False,
                 faults: Optional[Any] = None,
                 watchdog_s: Optional[float] = None,
                 max_ticks: Optional[int] = None):
        self.instances: list[TaskInstance] = []
        self.channel_set: set[Channel] = set()
        self.interface_set: set = set()          # MMap/AsyncMMap objects
        self._ports: list[AsyncMMap] = []        # async ports needing pump
        self.switches = 0
        self.capacity_violations = 0
        self.async_violations = 0
        self.track_stats = track_stats
        self.fast_path = False
        # chaos harness (repro.core.faults): accept a plan or an injector.
        # ``_chan_faults`` is non-None only when the plan actually targets
        # channel ops / task bodies, so the hot paths stay one `is None`
        # test (and subclasses keep fast_path) with a no-op injector.
        if isinstance(faults, FaultPlan):
            faults = faults.injector()
        self.faults: Optional[FaultInjector] = faults
        self._chan_faults = faults if (faults is not None and
                                       faults.affects_channels) else None
        # unified watchdog: wall-clock budget and/or logical-clock budget;
        # a trip raises DeadlockError with the same DeadlockReport payload
        # a genuine deadlock produces
        self.watchdog_s = watchdog_s
        self.max_ticks = max_ticks
        self._t0: Optional[float] = None
        self._deadlock_report: Optional[DeadlockReport] = None
        # async-response machinery (paper Table 2's async_mmap): a heap of
        # (due_tick, seq, deliver_fn) events over a logical clock that
        # advances with scheduling activity and fast-forwards when every
        # task is stalled waiting on memory
        self.clock = 0
        self._events: list = []
        self._event_seq = itertools.count()
        # sequential engine: responses must be delivered synchronously even
        # into a full response channel (it cannot wait) — the recorded
        # violation, mirroring its channel-capacity growth
        self.force_async = False
        # annotation-driven auto-wrap registry: one MMap wrapper per raw
        # buffer per run, so two tasks annotated `m: MMap` receiving the
        # same ndarray share a wrapper (one-writer enforceable) and the
        # wrapper shows up in interface_set like an explicit mmap
        self._adopted: dict[int, Any] = {}
        self._adopt_lock = threading.Lock()

    # -- runtime protocol (overridden) --------------------------------------
    def wait(self, chan: Channel, side: str) -> None:
        raise NotImplementedError

    def wait_many(self, keys: list) -> None:
        """Block until any (chan, side) in keys may be satisfiable —
        the engine-side primitive behind ``repro.select`` (multi-port
        polling, Section 2.2's KPN extension)."""
        raise NotImplementedError

    def push(self, chan: Channel, tok: Any) -> None:
        raise NotImplementedError

    def pop(self, chan: Channel) -> Any:
        raise NotImplementedError

    def push_burst(self, chan: Channel, toks: list) -> None:
        raise NotImplementedError

    def pop_burst(self, chan: Channel, n: int) -> list:
        raise NotImplementedError

    def data_run(self, chan: Channel, limit: int) -> int:
        """How many head tokens a burst read may consume (see
        Channel._data_run).  Single-task engines read channel state
        directly; the thread engine overrides this to hold its lock, since
        the EoT-present path iterates the deque and a concurrent producer
        append would raise 'deque mutated during iteration'."""
        return chan._data_run(limit)

    def spawn(self, inst: TaskInstance) -> None:
        raise NotImplementedError

    def join(self, insts: list[TaskInstance]) -> None:
        raise NotImplementedError

    # -- async interface protocol (used by repro.core.interface) -------------
    def schedule_async(self, delay: int, deliver: Callable) -> None:
        """Schedule ``deliver(engine)`` at ``clock + delay`` — the
        response half of an accepted async_mmap request.  ``deliver``
        returns False to be retried (response channel momentarily full)."""
        heapq.heappush(self._events,
                       (self.clock + delay, next(self._event_seq), deliver))

    def iface_pump(self, iface: AsyncMMap) -> None:
        """Offer queued requests to the memory model.  The thread engine
        overrides this to hold its lock; single-task engines go direct."""
        iface.pump(self)

    def adopt_mmap(self, data: Any, name: str) -> MMap:
        """Return this run's MMap wrapper for a raw buffer passed to an
        ``MMap``-annotated parameter, creating and registering it on first
        sight (keyed by buffer identity, which the registry entry pins)."""
        with self._adopt_lock:
            m = self._adopted.get(id(data))
            if m is None:
                m = MMap(data, name=name)
                self._adopted[id(data)] = m
                self.interface_set.add(m)
            return m

    def _iface_deliver(self, chan: Channel, tok: Any) -> None:
        """Memory-side push of a response token + reader wakeup."""
        raise NotImplementedError

    def _iface_pop(self, chan: Channel) -> Any:
        """Memory-side pop of an accepted request token + writer wakeup."""
        raise NotImplementedError

    def _deliver_due(self) -> int:
        """Run every event due at the current clock; returns how many
        actually delivered.  Deferred deliveries (full response channel)
        are requeued one tick ahead so a later pass retries them."""
        delivered = 0
        requeue = []
        while self._events and self._events[0][0] <= self.clock:
            _, _, fn = heapq.heappop(self._events)
            if fn(self):
                delivered += 1
            else:
                requeue.append((self.clock + 1, next(self._event_seq), fn))
        for ev in requeue:
            heapq.heappush(self._events, ev)
        return delivered

    def _fast_forward(self) -> bool:
        """No task can run: advance the clock through pending responses,
        in due order, until one delivers.  A deferred delivery (full
        response FIFO on a flooded port) must not mask a later-due event
        on a *different* port, so every event pending at entry gets one
        attempt.  Returns False only when none delivered — a genuine
        deadlock."""
        budget = len(self._events)      # each entry event tried at most once
        requeue = []
        delivered = False
        while self._events and budget > 0 and not delivered:
            due, _, fn = heapq.heappop(self._events)
            budget -= 1
            if due > self.clock:
                self.clock = due
            if fn(self):
                delivered = True
            else:
                requeue.append((self.clock + 1, next(self._event_seq), fn))
        for ev in requeue:
            heapq.heappush(self._events, ev)
        return delivered

    # -- shared helpers ------------------------------------------------------
    def _blocked_sites(self) -> list:
        return [(i.name, i.wait_site or "?") for i in self.instances
                if i.state == "blocked" and not i.detach]

    def _make_deadlock(self, reason: str,
                       blocked: Optional[list] = None) -> DeadlockReport:
        """Build (and remember) the structured no-progress report; the
        engine's failure path attaches it to ``SimReport.deadlock``."""
        occ = {c.name: c.size()
               for c in sorted(self.channel_set, key=lambda c: c.uid)}
        rep = DeadlockReport(
            engine=self.name, reason=reason,
            blocked=blocked if blocked is not None else self._blocked_sites(),
            occupancy=occ, clock=self.clock, switches=self.switches,
            wall_s=(time.perf_counter() - self._t0) if self._t0 else 0.0)
        self._deadlock_report = rep
        return rep

    def _watchdog_reason(self) -> Optional[str]:
        if self.max_ticks is not None and self.clock > self.max_ticks:
            return "tick-budget"
        if self.watchdog_s is not None and self._t0 is not None and \
                time.perf_counter() - self._t0 > self.watchdog_s:
            return "watchdog"
        return None

    def _stat_push(self, chan: Channel, k: int) -> None:
        """Burst-granular write statistics (one update per batch)."""
        chan.total_written += k
        occ = len(chan._q)
        if occ > chan.max_occupancy:
            chan.max_occupancy = occ

    def _check_spec(self, chan: Channel, toks) -> None:
        """Element-spec enforcement (``Channel(dtype=..., shape=...)``).

        Called by the engines' push paths under ``track_stats`` — the same
        opt-in that disables the fast path, so every token is observed.
        The error names the channel and the pushing task."""
        if chan.has_spec():
            inst = current_task()
            for t in toks:
                chan.check_token(t, inst)

    def _register(self, inst: TaskInstance) -> None:
        self.instances.append(inst)
        found_if: set = set()
        _find_channels(inst.args, self.channel_set, found_if)
        _find_channels(inst.kwargs, self.channel_set, found_if)
        for it in found_if:
            if it in self.interface_set:
                continue
            # first sighting under THIS engine: clear run-scoped binding
            # state so a host-created interface re-simulates cleanly
            it._reset_run()
            self.interface_set.add(it)
            if isinstance(it, AsyncMMap):
                self._ports.append(it)

    def _report(self, ok: bool, wall: float, err: Optional[str],
                result: Any = None,
                failure: Optional[BaseException] = None) -> SimReport:
        chans = sorted(self.channel_set, key=lambda c: c.uid)
        ifaces = sorted(self.interface_set, key=lambda i: i.uid)
        return SimReport(
            engine=self.name, ok=ok, wall_s=wall, switches=self.switches,
            n_instances=len(self.instances), n_channels=len(chans),
            tokens=sum(c.total_written for c in chans),
            capacity_violations=self.capacity_violations,
            async_violations=self.async_violations,
            error=err,
            instances=[(i.name, i.state) for i in self.instances],
            channels=[(c.name, c.total_written, c.max_occupancy)
                      for c in chans],
            interfaces=[(i.name, i.iface_kind, i.stats()) for i in ifaces],
            result=result,
            deadlock=self._deadlock_report,
            failure=failure,
        )

    def run(self, top: Callable, *args, **kwargs) -> SimReport:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# sequential engine (Vivado-HLS dataflow baseline)
# ---------------------------------------------------------------------------

class SequentialEngine(EngineBase):
    """Run each task to completion at its invocation point (paper S3.2)."""

    name = "sequential"

    def __init__(self, track_stats: bool = False, **kw):
        super().__init__(track_stats, **kw)
        # single thread, exclusive by construction: direct deque ops are
        # safe whenever stats don't need to observe every token; channel
        # faults need every op observed too
        self.fast_path = not track_stats and self._chan_faults is None
        self.force_async = True
        self._cur: Optional[TaskInstance] = None

    # async interfaces: a task runs to completion at its invocation point,
    # so a response can never be overlapped with other work — deliver it
    # synchronously at accept time and *record* the violation (the same
    # documented degradation as growing channel capacity above)
    def schedule_async(self, delay: int, deliver: Callable) -> None:
        self.async_violations += 1
        deliver(self)

    def _iface_deliver(self, chan: Channel, tok: Any) -> None:
        chan._push(tok)
        if self.track_stats:
            self._stat_push(chan, 1)

    def _iface_pop(self, chan: Channel) -> Any:
        if self.track_stats:
            chan.total_read += 1
        return chan._pop()

    # blocking ops ----------------------------------------------------------
    def wait(self, chan: Channel, side: str) -> None:
        self.clock += 1
        if self.watchdog_s is not None or self.max_ticks is not None:
            reason = self._watchdog_reason()
            if reason is not None:
                site = ("write " if side == WRITABLE else "read ") + chan.name
                name = self._cur.name if self._cur else "?"
                raise DeadlockError(
                    self._make_deadlock(reason, blocked=[(name, site)]))
        if side is WRITABLE or side == WRITABLE:
            # Sequential simulation cannot honor capacity: grow the channel
            # and record the violation (paper: "cannot correctly simulate
            # the capacity of channels").
            self.capacity_violations += 1
            chan.capacity = chan.size() + 1
            return
        # Blocking read from an empty channel: the producer either already
        # finished (true starvation) or is invoked later (the feedback /
        # invocation-order failure the paper documents).
        inst = self._cur
        if inst is not None and inst.detach:
            raise TaskKilled()
        name = inst.name if inst else "?"
        self._make_deadlock("sequential-read",
                            blocked=[(name, f"read {chan.name}")])
        raise SequentialSimulationError(
            f"sequential simulation cannot make progress: "
            f"{name} blocked reading "
            f"{chan.name!r} (feedback loop or invocation-order dependence)")

    def wait_many(self, keys: list) -> None:
        # Sequential execution cannot poll: nothing can change while this
        # task holds the (only) thread.  A writable side can be "satisfied"
        # by growing the channel (the capacity-violation fallback); a pure
        # read-wait is the documented failure mode.
        for chan, side in keys:
            if side == WRITABLE:
                return self.wait(chan, side)
        return self.wait(keys[0][0], keys[0][1])

    def _fault_op(self, chan: Channel, op: str) -> None:
        """Consult the chaos harness for one task-side op.  A stall is a
        pure logical-clock advance here (nothing to overlap with); wake
        delays are meaningless (no waiters ever park), but the consult
        keeps the per-site counters — and hence the injected *decisions* —
        identical to the concurrent engines."""
        stall, _ = self._chan_faults.chan_op(
            chan.name, op, self._cur.name if self._cur else "?")
        if stall:
            self.clock += stall

    def push(self, chan: Channel, tok: Any) -> None:
        if self._chan_faults is not None:
            self._fault_op(chan, "push")
        if self.track_stats:
            self._check_spec(chan, (tok,))
        chan._push(tok)
        if self.track_stats:
            self._stat_push(chan, 1)

    def pop(self, chan: Channel) -> Any:
        if self._chan_faults is not None:
            self._fault_op(chan, "pop")
        if self.track_stats:
            chan.total_read += 1
        return chan._pop()

    def push_burst(self, chan: Channel, toks: list) -> None:
        if self._chan_faults is not None:
            self._fault_op(chan, "push_burst")
        if self.track_stats:
            self._check_spec(chan, toks)
        chan._q.extend(toks)
        if self.track_stats:
            self._stat_push(chan, len(toks))

    def pop_burst(self, chan: Channel, n: int) -> list:
        if self._chan_faults is not None:
            self._fault_op(chan, "pop_burst")
        q = chan._q
        if self.track_stats:
            chan.total_read += n
        if n == len(q):
            out = list(q)
            q.clear()
            return out
        return [q.popleft() for _ in range(n)]

    # task management --------------------------------------------------------
    def spawn(self, inst: TaskInstance) -> None:
        self._register(inst)
        self._exec(inst)

    def join(self, insts: list[TaskInstance]) -> None:
        # children already ran to completion at spawn
        for i in insts:
            if i.state == "failed" and i.error is not None:
                raise i.error

    def _exec(self, inst: TaskInstance) -> Any:
        prev = self._cur
        self._cur = inst
        set_context(self, inst)
        self.switches += 1
        depth = builder_stack_depth()
        inst.state = "running"
        out = None
        try:
            a, k = bind_streams(inst)
            out = inst.fn(*a, **k)
            join_pending_builders(depth)
            inst.state = "finished"
        except TaskKilled:
            inst.state = "finished"   # detached task ran out of input
        except BaseException as e:
            inst.state = "failed"
            inst.error = e
            raise
        finally:
            self._cur = prev
            set_context(self, prev)
        return out

    def run(self, top: Callable, *args, **kwargs) -> SimReport:
        t0 = time.perf_counter()
        self._t0 = t0
        root = TaskInstance(top, args, kwargs, detach=False, parent=None,
                            name=getattr(top, "__name__", "top"))
        self._register(root)
        try:
            result = self._exec(root)
            return self._report(True, time.perf_counter() - t0, None, result)
        except (SequentialSimulationError, DeadlockError) as e:
            return self._report(False, time.perf_counter() - t0, str(e))
        except (InjectedFault, CrashFault) as e:
            # parity with the concurrent engines' task-failure reporting
            return self._report(False, time.perf_counter() - t0,
                                f"task error: {e!r}", failure=e)
        finally:
            clear_context()


# ---------------------------------------------------------------------------
# preemptive thread engine (multi-thread baseline)
# ---------------------------------------------------------------------------

class ThreadEngine(EngineBase):
    """One OS thread per task instance; preemptive scheduling (paper S3.2).

    ``fast_path`` stays False: preemption means two tasks can touch a
    channel concurrently, so every op must hold the engine lock — exactly
    the per-token synchronization cost the coroutine engine avoids.
    """

    name = "thread"

    def __init__(self, track_stats: bool = False, **kw):
        super().__init__(track_stats, **kw)
        # re-entrant: async_mmap request acceptance (iface_pump) nests
        # schedule_async/_iface_pop under the same lock
        self._lock = threading.RLock()
        self._conds: dict[tuple[int, str], threading.Condition] = {}
        self._finish_cond = threading.Condition(self._lock)
        self._threads: dict[int, threading.Thread] = {}
        self._started = 0          # threads whose body began executing
        self._blocked = 0          # tasks currently inside a wait
        self._chan_waiters: dict[tuple[int, str], Channel] = {}
        self._multi_waiters: dict[int, list] = {}     # uid -> [(chan, side)]
        self._any_cond = threading.Condition(self._lock)
        self._join_waiters: dict[int, list[TaskInstance]] = {}
        self._deadlocked = False
        self._stopping = False
        self._failure: Optional[BaseException] = None

    def _cond(self, chan: Channel, side: str) -> threading.Condition:
        key = (chan.uid, side)
        c = self._conds.get(key)
        if c is None:
            c = self._conds[key] = threading.Condition(self._lock)
        return c

    @staticmethod
    def _satisfied(chan: Channel, side: str) -> bool:
        return (not chan.is_empty()) if side == READABLE else \
               (not chan.is_full())

    def _live_unfinished(self) -> int:
        return sum(1 for i in self.instances
                   if i.state in ("running", "blocked"))

    def _any_nondetached_unfinished(self) -> bool:
        return any(i.state not in ("finished", "failed")
                   for i in self.instances if not i.detach)

    def _no_progress_possible(self) -> bool:
        """True iff every blocked task waits on an unsatisfiable condition.

        A task that was *notified* but has not yet re-acquired the lock is
        still counted blocked; checking condition satisfiability instead of
        raw counts avoids declaring a false deadlock in that window.
        """
        for (uid, side), chan in self._chan_waiters.items():
            if self._satisfied(chan, side):
                return False
        for keys in self._multi_waiters.values():
            if any(self._satisfied(c, s) for c, s in keys):
                return False
        for _, kids in self._join_waiters.items():
            if all(k.state in ("finished", "failed") for k in kids):
                return False
        return True

    def _maybe_end(self) -> None:
        """Called with the lock held whenever a task becomes blocked."""
        if self._blocked < self._live_unfinished() or \
                self._started < len(self.instances):
            return
        while self._no_progress_possible():
            # every task stalled: pending async memory responses are the
            # one legitimate way forward — fast-forward the clock and
            # deliver, repeating until some waiter becomes satisfiable
            # (the notifies wake it) or the event heap runs dry
            if self._events and self._fast_forward():
                continue
            if self._any_nondetached_unfinished():
                self._trigger_deadlock()
            else:
                self._trigger_stop()
            return

    # -- async interface protocol (lock-holding variants) --------------------
    def iface_pump(self, iface: AsyncMMap) -> None:
        with self._lock:
            iface.pump(self)

    def schedule_async(self, delay: int, deliver: Callable) -> None:
        with self._lock:
            super().schedule_async(delay, deliver)

    # lock already held on these paths (pump or _deliver_due); the RLock is
    # re-acquired re-entrantly.  These are memory-side ops, deliberately
    # not routed through push/pop: the chaos harness only perturbs
    # *task-side* channel ops (memory misbehaviour is mem_delay's job), and
    # a fault stall inside a lock-holding pump would block the whole run.
    def _iface_deliver(self, chan: Channel, tok: Any) -> None:
        with self._lock:
            chan._push(tok)
            if self.track_stats:
                self._stat_push(chan, 1)
            self._cond(chan, READABLE).notify()
            if self._multi_waiters:
                self._any_cond.notify_all()

    def _iface_pop(self, chan: Channel) -> Any:
        with self._lock:
            tok = chan._pop()
            if self.track_stats:
                chan.total_read += 1
            self._cond(chan, WRITABLE).notify()
            if self._multi_waiters:
                self._any_cond.notify_all()
            return tok

    # -- chaos-harness plumbing ---------------------------------------------
    def _fault_consult(self, chan: Channel, op: str):
        """Called outside the lock.  A stall becomes a real sleep (the
        preemptive analogue of yielding the processor) plus a logical-clock
        advance; the returned wake delay is applied by the caller."""
        inst = getattr(_thread_inst, "inst", None)
        stall, wake = self._chan_faults.chan_op(
            chan.name, op, inst.name if inst is not None else "?")
        if stall:
            time.sleep(stall * 1e-4)
            with self._lock:
                self.clock += stall
        return wake

    def _delayed_wake(self, chan: Channel, side: str):
        """Deliver fn for a fault-delayed wake-up: the token is already in
        the queue, only the notify travels through the event heap."""
        def deliver(eng, c=chan, s=side):
            eng._cond(c, s).notify()
            if eng._multi_waiters:
                eng._any_cond.notify_all()
            return True
        return deliver

    def wait(self, chan: Channel, side: str) -> None:
        cond = self._cond(chan, side)
        key = (chan.uid, side)
        with self._lock:
            self._check_abort()
            self.clock += 1
            if self.max_ticks is not None and not self._deadlocked and \
                    self._watchdog_reason() is not None:
                self._trigger_watchdog(self._watchdog_reason())
                self._check_abort()
            if self._events:
                self._deliver_due()
            if self._satisfied(chan, side):
                return                      # lost-wakeup guard
            inst = _thread_inst.inst
            inst.state = "blocked"
            inst.wait_site = \
                ("write " if side == WRITABLE else "read ") + chan.name
            self._blocked += 1
            self._chan_waiters[key] = chan
            try:
                self._maybe_end()
                self._check_abort()
                if self._satisfied(chan, side):
                    return      # _maybe_end's fast-forward delivered here
                self.switches += 1
                cond.wait()
                self._check_abort()
            finally:
                self._blocked -= 1
                self._chan_waiters.pop(key, None)
                if inst.state == "blocked":
                    inst.state = "running"

    def wait_many(self, keys: list) -> None:
        with self._lock:
            self._check_abort()
            self.clock += 1
            if self._events:
                self._deliver_due()
            if any(self._satisfied(c, s) for c, s in keys):
                return
            inst = _thread_inst.inst
            inst.state = "blocked"
            inst.wait_site = "select"
            self._blocked += 1
            self._multi_waiters[inst.uid] = keys
            try:
                self._maybe_end()
                self._check_abort()
                self.switches += 1
                while not any(self._satisfied(c, s) for c, s in keys):
                    self._any_cond.wait()
                    self._check_abort()
            finally:
                self._blocked -= 1
                self._multi_waiters.pop(inst.uid, None)
                if inst.state == "blocked":
                    inst.state = "running"

    def _check_abort(self) -> None:
        if self._deadlocked:
            if self._deadlock_report is not None:
                raise DeadlockError(self._deadlock_report)
            raise Deadlock("all tasks blocked; no progress possible")
        if self._stopping:
            raise TaskKilled()

    def _trigger_deadlock(self) -> None:
        if not self._deadlocked and self._failure is None and \
                self._deadlock_report is None:
            self._make_deadlock("deadlock")
        self._deadlocked = True
        self._notify_everything()

    def _trigger_watchdog(self, reason: str) -> None:
        """Lock held.  Same abort machinery as a deadlock, but the trip
        can fire while tasks are runnable (livelock / hang)."""
        self._make_deadlock(reason)
        self._deadlocked = True
        self._notify_everything()

    def _trigger_stop(self) -> None:
        self._stopping = True
        self._notify_everything()

    def _notify_everything(self) -> None:
        for c in self._conds.values():
            c.notify_all()
        self._any_cond.notify_all()
        self._finish_cond.notify_all()

    def push(self, chan: Channel, tok: Any) -> None:
        wake = self._fault_consult(chan, "push") \
            if self._chan_faults is not None else 0
        with self._lock:
            if self.track_stats:
                self._check_spec(chan, (tok,))
            chan._push(tok)
            if self.track_stats:
                self._stat_push(chan, 1)
            if wake:
                self.schedule_async(wake, self._delayed_wake(chan, READABLE))
            else:
                self._cond(chan, READABLE).notify()
                if self._multi_waiters:
                    self._any_cond.notify_all()

    def pop(self, chan: Channel) -> Any:
        wake = self._fault_consult(chan, "pop") \
            if self._chan_faults is not None else 0
        with self._lock:
            tok = chan._pop()
            if self.track_stats:
                chan.total_read += 1
            if wake:
                self.schedule_async(wake, self._delayed_wake(chan, WRITABLE))
            else:
                self._cond(chan, WRITABLE).notify()
                if self._multi_waiters:
                    self._any_cond.notify_all()
            return tok

    def push_burst(self, chan: Channel, toks: list) -> None:
        """Batch enqueue: one lock round-trip and one reader notify per
        burst instead of per token."""
        wake = self._fault_consult(chan, "push_burst") \
            if self._chan_faults is not None else 0
        with self._lock:
            if self.track_stats:
                self._check_spec(chan, toks)
            chan._q.extend(toks)
            if self.track_stats:
                self._stat_push(chan, len(toks))
            if wake:
                self.schedule_async(wake, self._delayed_wake(chan, READABLE))
            else:
                self._cond(chan, READABLE).notify()
                if self._multi_waiters:
                    self._any_cond.notify_all()

    def pop_burst(self, chan: Channel, n: int) -> list:
        wake = self._fault_consult(chan, "pop_burst") \
            if self._chan_faults is not None else 0
        with self._lock:
            q = chan._q
            if n == len(q):
                out = list(q)
                q.clear()
            else:
                out = [q.popleft() for _ in range(n)]
            if self.track_stats:
                chan.total_read += n
            if wake:
                self.schedule_async(wake, self._delayed_wake(chan, WRITABLE))
            else:
                self._cond(chan, WRITABLE).notify()
                if self._multi_waiters:
                    self._any_cond.notify_all()
            return out

    def data_run(self, chan: Channel, limit: int) -> int:
        with self._lock:
            return chan._data_run(limit)

    def spawn(self, inst: TaskInstance) -> None:
        with self._lock:
            self._register(inst)
        th = threading.Thread(target=self._body, args=(inst,),
                              name=inst.name, daemon=True)
        self._threads[inst.uid] = th
        th.start()

    def join(self, insts: list[TaskInstance]) -> None:
        with self._lock:
            inst = _thread_inst.inst
            while any(i.state not in ("finished", "failed") for i in insts):
                self._check_abort()
                inst.state = "blocked"
                inst.wait_site = "join"
                self._blocked += 1
                self._join_waiters[inst.uid] = insts
                try:
                    self._maybe_end()
                    self._check_abort()
                    self.switches += 1
                    self._finish_cond.wait()
                finally:
                    self._blocked -= 1
                    self._join_waiters.pop(inst.uid, None)
                    if inst.state == "blocked":
                        inst.state = "running"
            self._check_abort()
            for i in insts:
                if i.state == "failed" and i.error is not None and \
                        not isinstance(i.error, TaskKilled):
                    raise Deadlock(f"child task {i.name} failed: {i.error!r}")

    def _body(self, inst: TaskInstance) -> None:
        _thread_inst.inst = inst
        set_context(self, inst)
        with self._lock:
            self._started += 1
            inst.state = "running"
        depth = builder_stack_depth()
        try:
            a, k = bind_streams(inst)
            out = inst.fn(*a, **k)
            join_pending_builders(depth)
            with self._lock:
                inst.state = "finished"
                if inst.parent is None:
                    self._root_result = out
        except TaskKilled:
            with self._lock:
                inst.state = "finished"
        except Deadlock:
            with self._lock:
                inst.state = "failed"
        except BaseException as e:  # noqa: BLE001 - report any task failure
            with self._lock:
                inst.state = "failed"
                inst.error = e
                if self._failure is None:
                    self._failure = e
                self._trigger_deadlock()   # abort everything
        finally:
            with self._lock:
                if not self._any_nondetached_unfinished() and \
                        not self._deadlocked:
                    self._trigger_stop()
                else:
                    # a finishing producer may leave consumers permanently
                    # starved — re-run the end-state check
                    self._maybe_end()
                self._finish_cond.notify_all()
            clear_context()

    def run(self, top: Callable, *args, **kwargs) -> SimReport:
        t0 = time.perf_counter()
        self._t0 = t0
        self._root_result = None
        root = TaskInstance(top, args, kwargs, detach=False, parent=None,
                            name=getattr(top, "__name__", "top"))
        self.spawn(root)
        # wait for every non-detached task, then reap detached ones; the
        # run loop doubles as the wall-clock watchdog and — under channel
        # faults — as the pump that guarantees delayed wake-ups deliver
        # even when no task re-enters wait() (a satisfied-but-unnotified
        # waiter would otherwise strand: _no_progress_possible sees it as
        # satisfiable, so _maybe_end never fast-forwards for it)
        active = self.watchdog_s is not None or self._chan_faults is not None
        while True:
            with self._lock:
                if self._deadlocked or \
                        not self._any_nondetached_unfinished():
                    break
                if self._chan_faults is not None:
                    while self._events:
                        if not self._fast_forward():
                            break
                if self.watchdog_s is not None and not self._deadlocked:
                    reason = self._watchdog_reason()
                    if reason is not None:
                        self._trigger_watchdog(reason)
                        break
                self._finish_cond.wait(timeout=0.1 if active else 0.5)
        for uid, th in list(self._threads.items()):
            th.join(timeout=5.0)
        wall = time.perf_counter() - t0
        if self._failure is not None:
            return self._report(False, wall, f"task error: {self._failure!r}",
                                failure=self._failure)
        if self._deadlocked:
            rep = self._deadlock_report
            return self._report(False, wall,
                                rep.format() if rep is not None else "deadlock")
        return self._report(True, wall, None, self._root_result)


_thread_inst = threading.local()


# ---------------------------------------------------------------------------
# coroutine engine (the paper's contribution)
# ---------------------------------------------------------------------------

class _Fiber:
    """A cooperatively-scheduled execution context.

    Implemented over an OS thread that is *suspended at launch* and runs
    only when handed the baton — the pure-Python analogue of the paper's
    stackful coroutines ("a coroutine is launched but suspended
    immediately", S3.2).  Exactly one fiber (or the scheduler) is runnable
    at any instant, so channel state needs no locks.

    Switching is **symmetric**: a blocking fiber resumes the next ready
    fiber *directly* (one event signal per switch) instead of bouncing
    through the scheduler thread (two signals).  This is the user-level
    hand-off cost the paper contrasts with preemptive OS scheduling —
    the scheduler thread participates only at start, deadlock/termination
    detection and teardown.
    """

    __slots__ = ("inst", "engine", "sema", "thread", "killed", "done",
                 "wake_epoch")

    def __init__(self, inst: TaskInstance, engine: "CoroutineEngine"):
        self.inst = inst
        self.engine = engine
        # a counting semaphore is the cheapest exact-once baton in CPython
        # (C-level fast path, no condition-variable bookkeeping); the
        # epoch discipline in the engine guarantees single-resume, so the
        # count can never exceed one
        self.sema = threading.Semaphore(0)
        self.killed = False
        self.done = False
        self.wake_epoch = 0     # invalidates stale multi-wait queue entries
        self.thread = threading.Thread(target=self._main, name=inst.name,
                                       daemon=True)
        self.thread.start()

    # -- switching -----------------------------------------------------------
    def _handoff(self) -> None:
        """Pass the baton to the next ready fiber (or the scheduler when
        none is ready), without suspending self."""
        eng = self.engine
        nxt = eng._next_ready()
        if nxt is None:
            eng._sched_sema.release()  # scheduler: terminate/deadlock/kill
        else:
            eng.switches += 1
            nxt.sema.release()

    def _yield(self) -> None:
        """Block self: hand the baton off, then wait to be resumed."""
        self._handoff()
        self.sema.acquire()
        if self.killed:
            raise TaskKilled()

    def resume_from_scheduler(self) -> None:
        """Scheduler-side: run this fiber until control returns."""
        self.engine.switches += 1
        self.sema.release()
        self.engine._sched_sema.acquire()

    # -- body ----------------------------------------------------------------
    def _main(self) -> None:
        self.sema.acquire()      # suspended immediately at launch
        inst = self.inst
        set_context(self.engine, inst)
        _fiber_tls.fiber = self
        inst.state = "running"
        depth = builder_stack_depth()
        try:
            if self.killed:
                raise TaskKilled()
            a, k = bind_streams(inst)
            out = inst.fn(*a, **k)
            join_pending_builders(depth)
            inst.state = "finished"
            if inst.parent is None:
                self.engine._root_result = out
        except TaskKilled:
            inst.state = "finished"
        except BaseException as e:  # noqa: BLE001
            inst.state = "failed"
            inst.error = e
            if self.engine._failure is None:
                self.engine._failure = e
        finally:
            self.done = True
            clear_context()
            self.engine._on_fiber_finished(self)
            if self.engine._failure is not None:
                self.engine._sched_sema.release()  # abort: scheduler's baton
            else:
                self._handoff()                # pass baton; thread exits


_fiber_tls = threading.local()


class CoroutineEngine(EngineBase):
    """Collaborative run-to-block scheduler (paper Section 3.2).

    Determinism: the ready queue is FIFO over spawn/wake order, wake order
    is FIFO per channel side, and only one fiber runs at a time, so a given
    program produces the identical schedule on every run.

    Lock-free fast path: because exactly one fiber is runnable, a channel
    op that can make progress needs neither a lock nor engine dispatch —
    streams mutate the deque directly (``fast_path``).  The engine is
    entered only at genuine stalls (``wait``) and for wakeups, which are
    O(1): waiters park in per-channel deques (``Channel._rwait``/
    ``_wwait``), and the one-producer/one-consumer rule means each side
    holds at most one live entry.  Burst ops wake at most once per batch,
    and the wake epoch coalesces redundant wakes of an already-scheduled
    fiber, cutting the switch count to the dataflow-stall minimum.
    """

    name = "coroutine"

    def __init__(self, track_stats: bool = False, **kw):
        super().__init__(track_stats, **kw)
        # channel faults need every op observed, same as stats
        self.fast_path = not track_stats and self._chan_faults is None
        self._ready: deque[_Fiber] = deque()
        self._parked: set[Channel] = set()   # channels holding waiter entries
        self._fibers: dict[int, _Fiber] = {}
        self._join_pending: dict[int, int] = {}     # fiber uid -> #children
        self._child_to_joiner: dict[int, _Fiber] = {}
        self._sched_sema = threading.Semaphore(0)
        self._failure: Optional[BaseException] = None
        self._root_result: Any = None
        self._tearing = False

    def _next_ready(self) -> Optional["_Fiber"]:
        if self._tearing:
            return None                   # teardown: baton -> scheduler
        if not self._ports and not self._events:
            # no async interfaces in the program: zero-overhead path
            while self._ready:
                f = self._ready.popleft()
                if not f.done:
                    return f
            return None
        while True:
            # service step: the clock ticks once per scheduling decision,
            # queued requests are accepted, due responses delivered (their
            # wakes append to the ready queue)
            self.clock += 1
            for port in self._ports:
                port.pump(self)
            if self._events:
                self._deliver_due()
            while self._ready:
                f = self._ready.popleft()
                if not f.done:
                    return f
            # nothing runnable: fast-forward to the next memory response;
            # if that delivers nothing the stall is a genuine deadlock
            if not self._fast_forward():
                return None

    # -- async interface protocol --------------------------------------------
    def _iface_deliver(self, chan: Channel, tok: Any) -> None:
        chan._push(tok)
        if self.track_stats:
            self._stat_push(chan, 1)
        if chan._rwait:
            self._wake(chan._rwait)

    def _iface_pop(self, chan: Channel) -> Any:
        tok = chan._pop()
        if self.track_stats:
            chan.total_read += 1
        if chan._wwait:
            self._wake(chan._wwait)
        return tok

    # -- runtime protocol ----------------------------------------------------
    def wait(self, chan: Channel, side: str) -> None:
        fiber: _Fiber = _fiber_tls.fiber
        site = ("write " if side == WRITABLE else "read ") + chan.name
        if self.watchdog_s is not None or self.max_ticks is not None:
            self._watchdog_check(fiber, site)
        fiber.inst.state = "blocked"
        fiber.inst.wait_site = site
        wq = chan._rwait if side == READABLE else chan._wwait
        wq.append((fiber, fiber.wake_epoch))
        self._parked.add(chan)
        fiber._yield()
        fiber.inst.state = "running"

    def wait_many(self, keys: list) -> None:
        """Multi-port wait: register in every key's waiter queue; the first
        event on any of them wakes the fiber and the epoch stamp marks the
        other registrations stale."""
        fiber: _Fiber = _fiber_tls.fiber
        if self.watchdog_s is not None or self.max_ticks is not None:
            self._watchdog_check(fiber, "select")
        fiber.inst.state = "blocked"
        fiber.inst.wait_site = "select"
        e = fiber.wake_epoch
        for chan, side in keys:
            wq = chan._rwait if side == READABLE else chan._wwait
            wq.append((fiber, e))
            self._parked.add(chan)
        fiber._yield()
        fiber.inst.state = "running"

    def _watchdog_check(self, fiber: "_Fiber", site: str) -> None:
        """Raise DeadlockError inside the blocking fiber on a tripped
        budget — it surfaces as ``_failure`` and aborts the run with the
        structured report attached.  The tripping fiber is included in the
        blocked list (it is the task *about to* block)."""
        if self.max_ticks is not None:
            # the zero-overhead scheduling path never ticks the clock, so a
            # tick budget counts blocking waits instead (chaos runs only —
            # watchdog-less runs keep the clock untouched)
            self.clock += 1
        reason = self._watchdog_reason()
        if reason is None:
            return
        blocked = self._blocked_sites()
        blocked.append((fiber.inst.name, site))
        raise DeadlockError(self._make_deadlock(reason, blocked=blocked))

    # -- chaos-harness plumbing ---------------------------------------------
    def _fault_consult(self, chan: Channel, op: str):
        """Consult the injector *before* the op mutates anything, so an
        InjectedFault leaves the channel untouched."""
        fiber = getattr(_fiber_tls, "fiber", None)
        return self._chan_faults.chan_op(
            chan.name, op, fiber.inst.name if fiber is not None else "?")

    def _fault_stall(self, ticks: int) -> None:
        """Post-op stall: yield the baton ``ticks`` times (each round trips
        through the ready queue, letting other fibers run — the
        collaborative analogue of losing the processor)."""
        fiber = getattr(_fiber_tls, "fiber", None)
        if fiber is None:
            return
        for _ in range(ticks):
            self._schedule(fiber)
            fiber._yield()

    def _fault_wake(self, chan: Channel, side: str):
        """Deliver fn for a fault-delayed wake-up: the token is already in
        the queue, only the wake travels through the event heap (delivery
        is guaranteed — _next_ready fast-forwards pending events before
        ever declaring a deadlock)."""
        def deliver(eng, c=chan, s=side):
            wq = c._rwait if s == READABLE else c._wwait
            eng._wake(wq)
            return True
        return deliver

    def push(self, chan: Channel, tok: Any) -> None:
        stall = wake = 0
        if self._chan_faults is not None:
            stall, wake = self._fault_consult(chan, "push")
        if self.track_stats:
            self._check_spec(chan, (tok,))
        chan._push(tok)              # no lock: exclusivity by construction
        if self.track_stats:
            self._stat_push(chan, 1)
        if chan._rwait:
            if wake:
                self.schedule_async(wake, self._fault_wake(chan, READABLE))
            else:
                self._wake(chan._rwait)
        if stall:
            self._fault_stall(stall)

    def pop(self, chan: Channel) -> Any:
        stall = wake = 0
        if self._chan_faults is not None:
            stall, wake = self._fault_consult(chan, "pop")
        tok = chan._pop()
        if self.track_stats:
            chan.total_read += 1
        if chan._wwait:
            if wake:
                self.schedule_async(wake, self._fault_wake(chan, WRITABLE))
            else:
                self._wake(chan._wwait)
        if stall:
            self._fault_stall(stall)
        return tok

    def push_burst(self, chan: Channel, toks: list) -> None:
        """Batch enqueue: one deque.extend and at most one reader wake per
        burst — the per-token runtime cost is amortized away."""
        stall = wake = 0
        if self._chan_faults is not None:
            stall, wake = self._fault_consult(chan, "push_burst")
        if self.track_stats:
            self._check_spec(chan, toks)
        chan._q.extend(toks)
        if self.track_stats:
            self._stat_push(chan, len(toks))
        if chan._rwait:
            if wake:
                self.schedule_async(wake, self._fault_wake(chan, READABLE))
            else:
                self._wake(chan._rwait)
        if stall:
            self._fault_stall(stall)

    def pop_burst(self, chan: Channel, n: int) -> list:
        stall = wake = 0
        if self._chan_faults is not None:
            stall, wake = self._fault_consult(chan, "pop_burst")
        q = chan._q
        if n == len(q):
            out = list(q)
            q.clear()
        else:
            out = [q.popleft() for _ in range(n)]
        if self.track_stats:
            chan.total_read += n
        if chan._wwait:
            if wake:
                self.schedule_async(wake, self._fault_wake(chan, WRITABLE))
            else:
                self._wake(chan._wwait)
        if stall:
            self._fault_stall(stall)
        return out

    def _schedule(self, fiber: "_Fiber") -> None:
        """The single wake path: bumping the epoch here marks every other
        outstanding waiter-queue registration of this fiber stale, so a
        fiber can never be double-resumed (which would desynchronize the
        baton handshake) and consecutive wakes of the same fiber coalesce
        into one ready-queue entry."""
        fiber.wake_epoch += 1
        self._ready.append(fiber)

    def _wake(self, wq: deque) -> None:
        """Drain one per-channel waiter list: schedule live entries, drop
        stale ones.  The one-producer/one-consumer rule bounds live entries
        per side at one, so this is O(1) amortized."""
        while wq:
            fiber, epoch = wq.popleft()
            if fiber.wake_epoch == epoch and not fiber.done:
                self._schedule(fiber)

    def spawn(self, inst: TaskInstance) -> None:
        self._register(inst)
        fiber = _Fiber(inst, self)
        self._fibers[inst.uid] = fiber
        self._ready.append(fiber)

    def join(self, insts: list[TaskInstance]) -> None:
        fiber: _Fiber = _fiber_tls.fiber
        pending = [i for i in insts if i.state not in ("finished", "failed")]
        for i in insts:
            if i.state == "failed" and i.error is not None:
                raise Deadlock(f"child task {i.name} failed: {i.error!r}")
        if not pending:
            return
        self._join_pending[fiber.inst.uid] = len(pending)
        for c in pending:
            self._child_to_joiner[c.uid] = fiber
        fiber.inst.state = "blocked"
        fiber.inst.wait_site = "join"
        fiber._yield()
        fiber.inst.state = "running"
        for i in insts:
            if i.state == "failed" and i.error is not None:
                raise Deadlock(f"child task {i.name} failed: {i.error!r}")

    def _on_fiber_finished(self, fiber: _Fiber) -> None:
        joiner = self._child_to_joiner.pop(fiber.inst.uid, None)
        if joiner is not None:
            self._join_pending[joiner.inst.uid] -= 1
            if self._join_pending[joiner.inst.uid] == 0:
                del self._join_pending[joiner.inst.uid]
                self._schedule(joiner)

    # -- scheduler -----------------------------------------------------------
    def _any_nondetached_unfinished(self) -> bool:
        return any(i.state not in ("finished", "failed")
                   for i in self.instances if not i.detach)

    def _kill_blocked_fibers(self) -> None:
        """Tear down fibers that are permanently blocked (detached tasks at
        normal termination, or everything on deadlock)."""
        for chan in self._parked:
            for wq in (chan._rwait, chan._wwait):
                while wq:
                    f, epoch = wq.popleft()
                    if f.done or f.killed or f.wake_epoch != epoch:
                        continue
                    f.killed = True
                    f.resume_from_scheduler()
        for f in self._fibers.values():
            if not f.done and not f.killed and \
                    f.inst.state in ("created", "blocked"):
                f.killed = True
                f.resume_from_scheduler()

    def run(self, top: Callable, *args, **kwargs) -> SimReport:
        t0 = time.perf_counter()
        self._t0 = t0
        root = TaskInstance(top, args, kwargs, detach=False, parent=None,
                            name=getattr(top, "__name__", "top"))
        set_context(self, None)    # so top-level spawn() is routed at us
        self.spawn(root)
        deadlock = False
        # Direct-handoff scheduling: the scheduler thread starts the first
        # fiber and regains control only when no fiber is ready (normal
        # termination, deadlock) or on failure-abort; all other switches
        # are fiber-to-fiber.
        while True:
            if self._failure is not None:
                break
            nxt = self._next_ready()
            if nxt is not None:
                nxt.resume_from_scheduler()
                continue
            if self._any_nondetached_unfinished():
                deadlock = True
            break
        blocked_names = [i.name for i in self.instances
                         if i.state == "blocked" and not i.detach]
        if deadlock:
            # snapshot the structured report before teardown mutates states
            self._make_deadlock("deadlock")
        self._tearing = True
        self._kill_blocked_fibers()
        for f in self._fibers.values():
            f.thread.join(timeout=5.0)
        clear_context()
        wall = time.perf_counter() - t0
        if self._failure is not None:
            if isinstance(self._failure, DeadlockError):
                # watchdog trip inside a fiber: already carries the report
                return self._report(False, wall, str(self._failure))
            return self._report(False, wall,
                                f"task error: {self._failure!r}",
                                failure=self._failure)
        if deadlock:
            return self._report(
                False, wall, f"deadlock; blocked tasks: {blocked_names}")
        return self._report(True, wall, None, self._root_result)


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------

ENGINES = {
    "sequential": SequentialEngine,
    "thread": ThreadEngine,
    "coroutine": CoroutineEngine,
}


def run(top: Callable, *args, engine: str = "coroutine",
        track_stats: bool = False, faults: Any = None,
        watchdog_s: Optional[float] = None,
        max_ticks: Optional[int] = None, **kwargs) -> SimReport:
    """Simulate a task-parallel program.

    This is the software-simulation half of the paper's unified
    system-integration interface: the same top-level task function is later
    accepted by the compiled runners (``repro.launch``).

    ``track_stats=True`` records per-channel token counts and occupancy
    highwater marks (burst-granular) at the cost of disabling the
    run-to-block fast path.

    ``faults`` attaches a chaos harness (a ``FaultPlan`` or its injector,
    see :mod:`repro.core.faults`); ``watchdog_s`` / ``max_ticks`` arm the
    unified wall-clock / logical-clock watchdog (docs/robustness.md).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"choose from {sorted(ENGINES)}")
    eng = ENGINES[engine](track_stats=track_stats, faults=faults,
                          watchdog_s=watchdog_s, max_ticks=max_ticks)
    return eng.run(top, *args, **kwargs)
