"""Whole-graph synthesis: lower a task graph to ONE compiled XLA program.

The paper's two-sided contract (Fig. 2) is *simulate for correctness,
synthesize for QoR*.  Until now this repo's "codegen" jitted stage
functions one at a time while host Python shuttled every token between
them — the interconnect (FIFOs, task firing control) stayed in software.
TAPA's insight is that the win comes from synthesizing exactly that
interconnect; hlslib's is that channels must become typed, fixed-capacity
hardware objects for the lowering to exist.  This module is the XLA
analogue:

* every :class:`~repro.core.channel.Channel` becomes a fixed-capacity
  **on-device ring buffer** — ``(buf[capacity, *elem_shape], head, size)``
  carried through a ``lax.while_loop``;
* every task becomes a **guarded step**: it fires only when its declared
  reads are available and its writes fit, mirroring the engines' blocking
  semantics exactly;
* bursts become slice transfers (gather/scatter over the ring);
* mmap buffers and scalars flow through the PR-4 ``lower_spec`` path —
  mmaps are runtime inputs of the executable, scalars static constants.

The synthesizable subset is the **step-function form**: a leaf task is a
:class:`StepTask` whose phases are pure jax-traceable functions

    ``state, *port_views -> state``

with *static* I/O rates (reads/writes per firing fixed at trace time).
The same StepTask runs unmodified under the Python engines — its
``__call__`` is the **simulation twin**, executing the phase functions
against real blocking streams — so one graph definition is both the
correctness vehicle and the compiled artifact, bit-for-bit.

Whole-graph lowerings are keyed in the PR-2 compile cache by the graph's
structural hash + input avals: a second process re-running the same graph
performs **zero XLA compiles**.

Since schema ``synth3`` a graph can also be **partitioned** across a
1-D device mesh (``CompiledEngine(mesh=N)``): the floorplanner
(:mod:`repro.core.floorplan`) assigns tasks to devices on real per-task
costs, and ``_build_partitioned_program`` lowers the cut channels to
``lax.ppermute`` exchanges inside a sweep-synchronous ``shard_map``
body that is a bit-twin of the single-device program.  Placements are
content-addressed artifacts; the owners vector folds into the compile
key, so re-partitioning and recompiling are both zero on reuse.

The ring-buffer ops themselves (pop/push bursts, fused guard
evaluation) dispatch through :mod:`repro.kernels.ring` — Pallas kernels
on TPU, a bit-exact vectorized XLA reference elsewhere, interpret mode
for parity tests — selected per engine (``ring_impl=``) or process
(``$REPRO_RING_IMPL``).

``async_mmap`` ports ARE synthesizable (since schema ``synth2``): the
five member channels lower to ordinary ring buffers and the memory
endpoint becomes a fixed-``depth`` latency queue in the while_loop
carry, serviced once per sweep — requests are accepted issue-ahead up
to ``depth`` outstanding and responses delivered ``latency`` sweeps
later in per-port FIFO order, matching the simulator contract.  See
``docs/synthesis.md`` ("kernel lowering").

Anything outside the subset is *refused with a diagnostic naming the
task/channel* (:class:`~repro.core.errors.SynthesisError`), never
miscompiled: non-step leaf tasks (e.g. availability-routed switches using
``peek``/``select``), channels without a declared element spec,
data-dependent I/O rates, async_mmap ports with an unbounded in-flight
window (``depth=None``) or used for both reads and writes (response-
timing-dependent), and mmaps both written and read across tasks
(schedule-dependent).  See ``docs/synthesis.md``.
"""

from __future__ import annotations

import hashlib
import inspect
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .channel import Channel, IStream, OStream
from .compile_cache import aval_signature, default_cache, _stable_repr
from .context import clear_context, set_context
from .engines import ENGINES, EngineBase, SimReport
from .errors import (ChannelMisuse, DeadlockReport, GraphValidationError,
                     SynthesisError)
from .graph import extract_graph
from .interface import AsyncMMap, MMap
from .task import (AutoStream, TaskInstance, bind_streams,
                   builder_stack_depth, join_pending_builders)
from ..kernels.dispatch import resolve_impl
from ..kernels.ring import (RING_CHOICES, RING_ENV, eval_guards, ring_pop,
                            ring_push)

SYNTH_SCHEMA = "synth3"

try:                                    # moved to jax.shard_map in 0.5+
    _shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def _canon_dtype(dtype: Any) -> np.dtype:
    """The dtype a ring buffer (or mmap input) actually carries on device:
    the declared dtype after jax canonicalization (x64 -> x32 when 64-bit
    mode is off).  Element checks compare against THIS, so a float64
    declaration is not misreported as the task's fault."""
    return np.dtype(jax.dtypes.canonicalize_dtype(np.dtype(dtype)))


def _materialize_state(init: Any) -> Any:
    """Canonicalize an initial-state pytree to jax arrays — the same
    representation the twin and the compiled program both carry, so float
    semantics (incl. x64 canonicalization) agree between them."""
    return jax.tree.map(jnp.asarray, init)


def _state_spec(state: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), state)


# ---------------------------------------------------------------------------
# the step-function task form
# ---------------------------------------------------------------------------

class StepTask:
    """A leaf task in traceable step-function form.

    Up to three phases, each a pure function ``state, *ports -> state``
    with static per-firing I/O rates:

    * ``warmup`` — fires ``n_warmup`` times (pipeline fill: e.g. read the
      first stencil row without emitting);
    * ``step``   — the steady state, fires ``steps`` times;
    * ``flush``  — fires ``n_flush`` times (drain: e.g. emit the
      accumulated result block).

    ``init`` is the initial state pytree.  Ports are the invoke arguments:
    channels appear as stream views (``read``/``read_burst``/``write``/
    ``write_burst`` only — no EoT, no peek: termination is by firing
    count), mmaps as memory views, scalars as plain values.

    Calling a StepTask *is* its simulation twin: the classic engines
    invoke it like any task body, and it runs the phase functions against
    the real blocking streams.  ``CompiledEngine`` instead lowers every
    firing into a guarded step of one jitted whole-graph program.
    """

    is_step_task = True

    def __init__(self, step: Callable, *, steps: int, init: Any = None,
                 warmup: Optional[Callable] = None, n_warmup: int = 1,
                 flush: Optional[Callable] = None, n_flush: int = 1,
                 close_outputs: bool = False, name: Optional[str] = None):
        if not isinstance(steps, int) or steps < 0:
            raise ValueError("StepTask steps must be a static int >= 0")
        self.step = step
        self.steps = steps
        self.init = init
        self.warmup = warmup
        self.n_warmup = int(n_warmup) if warmup is not None else 0
        self.flush = flush
        self.n_flush = int(n_flush) if flush is not None else 0
        # interop with EoT-consuming free-form tasks: the twin closes every
        # written stream after its last firing.  EoT is outside the
        # synthesizable subset, so synthesis refuses such tasks.
        self.close_outputs = close_outputs
        self.__name__ = name or getattr(step, "__name__", "step_task")
        try:
            sig = inspect.signature(step)
            params = list(sig.parameters.values())[1:]   # drop ``state``
            self.__signature__ = sig.replace(parameters=params)
        except (TypeError, ValueError):
            pass

    def phases(self) -> list[tuple[str, Callable, int]]:
        out = []
        if self.warmup is not None and self.n_warmup:
            out.append(("warmup", self.warmup, self.n_warmup))
        if self.steps:
            out.append(("step", self.step, self.steps))
        if self.flush is not None and self.n_flush:
            out.append(("flush", self.flush, self.n_flush))
        return out

    @property
    def total_fires(self) -> int:
        return sum(n for _, _, n in self.phases())

    # -- simulation twin -----------------------------------------------------
    def __call__(self, *args, **kwargs):
        streams: list[_TwinStream] = []
        views = tuple(_twin_view(a, streams) for a in args)
        kw = {k: _twin_view(v, streams) for k, v in kwargs.items()}
        state = _materialize_state(self.init)
        for _, fn, n in self.phases():
            for _ in range(n):
                state = fn(state, *views, **kw)
        if self.close_outputs:
            for s in streams:
                # close written streams, and annotated output ports even
                # when this instance never fired (an empty schedule must
                # still end its downstream consumer's transaction)
                if s._wrote or isinstance(s._s, OStream):
                    s._s.close()
        return state

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"StepTask({self.__name__!r}, "
                f"fires={self.total_fires})")


class _TwinStream:
    """Simulation-twin stream view: the synthesizable port API
    (``read``/``read_burst``/``write``/``write_burst``) over a real
    blocking stream.  Burst reads stack to an array so the phase function
    sees the exact value shape synthesis hands it."""

    __slots__ = ("_s", "_wrote")

    def __init__(self, s):
        self._s = s
        self._wrote = False

    def read(self):
        return self._s.read()

    def read_burst(self, n: int):
        toks = self._s.read_burst(n)
        if len(toks) != n:
            raise ChannelMisuse(
                f"step task read_burst({n}) hit EoT after {len(toks)} "
                f"tokens on channel {self._s.channel.name!r}; step graphs "
                f"terminate by firing counts, not EoT")
        return jnp.stack([jnp.asarray(t) for t in toks])

    def write(self, tok) -> None:
        self._wrote = True
        self._s.write(tok)

    def write_burst(self, arr) -> None:
        self._wrote = True
        self._s.write_burst(list(arr))


class _TwinPort:
    """Simulation-twin view of an async memory port: the five member
    streams wrapped as :class:`_TwinStream` so burst reads stack to
    arrays — the exact value shapes synthesis hands the phase function.
    Port streams are never EoT-closed (memory request channels carry no
    transactions), so they stay off the ``close_outputs`` list."""

    __slots__ = ("_p", "read_addr", "read_data", "write_addr",
                 "write_data", "write_resp")

    def __init__(self, p: AsyncMMap):
        self._p = p
        self.read_addr = _TwinStream(p.read_addr)
        self.read_data = _TwinStream(p.read_data)
        self.write_addr = _TwinStream(p.write_addr)
        self.write_data = _TwinStream(p.write_data)
        self.write_resp = _TwinStream(p.write_resp)

    @property
    def shape(self) -> tuple:
        return tuple(self._p.shape)

    @property
    def dtype(self):
        return self._p.dtype

    @property
    def latency(self) -> int:
        return self._p.latency

    @property
    def depth(self):
        return self._p.depth

    @property
    def name(self) -> str:
        return self._p.name

    def __len__(self) -> int:
        return len(self._p)

    def read_pipelined(self, addrs) -> list:
        return self._p.read_pipelined(addrs)


def _twin_view(v: Any, streams: Optional[list] = None) -> Any:
    if isinstance(v, (IStream, OStream, AutoStream)):
        tw = _TwinStream(v)
        if streams is not None:
            streams.append(tw)
        return tw
    if isinstance(v, AsyncMMap):
        return _TwinPort(v)
    if isinstance(v, (list, tuple)):
        return type(v)(_twin_view(x, streams) for x in v)
    return v


# ---------------------------------------------------------------------------
# trace-time views (shared by the counting pass and the real lowering)
# ---------------------------------------------------------------------------

class _Ctx:
    """Mutable trace-time context: the functional channel/mmap states a
    firing reads and replaces."""

    __slots__ = ("chans", "mmaps", "ring_impl")

    def __init__(self, chans: dict, mmaps: dict, ring_impl: str = "xla"):
        self.chans = chans      # ci -> (buf, head, size)
        self.mmaps = mmaps      # mi -> array
        self.ring_impl = ring_impl


class _Recorder:
    """Counting-pass sink: per-phase I/O rates + endpoint/direction
    registration.  Absent (None) during the real lowering trace — the
    counts are already validated identical because the trace is the same
    Python."""

    def __init__(self, inst: TaskInstance):
        self.inst = inst
        self.reads: dict[int, int] = {}
        self.writes: dict[int, int] = {}
        self.mmap_loads: dict[int, int] = {}     # element counts
        self.mmap_stores: dict[int, int] = {}
        self.mmap_load_ops: dict[int, int] = {}  # transfer counts
        self.mmap_store_ops: dict[int, int] = {}
        self.mmap_read: set = set()
        self.mmap_written: set = set()


class _SynthStream:
    """Trace-time stream view over a ring buffer in the carry."""

    __slots__ = ("_ctx", "_ci", "_chan", "_inst", "_rec")

    def __init__(self, ctx: _Ctx, ci: int, chan: Channel,
                 inst: TaskInstance, rec: Optional[_Recorder]):
        self._ctx = ctx
        self._ci = ci
        self._chan = chan
        self._inst = inst
        self._rec = rec

    # -- reads ---------------------------------------------------------------
    def read(self):
        buf, head, size = self._ctx.chans[self._ci]
        self._account("read", 1)
        toks, head, size = ring_pop(buf, head, size, 1,
                                    impl=self._ctx.ring_impl)
        self._ctx.chans[self._ci] = (buf, head, size)
        return toks[0]

    def read_burst(self, n: int):
        n = self._static(n, "read_burst")
        buf, head, size = self._ctx.chans[self._ci]
        self._account("read", n)
        toks, head, size = ring_pop(buf, head, size, n,
                                    impl=self._ctx.ring_impl)
        self._ctx.chans[self._ci] = (buf, head, size)
        return toks

    # -- writes --------------------------------------------------------------
    def write(self, tok) -> None:
        tok = jnp.asarray(tok)
        self._check_elem(tok, burst=False)
        buf, head, size = self._ctx.chans[self._ci]
        self._account("write", 1)
        self._ctx.chans[self._ci] = ring_push(buf, head, size, tok[None],
                                              impl=self._ctx.ring_impl)

    def write_burst(self, arr) -> None:
        arr = jnp.asarray(arr) if not isinstance(arr, (list, tuple)) \
            else jnp.stack([jnp.asarray(t) for t in arr])
        self._check_elem(arr, burst=True)
        n = int(arr.shape[0])
        buf, head, size = self._ctx.chans[self._ci]
        self._account("write", n)
        self._ctx.chans[self._ci] = ring_push(buf, head, size, arr,
                                              impl=self._ctx.ring_impl)

    # -- everything else is outside the synthesizable subset -----------------
    def _unsupported(self, op: str):
        raise SynthesisError(
            f"task {self._inst.name!r} used stream op {op!r} on channel "
            f"{self._chan.name!r}: step-function tasks may only "
            f"read/read_burst/write/write_burst (termination is by firing "
            f"count, availability routing needs the simulation engines)")

    def close(self):
        self._unsupported("close")

    def peek(self):
        self._unsupported("peek")

    def eot(self):
        self._unsupported("eot")

    def open(self):
        self._unsupported("open")

    def empty(self):
        self._unsupported("empty")

    def full(self):
        self._unsupported("full")

    def try_read(self):
        self._unsupported("try_read")

    def try_write(self, v):
        self._unsupported("try_write")

    # -- helpers -------------------------------------------------------------
    def _static(self, n: Any, op: str) -> int:
        if not isinstance(n, (int, np.integer)):
            raise SynthesisError(
                f"task {self._inst.name!r}: {op} size on channel "
                f"{self._chan.name!r} is data-dependent (a traced value); "
                f"synthesis needs static I/O rates")
        return int(n)

    def _check_elem(self, arr, burst: bool) -> None:
        c = self._chan
        got_shape = tuple(arr.shape[1:]) if burst else tuple(arr.shape)
        if got_shape != c.shape:
            raise SynthesisError(
                f"task {self._inst.name!r} wrote a token of shape "
                f"{got_shape} to channel {c.name!r} declaring element "
                f"shape {c.shape}")
        if np.dtype(arr.dtype) != _canon_dtype(c.dtype):
            raise SynthesisError(
                f"task {self._inst.name!r} wrote a token of dtype "
                f"{arr.dtype} to channel {c.name!r} declaring element "
                f"dtype {c.dtype} (canonicalized {_canon_dtype(c.dtype)})")

    def _account(self, op: str, n: int) -> None:
        rec = self._rec
        if rec is None:
            return
        if op == "read":
            self._chan._bind("consumer", self._inst)
            rec.reads[self._ci] = rec.reads.get(self._ci, 0) + n
        else:
            self._chan._bind("producer", self._inst)
            rec.writes[self._ci] = rec.writes.get(self._ci, 0) + n


class _SynthMMap:
    """Trace-time memory view: the MMap API over a carry array, updated
    functionally.  Loads/stores may use traced indices (they lower to
    gathers / dynamic slices)."""

    __slots__ = ("_ctx", "_mi", "_mmap", "_inst", "_rec")

    def __init__(self, ctx: _Ctx, mi: int, mmap: MMap,
                 inst: TaskInstance, rec: Optional[_Recorder]):
        self._ctx = ctx
        self._mi = mi
        self._mmap = mmap
        self._inst = inst
        self._rec = rec

    @property
    def shape(self) -> tuple:
        return tuple(self._mmap.shape)

    @property
    def dtype(self):
        return self._ctx.mmaps[self._mi].dtype

    def __len__(self) -> int:
        return len(self._mmap)

    def __getitem__(self, idx):
        v = self._ctx.mmaps[self._mi][idx]
        self._account("read", v)
        return v

    def __setitem__(self, idx, value) -> None:
        value = jnp.asarray(value)
        self._account("write", value)
        self._ctx.mmaps[self._mi] = \
            self._ctx.mmaps[self._mi].at[idx].set(value)

    def read_burst(self, start, n: int):
        if not isinstance(n, (int, np.integer)):
            raise SynthesisError(
                f"task {self._inst.name!r}: mmap {self._mmap.name!r} "
                f"read_burst size is data-dependent; synthesis needs a "
                f"static transfer size")
        out = jax.lax.dynamic_slice_in_dim(
            self._ctx.mmaps[self._mi], jnp.asarray(start, jnp.int32),
            int(n), axis=0)
        self._account("read", out)
        return out

    def write_burst(self, start, seq) -> None:
        seq = jnp.asarray(seq)
        self._account("write", seq)
        self._ctx.mmaps[self._mi] = jax.lax.dynamic_update_slice_in_dim(
            self._ctx.mmaps[self._mi], seq, jnp.asarray(start, jnp.int32),
            axis=0)

    def _account(self, op: str, v) -> None:
        rec = self._rec
        if rec is None:
            return
        n = int(np.prod(np.shape(v))) if np.shape(v) else 1
        if op == "read":
            rec.mmap_read.add(self._mi)
            rec.mmap_loads[self._mi] = rec.mmap_loads.get(self._mi, 0) + n
            rec.mmap_load_ops[self._mi] = \
                rec.mmap_load_ops.get(self._mi, 0) + 1
        else:
            rec.mmap_written.add(self._mi)
            rec.mmap_stores[self._mi] = rec.mmap_stores.get(self._mi, 0) + n
            rec.mmap_store_ops[self._mi] = \
                rec.mmap_store_ops.get(self._mi, 0) + 1
        b = self._mmap._by_inst.get(self._inst.uid)
        if b is not None:
            b.direction.add(op)


# ---------------------------------------------------------------------------
# lowering plan
# ---------------------------------------------------------------------------

class _ChanRef:
    __slots__ = ("ci",)

    def __init__(self, ci: int):
        self.ci = ci


class _MMapRef:
    __slots__ = ("mi",)

    def __init__(self, mi: int):
        self.mi = mi


class _PortRef:
    __slots__ = ("pi", "cis")

    def __init__(self, pi: int, cis: tuple):
        self.pi = pi
        self.cis = cis      # (raddr, rdata, waddr, wdata, wresp) chan ids


class _SynthAsyncPort:
    """Trace-time view of an async memory port: the five member streams
    are ordinary :class:`_SynthStream` views over their ring buffers in
    the carry — so port I/O gets guards and static-rate counting for
    free — while the memory endpoint itself is serviced once per sweep
    by the lowered latency queue (see ``_build_program``)."""

    __slots__ = ("_port", "_inst", "read_addr", "read_data", "write_addr",
                 "write_data", "write_resp")

    def __init__(self, ctx: _Ctx, cis: tuple, port: AsyncMMap,
                 inst: TaskInstance, rec: Optional[_Recorder],
                 plan: "_Plan"):
        self._port = port
        self._inst = inst
        mk = lambda ci: _SynthStream(  # noqa: E731
            ctx, ci, plan.channels[ci], inst, rec)
        ra, rd, wa, wd, wr = cis
        self.read_addr = mk(ra)
        self.read_data = mk(rd)
        self.write_addr = mk(wa)
        self.write_data = mk(wd)
        self.write_resp = mk(wr)

    @property
    def shape(self) -> tuple:
        return tuple(self._port.shape)

    @property
    def dtype(self):
        return self._port.dtype

    @property
    def latency(self) -> int:
        return self._port.latency

    @property
    def depth(self):
        return self._port.depth

    @property
    def name(self) -> str:
        return self._port.name

    def __len__(self) -> int:
        return len(self._port)

    def read_pipelined(self, addrs):
        raise SynthesisError(
            f"task {self._inst.name!r} used read_pipelined on async_mmap "
            f"{self._port.name!r}: its issue/drain interleaving is "
            f"availability-routed (try_write/select), outside the static-"
            f"rate subset.  Software-pipeline it instead: issue addresses "
            f"with write/write_burst on read_addr and drain read_data with "
            f"read/read_burst across warmup/step/flush phases (see "
            f"docs/synthesis.md, kernel lowering)")


@dataclass
class _PhasePlan:
    label: str
    fn: Callable
    count: int
    reads: dict = field(default_factory=dict)    # ci -> tokens per firing
    writes: dict = field(default_factory=dict)
    mmap_loads: dict = field(default_factory=dict)    # mi -> elems/firing
    mmap_stores: dict = field(default_factory=dict)
    mmap_load_ops: dict = field(default_factory=dict)  # mi -> transfers
    mmap_store_ops: dict = field(default_factory=dict)


@dataclass
class _TaskPlan:
    inst: TaskInstance
    task: StepTask
    t_args: tuple = ()
    t_kwargs: dict = field(default_factory=dict)
    chan_ids: list = field(default_factory=list)
    mmap_ids: list = field(default_factory=list)
    port_ids: list = field(default_factory=list)
    phases: list = field(default_factory=list)   # [_PhasePlan]
    state0: Any = None

    @property
    def total(self) -> int:
        return sum(p.count for p in self.phases)

    @property
    def bounds(self) -> list[int]:
        out, acc = [], 0
        for p in self.phases:
            acc += p.count
            out.append(acc)
        return out


class _Plan:
    def __init__(self):
        self.channels: list[Channel] = []
        self._chan_idx: dict[int, int] = {}
        self.mmaps: list[MMap] = []
        self._mmap_idx: dict[int, int] = {}
        self.ports: list[AsyncMMap] = []
        self._port_idx: dict[int, int] = {}
        self.port_chan_ids: dict[int, tuple] = {}   # pi -> 5 member cis
        self.port_dirs: list[set] = []              # pi -> {"read","write"}
        self.ring_impl: str = "xla"
        self.tasks: list[_TaskPlan] = []

    def chan_index(self, c: Channel) -> int:
        i = self._chan_idx.get(id(c))
        if i is None:
            i = self._chan_idx[id(c)] = len(self.channels)
            self.channels.append(c)
        return i

    def mmap_index(self, m: MMap) -> int:
        i = self._mmap_idx.get(id(m))
        if i is None:
            i = self._mmap_idx[id(m)] = len(self.mmaps)
            self.mmaps.append(m)
        return i

    def port_index(self, p: AsyncMMap) -> int:
        i = self._port_idx.get(id(p))
        if i is None:
            i = self._port_idx[id(p)] = len(self.ports)
            self.ports.append(p)
            self.port_dirs.append(set())
        return i


def _build_template(v: Any, plan: _Plan, tp: _TaskPlan) -> Any:
    """Replace bound stream/mmap views with carry references; everything
    else (scalars, None, raw arrays — trace-time constants) passes
    through."""
    if isinstance(v, (IStream, OStream, AutoStream)):
        ci = plan.chan_index(v.channel)
        if ci not in tp.chan_ids:
            tp.chan_ids.append(ci)
        return _ChanRef(ci)
    if isinstance(v, MMap):
        mi = plan.mmap_index(v)
        if mi not in tp.mmap_ids:
            tp.mmap_ids.append(mi)
        return _MMapRef(mi)
    if isinstance(v, AsyncMMap):
        if not isinstance(v.depth, int):
            raise SynthesisError(
                f"task {tp.inst.name!r} binds async_mmap {v.name!r} with "
                f"an unbounded in-flight window (depth=None): synthesis "
                f"sizes the latency queue in the while_loop carry from a "
                f"static depth — give the port a bounded depth (e.g. "
                f"depth=4) or run on a simulation engine")
        pi = plan.port_index(v)
        if pi not in tp.port_ids:
            tp.port_ids.append(pi)
        cis = []
        for ch in v.channels():
            ci = plan.chan_index(ch)
            if ci not in tp.chan_ids:
                tp.chan_ids.append(ci)
            cis.append(ci)
        plan.port_chan_ids[pi] = tuple(cis)
        return _PortRef(pi, tuple(cis))
    if isinstance(v, (list, tuple)):
        conv = [_build_template(x, plan, tp) for x in v]
        return type(v)(conv) if isinstance(v, tuple) else conv
    return v


def _instantiate(t: Any, ctx: _Ctx, plan: _Plan, inst: TaskInstance,
                 rec: Optional[_Recorder]) -> Any:
    if isinstance(t, _ChanRef):
        return _SynthStream(ctx, t.ci, plan.channels[t.ci], inst, rec)
    if isinstance(t, _MMapRef):
        return _SynthMMap(ctx, t.mi, plan.mmaps[t.mi], inst, rec)
    if isinstance(t, _PortRef):
        return _SynthAsyncPort(ctx, t.cis, plan.ports[t.pi], inst, rec,
                               plan)
    if isinstance(t, (list, tuple)):
        conv = [_instantiate(x, ctx, plan, inst, rec) for x in t]
        return type(t)(conv) if isinstance(t, tuple) else conv
    return t


def _chan_specs(plan: _Plan, tp: _TaskPlan) -> tuple:
    out = []
    for ci in tp.chan_ids:
        c = plan.channels[ci]
        out.append((
            jax.ShapeDtypeStruct((c.capacity,) + c.shape,
                                 _canon_dtype(c.dtype)),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32)))
    return tuple(out)


def _mmap_specs(plan: _Plan, tp: _TaskPlan) -> tuple:
    # canonical dtype: what jnp.asarray(m.data) will produce at run time
    return tuple(
        jax.ShapeDtypeStruct(
            tuple(plan.mmaps[mi].shape),
            jax.dtypes.canonicalize_dtype(np.dtype(plan.mmaps[mi].dtype)))
        for mi in tp.mmap_ids)


def _phase_probe(plan: _Plan, tp: _TaskPlan, fn: Callable,
                 rec: Optional[_Recorder]) -> Callable:
    """The single firing body shared by the counting pass (abstract, via
    eval_shape) and the real lowering (traced into the while_loop)."""

    def probe(state, chans, mmaps):
        ctx = _Ctx(dict(zip(tp.chan_ids, chans)),
                   dict(zip(tp.mmap_ids, mmaps)), plan.ring_impl)
        args = tuple(_instantiate(t, ctx, plan, tp.inst, rec)
                     for t in tp.t_args)
        kw = {k: _instantiate(t, ctx, plan, tp.inst, rec)
              for k, t in tp.t_kwargs.items()}
        new_state = fn(state, *args, **kw)
        return (new_state,
                tuple(ctx.chans[ci] for ci in tp.chan_ids),
                tuple(ctx.mmaps[mi] for mi in tp.mmap_ids))

    return probe


def _count_phase(plan: _Plan, tp: _TaskPlan, label: str, fn: Callable,
                 count: int) -> _PhasePlan:
    rec = _Recorder(tp.inst)
    probe = _phase_probe(plan, tp, fn, rec)
    spec = _state_spec(tp.state0)
    try:
        out_state, _, _ = jax.eval_shape(
            probe, spec, _chan_specs(plan, tp), _mmap_specs(plan, tp))
    except (SynthesisError, ChannelMisuse, GraphValidationError):
        raise
    except Exception as e:
        raise SynthesisError(
            f"task {tp.inst.name!r}: phase {label!r} failed to trace "
            f"({type(e).__name__}: {e}); step-function bodies must be "
            f"jax-traceable with static I/O rates") from e
    got = jax.tree.map(lambda x: (tuple(x.shape), np.dtype(x.dtype)),
                       out_state)
    want = jax.tree.map(lambda x: (tuple(x.shape), np.dtype(x.dtype)), spec)
    if got != want:
        raise SynthesisError(
            f"task {tp.inst.name!r}: phase {label!r} changed the state "
            f"spec from {want} to {got}; step state must be shape- and "
            f"dtype-stable across firings")
    for ci, r in rec.reads.items():
        c = plan.channels[ci]
        if r > c.capacity:
            raise SynthesisError(
                f"task {tp.inst.name!r}: phase {label!r} reads {r} tokens "
                f"per firing from channel {c.name!r} of capacity "
                f"{c.capacity}; it could never fire")
    for ci, w in rec.writes.items():
        c = plan.channels[ci]
        if w > c.capacity:
            raise SynthesisError(
                f"task {tp.inst.name!r}: phase {label!r} writes {w} tokens "
                f"per firing to channel {c.name!r} of capacity "
                f"{c.capacity}; it could never fire")
    return _PhasePlan(label=label, fn=fn, count=count, reads=rec.reads,
                      writes=rec.writes, mmap_loads=rec.mmap_loads,
                      mmap_stores=rec.mmap_stores,
                      mmap_load_ops=rec.mmap_load_ops,
                      mmap_store_ops=rec.mmap_store_ops)


# ---------------------------------------------------------------------------
# the whole-graph program
# ---------------------------------------------------------------------------

def _port_carry0(port: AsyncMMap) -> tuple:
    """Initial latency-queue carry for one async port: the device copy of
    the buffer, two fixed-``depth`` in-flight rings (read: addr+due;
    write: addr+due+value), and the six always-on request counters."""
    data = jnp.asarray(port.data)
    d = port.depth
    zv = jnp.zeros((d,), jnp.int32)
    zs = jnp.zeros((), jnp.int32)
    return (data,
            zv, zv, zs, zs,                                  # read queue
            zv, zv, jnp.zeros((d,) + data.shape[1:], data.dtype),
            zs, zs,                                          # write queue
            zs, zs, zs, zs, zs, zs)                          # counters

# _port_carry0 tuple indices (shared by the program and the stats fill)
_P_DATA, _P_RADDR, _P_RDUE, _P_RHEAD, _P_RSIZE = 0, 1, 2, 3, 4
_P_WADDR, _P_WDUE, _P_WVAL, _P_WHEAD, _P_WSIZE = 5, 6, 7, 8, 9
_P_ACC_R, _P_DEL_R, _P_ACC_W, _P_DEL_W, _P_MAX_R, _P_MAX_W = \
    10, 11, 12, 13, 14, 15


def _guard_tables(plan: _Plan):
    """Static fused-guard tables shared by the single-device and
    partitioned programs: per (task, phase) read/write token needs over
    every channel, and the cumulative phase bounds (padded with
    int32-max so shorter tasks never advance past their last phase).
    Returns ``(need_r, need_w, bounds_or_None, n_ph_max)``."""
    n_tasks = len(plan.tasks)
    n_chans = len(plan.channels)
    n_ph_max = max((len(tp.phases) for tp in plan.tasks), default=1)
    need_r_np = np.zeros((n_tasks, n_ph_max, max(n_chans, 1)), np.int32)
    need_w_np = np.zeros_like(need_r_np)
    for ti, tp in enumerate(plan.tasks):
        for pi, ph in enumerate(tp.phases):
            for ci, r in ph.reads.items():
                need_r_np[ti, pi, ci] = r
            for ci, w in ph.writes.items():
                need_w_np[ti, pi, ci] = w
    bounds_np = None
    if n_ph_max > 1:
        bounds_np = np.full((n_tasks, n_ph_max - 1),
                            np.iinfo(np.int32).max, np.int32)
        for ti, tp in enumerate(plan.tasks):
            b = tp.bounds[:-1]
            bounds_np[ti, :len(b)] = b
    return need_r_np, need_w_np, bounds_np, n_ph_max


def _rebase_port_dues(pc: tuple, sweeps) -> tuple:
    """Rewrite one port carry's due stamps from chunk-local absolute
    sweeps to "sweeps remaining" (in-use slots only; free slots zero),
    so a restored snapshot replays response timing against a fresh
    chunk's counter."""
    d = pc[_P_RADDR].shape[0]
    iota = jnp.arange(d, dtype=jnp.int32)
    in_r = ((iota - pc[_P_RHEAD]) % d) < pc[_P_RSIZE]
    in_w = ((iota - pc[_P_WHEAD]) % d) < pc[_P_WSIZE]
    out = list(pc)
    out[_P_RDUE] = jnp.where(in_r, pc[_P_RDUE] - sweeps, 0)
    out[_P_WDUE] = jnp.where(in_w, pc[_P_WDUE] - sweeps, 0)
    return tuple(out)


def _build_program(plan: _Plan, resumable: bool = False) -> Callable:
    """One jitted function for the whole graph.

    carry = (chans, states, mmaps, ports, fires, progress, sweeps,
    maxocc); one while_loop iteration is one *sweep*: every task instance
    gets one guarded chance to fire, then every async port gets one
    service step.  The loop runs until every task exhausted its firing
    budget and every port drained its in-flight window, or a full sweep
    made no progress (the compiled analogue of the engines' deadlock
    detection).

    Firing guards are evaluated *fused at sweep start*: one
    :func:`repro.kernels.ring.eval_guards` call computes every task's
    fire predicate from the occupancy vector.  This is sound — and
    stall-for-stall equivalent to the old sequential mid-sweep guards —
    because each channel has one producer and one consumer: a consumer's
    available tokens can only shrink through its own firing, and a
    producer's free space only through its own, so a guard true at sweep
    start is still true when the task's effects apply in task order.

    Each async port is a fixed-``depth`` latency queue: the service step
    accepts queued requests issue-ahead (up to ``depth`` outstanding per
    direction), stamps them due ``latency`` sweeps ahead, and delivers
    due responses in per-port FIFO order — deferring, never dropping,
    when the response ring is full.  That is exactly the simulator's
    ``AsyncMMap.pump`` contract, so a port-using graph keeps its
    bit-identical coroutine twin.

    With ``resumable=True`` the program instead takes the full channel
    and port state, the firing counters and a sweep budget as inputs and
    returns the complete carry: ``program(states0, mmaps0, chans0,
    ports0, fires0, max_sweeps)`` runs at most ``max_sweeps`` sweeps and
    hands back ``(chans, states, mmaps, ports, fires, progress, sweeps,
    maxocc, sizes)`` — the ``lax.while_loop`` carry *is* the snapshot,
    which is how the recovery subsystem (:mod:`repro.ft.recovery`)
    checkpoints compiled runs between carry sweeps.  In-flight port
    requests stamp their due sweep against the *chunk-local* sweep
    counter, so before returning, every latency-queue due entry is
    rebased to "sweeps remaining" (``due - sweeps`` for in-use slots) —
    a snapshot restored into a fresh chunk replays delivery timing
    exactly.  Both variants trace the identical sweep body, so a chunked
    resumable run lands on the same fires — and therefore bit-identical
    channel/mmap/port contents — as one uninterrupted program."""
    caps = [c.capacity for c in plan.channels]
    totals = np.asarray([tp.total for tp in plan.tasks], np.int32)
    n_chans = len(plan.channels)
    n_tasks = len(plan.tasks)
    ring_impl = plan.ring_impl
    need_r_np, need_w_np, bounds_np, n_ph_max = _guard_tables(plan)

    def _service_ports(chans, ports, sweeps):
        """One per-sweep service step for every port: deliver due
        responses (FIFO, reads then writes), then accept queued requests
        into freed window slots (reads then writes) — the order
        ``AsyncMMap.pump`` re-pumps after each delivery."""
        chans = list(chans)
        ports = list(ports)
        activity = jnp.zeros((), jnp.bool_)
        waiting = jnp.zeros((), jnp.bool_)
        for pi, port in enumerate(plan.ports):
            d, lat = port.depth, port.latency
            ra, rd, wa, wd, wr = plan.port_chan_ids[pi]
            (data, r_addr, r_due, r_head, r_size,
             w_addr, w_due, w_val, w_head, w_size,
             acc_r, del_r, acc_w, del_w, max_r, max_w) = ports[pi]
            nrow = data.shape[0]
            # deliver due reads (up to ``depth`` per sweep, as response
            # ring space allows)
            rd_buf, rd_head, rd_size = chans[rd]
            for _ in range(d):
                can = ((r_size > 0) & (r_due[r_head] <= sweeps)
                       & (rd_size < caps[rd]))
                addr = jnp.clip(r_addr[r_head], 0, nrow - 1)
                slot = (rd_head + rd_size) % caps[rd]
                rd_buf = rd_buf.at[slot].set(
                    jnp.where(can, data[addr], rd_buf[slot]))
                rd_size = rd_size + can.astype(jnp.int32)
                r_head = jnp.where(can, (r_head + 1) % d, r_head)
                r_size = r_size - can.astype(jnp.int32)
                del_r = del_r + can.astype(jnp.int32)
                activity = activity | can
            chans[rd] = (rd_buf, rd_head, rd_size)
            # deliver due writes
            wr_buf, wr_head, wr_size = chans[wr]
            for _ in range(d):
                can = ((w_size > 0) & (w_due[w_head] <= sweeps)
                       & (wr_size < caps[wr]))
                addr = jnp.clip(w_addr[w_head], 0, nrow - 1)
                data = data.at[addr].set(
                    jnp.where(can, w_val[w_head], data[addr]))
                slot = (wr_head + wr_size) % caps[wr]
                wr_buf = wr_buf.at[slot].set(
                    jnp.where(can, True, wr_buf[slot]))
                wr_size = wr_size + can.astype(jnp.int32)
                w_head = jnp.where(can, (w_head + 1) % d, w_head)
                w_size = w_size - can.astype(jnp.int32)
                del_w = del_w + can.astype(jnp.int32)
                activity = activity | can
            chans[wr] = (wr_buf, wr_head, wr_size)
            # accept queued reads into the in-flight window
            ra_buf, ra_head, ra_size = chans[ra]
            for _ in range(d):
                can = (ra_size > 0) & (r_size < d)
                addr = ra_buf[ra_head]
                ra_head = jnp.where(can, (ra_head + 1) % caps[ra], ra_head)
                ra_size = ra_size - can.astype(jnp.int32)
                slot = (r_head + r_size) % d
                r_addr = r_addr.at[slot].set(
                    jnp.where(can, addr, r_addr[slot]))
                r_due = r_due.at[slot].set(
                    jnp.where(can, sweeps + lat, r_due[slot]))
                r_size = r_size + can.astype(jnp.int32)
                acc_r = acc_r + can.astype(jnp.int32)
                activity = activity | can
            chans[ra] = (ra_buf, ra_head, ra_size)
            max_r = jnp.maximum(max_r, r_size)
            # accept queued writes (need an address AND a value token)
            wa_buf, wa_head, wa_size = chans[wa]
            wd_buf, wd_head, wd_size = chans[wd]
            for _ in range(d):
                can = (wa_size > 0) & (wd_size > 0) & (w_size < d)
                addr = wa_buf[wa_head]
                val = wd_buf[wd_head]
                wa_head = jnp.where(can, (wa_head + 1) % caps[wa], wa_head)
                wa_size = wa_size - can.astype(jnp.int32)
                wd_head = jnp.where(can, (wd_head + 1) % caps[wd], wd_head)
                wd_size = wd_size - can.astype(jnp.int32)
                slot = (w_head + w_size) % d
                w_addr = w_addr.at[slot].set(
                    jnp.where(can, addr, w_addr[slot]))
                w_due = w_due.at[slot].set(
                    jnp.where(can, sweeps + lat, w_due[slot]))
                w_val = w_val.at[slot].set(
                    jnp.where(can, val, w_val[slot]))
                w_size = w_size + can.astype(jnp.int32)
                acc_w = acc_w + can.astype(jnp.int32)
                activity = activity | can
            chans[wa] = (wa_buf, wa_head, wa_size)
            chans[wd] = (wd_buf, wd_head, wd_size)
            max_w = jnp.maximum(max_w, w_size)
            # liveness: an in-flight request due in the future is progress
            # pending — keep sweeping (the compiled analogue of the
            # simulators fast-forwarding the clock to the next delivery)
            iota = jnp.arange(d, dtype=jnp.int32)
            waiting = waiting | jnp.any(
                (iota < r_size) & (r_due[(r_head + iota) % d] > sweeps))
            waiting = waiting | jnp.any(
                (iota < w_size) & (w_due[(w_head + iota) % d] > sweeps))
            ports[pi] = (data, r_addr, r_due, r_head, r_size,
                         w_addr, w_due, w_val, w_head, w_size,
                         acc_r, del_r, acc_w, del_w, max_r, max_w)
        return chans, tuple(ports), activity, waiting

    def _run_loop(chans0, states0, mmaps0, ports0, fires0, budget):
        totals_v = jnp.asarray(totals)
        maxocc0 = jnp.zeros((max(n_chans, 1),), jnp.int32)

        def cond(carry):
            _, _, _, ports, fires, progress, sweeps, _ = carry
            pending = jnp.zeros((), jnp.bool_)
            for p in ports:
                pending = pending | (p[_P_RSIZE] > 0) | (p[_P_WSIZE] > 0)
            live = progress & (jnp.any(fires < totals_v) | pending)
            if budget is not None:
                live = live & (sweeps < budget)
            return live

        def body(carry):
            chans, states, mmaps, ports, fires, _, sweeps, maxocc = carry
            chans = list(chans)
            states = list(states)
            mmaps = list(mmaps)
            # fused start-of-sweep guard evaluation: one kernel for every
            # task's fire predicate
            if n_ph_max > 1:
                phase_vec = jnp.sum(
                    (fires[:, None] >= jnp.asarray(bounds_np))
                    .astype(jnp.int32), axis=1)
            else:
                phase_vec = jnp.zeros((n_tasks,), jnp.int32)
            live = fires < totals_v
            if n_chans:
                sizes_vec = jnp.stack([c[2] for c in chans])
                nr = jnp.take_along_axis(
                    jnp.asarray(need_r_np), phase_vec[:, None, None],
                    axis=1)[:, 0, :]
                nw = jnp.take_along_axis(
                    jnp.asarray(need_w_np), phase_vec[:, None, None],
                    axis=1)[:, 0, :]
                fire_vec = eval_guards(
                    sizes_vec, jnp.asarray(caps, jnp.int32), nr, nw, live,
                    impl=ring_impl)
            else:
                fire_vec = live
            for ti, tp in enumerate(plan.tasks):
                fire = fire_vec[ti]
                phase = phase_vec[ti] if len(tp.phases) > 1 else None

                branches = [
                    _fire_branch(plan, tp, ph.fn) for ph in tp.phases]

                def fire_fn(sub, branches=branches, phase=phase):
                    if len(branches) == 1:
                        return branches[0](sub)
                    return jax.lax.switch(phase, branches, sub)

                sub = (states[ti],
                       tuple(chans[ci] for ci in tp.chan_ids),
                       tuple(mmaps[mi] for mi in tp.mmap_ids))
                new_sub = jax.lax.cond(fire, fire_fn, lambda s: s, sub)
                states[ti] = new_sub[0]
                for k, ci in enumerate(tp.chan_ids):
                    chans[ci] = new_sub[1][k]
                for k, mi in enumerate(tp.mmap_ids):
                    mmaps[mi] = new_sub[2][k]
                if tp.chan_ids:
                    # occupancy highwater sampled after every firing (a
                    # sweep-boundary sample would always see drained FIFOs)
                    maxocc = maxocc.at[jnp.asarray(tp.chan_ids)].max(
                        jnp.stack([chans[ci][2] for ci in tp.chan_ids]))
            fires = fires + fire_vec.astype(jnp.int32)
            fired_any = jnp.any(fire_vec)
            if plan.ports:
                chans, ports, activity, waiting = _service_ports(
                    chans, ports, sweeps)
                progress = fired_any | activity | waiting
                maxocc = jnp.maximum(
                    maxocc, jnp.stack([c[2] for c in chans]))
            else:
                progress = fired_any
            return (tuple(chans), tuple(states), tuple(mmaps), ports,
                    fires, progress, sweeps + 1, maxocc)

        carry0 = (chans0, tuple(states0), tuple(mmaps0), tuple(ports0),
                  fires0, jnp.ones((), jnp.bool_),
                  jnp.zeros((), jnp.int32), maxocc0)
        return jax.lax.while_loop(cond, body, carry0)

    if resumable:
        def program(states0: tuple, mmaps0: tuple, chans0: tuple,
                    ports0: tuple, fires0, max_sweeps):
            chans, states, mmaps, ports, fires, progress, sweeps, maxocc \
                = _run_loop(tuple(tuple(c) for c in chans0), states0,
                            mmaps0, tuple(tuple(p) for p in ports0),
                            jnp.asarray(fires0, jnp.int32),
                            jnp.asarray(max_sweeps, jnp.int32))
            ports = tuple(_rebase_port_dues(p, sweeps) for p in ports)
            sizes = (jnp.stack([c[2] for c in chans]) if n_chans
                     else jnp.zeros((1,), jnp.int32))
            return (tuple(chans), tuple(states), tuple(mmaps), ports,
                    fires, progress, sweeps, maxocc, sizes)
    else:
        def program(states0: tuple, mmaps0: tuple, ports0: tuple):
            chans0 = tuple(
                (jnp.zeros((c.capacity,) + c.shape, _canon_dtype(c.dtype)),
                 jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
                for c in plan.channels)
            fires0 = jnp.zeros((len(plan.tasks),), jnp.int32)
            chans, states, mmaps, ports, fires, _, sweeps, maxocc = \
                _run_loop(chans0, states0, mmaps0, ports0, fires0, None)
            sizes = (jnp.stack([c[2] for c in chans]) if n_chans
                     else jnp.zeros((max(n_chans, 1),), jnp.int32))
            return tuple(mmaps), ports, fires, sweeps, maxocc, sizes

    return program


def _fire_branch(plan: _Plan, tp: _TaskPlan, fn: Callable) -> Callable:
    probe = _phase_probe(plan, tp, fn, rec=None)

    def branch(sub):
        state, chs, mms = sub
        return probe(state, chs, mms)

    return branch


def _build_partitioned_program(plan: _Plan, owners, mesh,
                               axis: str = "dev") -> Callable:
    """The multi-device twin of :func:`_build_program`: one
    ``shard_map`` whose per-device body runs the whole-graph while_loop,
    firing only the tasks ``owners`` assigns to that device.

    The partition invariant is *sweep-synchronous SPMD*: at every sweep
    start, all devices agree on every channel's head/size and every
    task's firing count, and agree on the buffer contents of every
    channel they might touch.  The sweep body maintains it with zero
    mid-sweep communication:

    * **guards/fires are replicated by construction** — ``eval_guards``
      reads only head/size vectors, which every device carries and
      advances identically, so the fire vector (and hence phase indices
      and the loop condition) needs no collective;
    * **a device executes only its own tasks** (``lax.cond`` on
      ``owner == axis_index``), paying compute only for its partition;
    * **head/size are re-synchronized by arithmetic, not exchange**: a
      firing's pops/pushes move head/size by the *static* per-phase
      token counts, so sweep-end metadata is recomputed globally as
      ``head += Σ fired·reads``, ``size += Σ fired·(writes - reads)``
      and overwritten on every device — for locally-fired tasks this
      lands exactly where the local ring ops already did;
    * **cut channels ship their ring once per sweep**: pops never
      mutate buffer contents and pushes land at ``(head+size+i) % cap``
      — invariant under the consumer's concurrent pops — so sending the
      producer's post-push buffer to the consumer via ``lax.ppermute``
      (and adopting it with a ``where`` on the receiver) restores full
      agreement.  Intra-device channels never hit the interconnect.

    Under this invariant the partitioned run executes the identical
    firing schedule, pops the identical values and writes the identical
    mmap cells as the single-device lowering — bit-identical outputs.
    (Channel ``max_occupancy`` becomes sweep-granular: sampled from
    sweep-end sizes rather than after every firing.)

    Outputs are stacked across the mesh axis (every leaf gains a
    leading device dimension); the caller selects the authoritative row
    — the writer task's owner for each written mmap, any row for the
    replicated fires/sweeps/maxocc/sizes.
    """
    if plan.ports:
        raise SynthesisError(
            "partitioned lowering does not cover async_mmap ports")
    caps = [c.capacity for c in plan.channels]
    totals = np.asarray([tp.total for tp in plan.tasks], np.int32)
    n_chans = len(plan.channels)
    n_tasks = len(plan.tasks)
    ring_impl = plan.ring_impl
    need_r_np, need_w_np, bounds_np, n_ph_max = _guard_tables(plan)
    owners_np = np.asarray(owners, np.int32)
    caps_np = np.asarray(caps, np.int32) if n_chans else \
        np.zeros((1,), np.int32)
    # cut edges: (channel, producer device, consumer device)
    prod = [-1] * n_chans
    cons = [-1] * n_chans
    for ti, tp in enumerate(plan.tasks):
        for ph in tp.phases:
            for ci in ph.writes:
                prod[ci] = ti
            for ci in ph.reads:
                cons[ci] = ti
    cuts = [(ci, int(owners_np[prod[ci]]), int(owners_np[cons[ci]]))
            for ci in range(n_chans)
            if prod[ci] >= 0 and cons[ci] >= 0
            and owners_np[prod[ci]] != owners_np[cons[ci]]]

    def device_body(states0, mmaps0):
        me = jax.lax.axis_index(axis)
        owners_v = jnp.asarray(owners_np)
        totals_v = jnp.asarray(totals)
        caps_v = jnp.asarray(caps_np)
        chans0 = tuple(
            (jnp.zeros((c.capacity,) + c.shape, _canon_dtype(c.dtype)),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
            for c in plan.channels)
        fires0 = jnp.zeros((n_tasks,), jnp.int32)
        maxocc0 = jnp.zeros((max(n_chans, 1),), jnp.int32)

        def cond(carry):
            _, _, _, fires, progress, sweeps, _ = carry
            return progress & jnp.any(fires < totals_v)

        def body(carry):
            chans, states, mmaps, fires, _, sweeps, maxocc = carry
            chans = list(chans)
            states = list(states)
            mmaps = list(mmaps)
            if n_ph_max > 1:
                phase_vec = jnp.sum(
                    (fires[:, None] >= jnp.asarray(bounds_np))
                    .astype(jnp.int32), axis=1)
            else:
                phase_vec = jnp.zeros((n_tasks,), jnp.int32)
            live = fires < totals_v
            if n_chans:
                heads0 = jnp.stack([c[1] for c in chans])
                sizes0 = jnp.stack([c[2] for c in chans])
                nr = jnp.take_along_axis(
                    jnp.asarray(need_r_np), phase_vec[:, None, None],
                    axis=1)[:, 0, :]
                nw = jnp.take_along_axis(
                    jnp.asarray(need_w_np), phase_vec[:, None, None],
                    axis=1)[:, 0, :]
                fire_vec = eval_guards(
                    sizes0, jnp.asarray(caps, jnp.int32), nr, nw, live,
                    impl=ring_impl)
            else:
                fire_vec = live
            for ti, tp in enumerate(plan.tasks):
                fire = fire_vec[ti] & (owners_v[ti] == me)
                phase = phase_vec[ti] if len(tp.phases) > 1 else None

                branches = [
                    _fire_branch(plan, tp, ph.fn) for ph in tp.phases]

                def fire_fn(sub, branches=branches, phase=phase):
                    if len(branches) == 1:
                        return branches[0](sub)
                    return jax.lax.switch(phase, branches, sub)

                sub = (states[ti],
                       tuple(chans[ci] for ci in tp.chan_ids),
                       tuple(mmaps[mi] for mi in tp.mmap_ids))
                new_sub = jax.lax.cond(fire, fire_fn, lambda s: s, sub)
                states[ti] = new_sub[0]
                for k, ci in enumerate(tp.chan_ids):
                    chans[ci] = new_sub[1][k]
                for k, mi in enumerate(tp.mmap_ids):
                    mmaps[mi] = new_sub[2][k]
            if n_chans:
                fv = fire_vec.astype(jnp.int32)
                delta_r = jnp.sum(fv[:, None] * nr, axis=0)
                delta_w = jnp.sum(fv[:, None] * nw, axis=0)
                new_heads = (heads0 + delta_r) % jnp.maximum(caps_v, 1)
                new_sizes = sizes0 + delta_w - delta_r
                for ci, src, dst in cuts:
                    buf = chans[ci][0]
                    recv = jax.lax.ppermute(buf, axis, [(src, dst)])
                    chans[ci] = (jnp.where(me == dst, recv, buf),) \
                        + chans[ci][1:]
                chans = [(chans[ci][0], new_heads[ci], new_sizes[ci])
                         for ci in range(n_chans)]
                maxocc = jnp.maximum(maxocc, new_sizes)
            fires = fires + fire_vec.astype(jnp.int32)
            return (tuple(chans), tuple(states), tuple(mmaps), fires,
                    jnp.any(fire_vec), sweeps + 1, maxocc)

        carry0 = (chans0, tuple(states0), tuple(mmaps0), fires0,
                  jnp.ones((), jnp.bool_), jnp.zeros((), jnp.int32),
                  maxocc0)
        chans, states, mmaps, fires, _, sweeps, maxocc = \
            jax.lax.while_loop(cond, body, carry0)
        sizes = (jnp.stack([c[2] for c in chans]) if n_chans
                 else jnp.zeros((max(n_chans, 1),), jnp.int32))
        out = (tuple(mmaps), fires, sweeps, maxocc, sizes)
        # every leaf gains a leading device axis; the concatenated
        # global view lets the host pick the authoritative row
        return jax.tree.map(lambda x: jnp.asarray(x)[None], out)

    from jax.sharding import PartitionSpec as _P

    def program(states0: tuple, mmaps0: tuple):
        return _shard_map(device_body, mesh=mesh,
                          in_specs=(_P(), _P()), out_specs=_P(axis),
                          check_vma=False)(states0, mmaps0)

    return program


# ---------------------------------------------------------------------------
# the fourth engine
# ---------------------------------------------------------------------------

class CompiledEngine(EngineBase):
    """Whole-graph synthesis engine (the compiled twin of the simulators).

    ``run(top, *args)`` elaborates the graph by executing the *wiring*
    bodies (parents that instantiate channels and invoke children) and
    recording every :class:`StepTask` leaf, then lowers the entire graph
    into one jitted XLA program through the compile cache, executes it,
    writes mmap results back into the host buffers, and returns a real
    :class:`SimReport` (fires, token counts, occupancy highwater marks,
    sweep count as ``switches``).

    A graph outside the synthesizable subset raises
    :class:`SynthesisError` naming the offending task/channel; a lowered
    graph that stalls (a genuine dataflow deadlock) returns
    ``ok=False`` with the blocked tasks listed, mirroring the simulation
    engines.
    """

    name = "compiled"

    def __init__(self, track_stats: bool = False, cache: Any = None,
                 ring_impl: Optional[str] = None, mesh: Any = None,
                 placement: Any = None, **kw):
        super().__init__(track_stats, **kw)
        self.cache = cache          # CompileCache | None=default | False=off
        # interconnect kernel backend: "pallas" | "interpret" | "xla";
        # None defers to $REPRO_RING_IMPL / the backend default
        self.ring_impl = ring_impl
        # multi-device floorplan: mesh = device count (int) or a 1-D
        # jax.sharding.Mesh; placement = manual {task_name: device}
        # overrides (partial pins OK) or a floorplan.Placement to reuse
        self.mesh = mesh
        self.placement = placement
        self._cur: Optional[TaskInstance] = None
        # post-run introspection (tests / benchmarks)
        self.compile_source: Optional[str] = None
        self.compile_key: Optional[str] = None
        self.n_sweeps = 0
        self.placement_used = None      # floorplan.Placement after a run
        self.partition_source = None    # "partitioned" | "memo" | None

    # -- runtime protocol: any live stream op means "not step form" ----------
    def _refuse(self, op: str):
        name = self._cur.name if self._cur is not None else "<top>"
        raise SynthesisError(
            f"task {name!r} performed a runtime stream operation ({op}) "
            f"during synthesis elaboration: it is not in step-function "
            f"form.  CompiledEngine only lowers graphs whose leaf tasks "
            f"are StepTask definitions (availability-routed designs using "
            f"peek/select stay on the simulation engines); see "
            f"docs/synthesis.md")

    def wait(self, chan, side):
        self._refuse("wait")

    def wait_many(self, keys):
        self._refuse("select")

    def push(self, chan, tok):
        self._refuse("write")

    def pop(self, chan):
        self._refuse("read")

    def push_burst(self, chan, toks):
        self._refuse("write_burst")

    def pop_burst(self, chan, n):
        self._refuse("read_burst")

    def schedule_async(self, delay, deliver):
        # compiled runs service async_mmap ports inside the lowered
        # program (the latency queue in the while_loop carry); a live
        # delivery callback during elaboration means a *wiring body*
        # performed memory I/O, which is not step-function form
        name = self._cur.name if self._cur is not None else "<top>"
        raise SynthesisError(
            f"task {name!r} issued an async_mmap request during synthesis "
            f"elaboration: memory I/O belongs in StepTask phase bodies "
            f"(where it lowers to the compiled latency queue), not in "
            f"wiring bodies; see docs/synthesis.md")

    # -- elaboration ---------------------------------------------------------
    def spawn(self, inst: TaskInstance) -> None:
        self._register(inst)
        if getattr(inst.fn, "is_step_task", False):
            return                  # recorded; lowered later, never executed
        self._exec(inst)            # wiring body runs inline

    def join(self, insts: list[TaskInstance]) -> None:
        for i in insts:
            if i.state == "failed" and i.error is not None:
                raise i.error

    def _exec(self, inst: TaskInstance) -> Any:
        prev = self._cur
        self._cur = inst
        set_context(self, inst)
        depth = builder_stack_depth()
        inst.state = "running"
        try:
            a, k = bind_streams(inst)
            out = inst.fn(*a, **k)
            join_pending_builders(depth)
            inst.state = "finished"
            return out
        except BaseException as e:
            inst.state = "failed"
            inst.error = e
            raise
        finally:
            self._cur = prev
            set_context(self, prev)

    # -- lowering ------------------------------------------------------------
    def _lower(self) -> tuple[_Plan, Any]:
        step_insts = [i for i in self.instances
                      if getattr(i.fn, "is_step_task", False)]
        if not step_insts:
            raise SynthesisError(
                "graph contains no step-function tasks; CompiledEngine "
                "lowers StepTask leaves (see docs/synthesis.md)")
        plan = _Plan()
        plan.ring_impl = resolve_impl("ring", RING_ENV, RING_CHOICES,
                                      fallback="xla",
                                      impl=getattr(self, "ring_impl", None))
        bound = []
        for inst in step_insts:
            a, k = bind_streams(inst)
            bound.append((inst, a, k))
        for inst, a, k in bound:
            if inst.fn.close_outputs:
                raise SynthesisError(
                    f"task {inst.name!r} closes its outputs (EoT) after "
                    f"its last firing; EoT-terminated streams are outside "
                    f"the synthesizable subset — downstream consumers "
                    f"must terminate by firing count instead")
            tp = _TaskPlan(inst=inst, task=inst.fn)
            tp.t_args = tuple(_build_template(x, plan, tp) for x in a)
            tp.t_kwargs = {key: _build_template(x, plan, tp)
                           for key, x in k.items()}
            tp.state0 = _materialize_state(inst.fn.init)
            plan.tasks.append(tp)
        for c in plan.channels:
            if c.shape is None or not isinstance(c.dtype, np.dtype):
                raise SynthesisError(
                    f"channel {c.name!r} has no declared element spec; "
                    f"synthesis sizes its ring buffer from "
                    f"Channel(dtype=..., shape=...)")
        for tp in plan.tasks:
            for label, fn, count in tp.task.phases():
                tp.phases.append(
                    _count_phase(plan, tp, label, fn, count))
            if not tp.phases:
                raise SynthesisError(
                    f"task {tp.inst.name!r} has zero total firings")
        # async ports: record each port's direction from its member-channel
        # traffic, and refuse read+write ports — a read racing an in-flight
        # write to the same buffer resolves by response timing, which the
        # sweep schedule must not be allowed to decide
        for tp in plan.tasks:
            for ph in tp.phases:
                for ci in list(ph.reads) + list(ph.writes):
                    c = plan.channels[ci]
                    pi = plan._port_idx.get(id(c.iface)) \
                        if c.iface is not None else None
                    if pi is None:
                        continue
                    p = plan.ports[pi]
                    if c is p._raddr or c is p._rdata:
                        plan.port_dirs[pi].add("read")
                    else:
                        plan.port_dirs[pi].add("write")
        for pi, dirs in enumerate(plan.port_dirs):
            if dirs >= {"read", "write"}:
                raise SynthesisError(
                    f"async_mmap {plan.ports[pi].name!r} is both read and "
                    f"written in the synthesized graph: read-after-write "
                    f"through an async port depends on response timing; "
                    f"use one port per direction (or route the value "
                    f"through a channel)")
        # schedule-independence: an mmap written by one task and read by
        # another would make results depend on sweep order — refuse
        readers: dict[int, set] = {}
        writers: dict[int, set] = {}
        for tp in plan.tasks:
            for ph in tp.phases:
                for mi in ph.mmap_loads:
                    readers.setdefault(mi, set()).add(tp.inst.name)
                for mi in ph.mmap_stores:
                    writers.setdefault(mi, set()).add(tp.inst.name)
        for mi, ws in writers.items():
            m = plan.mmaps[mi]
            if len(ws) > 1:
                raise SynthesisError(
                    f"mmap {m.name!r} has multiple writers {sorted(ws)} "
                    f"(one-writer rule)")
            others = readers.get(mi, set()) - ws
            if others:
                raise SynthesisError(
                    f"mmap {m.name!r} is written by {sorted(ws)} and read "
                    f"by {sorted(others)}: cross-task read-after-write "
                    f"through memory is schedule-dependent; route the "
                    f"value through a channel instead")
        graph = extract_graph(self)
        try:
            graph.validate()
        except GraphValidationError as e:
            raise SynthesisError(f"graph failed validation: {e}") from e
        return plan, graph

    def _cache_key(self, graph, args: tuple, ring_impl: str = "xla",
                   extra: str = "") -> str:
        h = hashlib.sha256()
        h.update(graph.structural_hash().encode())
        h.update(_stable_repr(aval_signature(args, {})).encode())
        h.update(f"jax:{jax.__version__}:{jax.default_backend()}:"
                 f"{SYNTH_SCHEMA}:ring={ring_impl}:{extra}".encode())
        return h.hexdigest()

    # -- run -----------------------------------------------------------------
    def _elaborate(self, top: Callable, *args, **kwargs):
        """Execute the wiring bodies and lower to a plan, without running
        the compiled program.  Returns ``(plan, graph, result)`` — the
        shared front half of :meth:`run`, also used by the recovery
        subsystem to build its chunk schedule.  The caller owns
        ``clear_context()``."""
        root = TaskInstance(top, args, kwargs, detach=False, parent=None,
                            name=getattr(top, "__name__", "top"))
        set_context(self, None)
        self._register(root)
        result = self._exec(root)
        plan, graph = self._lower()
        return plan, graph, result

    def run(self, top: Callable, *args, **kwargs) -> SimReport:
        t0 = time.perf_counter()
        try:
            plan, graph, result = self._elaborate(top, *args, **kwargs)
            if self.mesh is not None:
                return self._run_partitioned(plan, graph, result, t0)
            states0 = tuple(tp.state0 for tp in plan.tasks)
            mmaps0 = tuple(jnp.asarray(m.data) for m in plan.mmaps)
            ports0 = tuple(_port_carry0(p) for p in plan.ports)
            program = _build_program(plan)
            key = self._cache_key(graph, (states0, mmaps0, ports0),
                                  plan.ring_impl)
            self.compile_key = key
            if self.cache is False:
                exe = jax.jit(program).lower(
                    states0, mmaps0, ports0).compile()
                source = "compiled"
            else:
                cc = self.cache if self.cache is not None \
                    else default_cache()
                exe, source = cc.compile_cached(
                    program, (states0, mmaps0, ports0), key=key)
            self.compile_source = source
            mm_final, ports_final, fires, sweeps, maxocc, sizes = exe(
                states0, mmaps0, ports0)
            self._writeback_ports(plan, ports_final)
            self._fill_port_stats(plan, ports_final)
            return self._finish(plan, mm_final, fires, sweeps, maxocc,
                                sizes, result, t0)
        finally:
            clear_context()

    def _resolve_mesh(self):
        """``self.mesh`` as a validated 1-D Mesh: an int means "the
        first N visible devices on a fresh axis" (see
        ``distributed.sharding.device_mesh``)."""
        from jax.sharding import Mesh
        if isinstance(self.mesh, Mesh):
            mesh = self.mesh
            if len(mesh.axis_names) != 1:
                raise SynthesisError(
                    f"partitioned synthesis takes a 1-D mesh; got axes "
                    f"{mesh.axis_names!r} — task graphs are placed along "
                    f"one device axis")
            return mesh
        from ..distributed.sharding import device_mesh
        return device_mesh(int(self.mesh))

    def _run_partitioned(self, plan: _Plan, graph, result,
                         t0: float) -> SimReport:
        """The mesh floorplan path: place tasks (cached artifact), lower
        the partitioned program (cached executable), pick authoritative
        output rows, and finish exactly like the single-device path."""
        from .floorplan import Placement, plan_placement
        mesh = self._resolve_mesh()
        axis = mesh.axis_names[0]
        n_dev = mesh.devices.size
        if plan.ports:
            users = sorted({tp.inst.name for tp in plan.tasks
                            if tp.port_ids})
            raise SynthesisError(
                f"partitioned synthesis does not cover async_mmap ports "
                f"yet: port(s) {[p.name for p in plan.ports]} bound by "
                f"task(s) {users} — the latency queue is serviced by one "
                f"device's sweep and has no cut protocol; run the graph "
                f"single-device (mesh=None) or route the memory traffic "
                f"through channels")
        if isinstance(self.placement, Placement):
            placement = self.placement
            if placement.n_devices != n_dev or \
                    len(placement.owners) != len(plan.tasks):
                raise SynthesisError(
                    f"placement reuse mismatch: placement is for "
                    f"{placement.n_devices} devices / "
                    f"{len(placement.owners)} tasks, graph has "
                    f"{len(plan.tasks)} tasks on a {n_dev}-device mesh")
        else:
            placement = plan_placement(
                plan, graph, n_dev, overrides=self.placement,
                cache=self.cache)
        self.placement_used = placement
        self.partition_source = placement.source
        owners = np.asarray(placement.owners, np.int32)

        states0 = tuple(tp.state0 for tp in plan.tasks)
        mmaps0 = tuple(jnp.asarray(m.data) for m in plan.mmaps)
        program = _build_partitioned_program(plan, owners, mesh, axis)
        key = self._cache_key(
            graph, (states0, mmaps0), plan.ring_impl,
            extra=f"mesh={axis}:{n_dev}:owners={owners.tolist()}")
        self.compile_key = key
        if self.cache is False:
            exe = jax.jit(program).lower(states0, mmaps0).compile()
            source = "compiled"
        else:
            cc = self.cache if self.cache is not None else default_cache()
            exe, source = cc.compile_cached(
                program, (states0, mmaps0), key=key)
        self.compile_source = source
        mm_st, fires_st, sweeps_st, maxocc_st, sizes_st = exe(
            states0, mmaps0)
        # authoritative rows: the writer's owner per written mmap (the
        # one-writer rule makes it unique); anything replicated -> row 0
        writer_of = {}
        for ti, tp in enumerate(plan.tasks):
            for ph in tp.phases:
                for mi in ph.mmap_stores:
                    writer_of[mi] = int(owners[ti])
        mm_final = tuple(np.asarray(m)[writer_of.get(mi, 0)]
                         for mi, m in enumerate(mm_st))
        fires = np.asarray(fires_st)[0]
        sweeps = np.asarray(sweeps_st)[0]
        maxocc = np.asarray(maxocc_st)[0]
        sizes = np.asarray(sizes_st)[0]
        return self._finish(plan, mm_final, fires, sweeps, maxocc, sizes,
                            result, t0)

    def _finish(self, plan: _Plan, mm_final, fires, sweeps, maxocc,
                sizes, result, t0: float) -> SimReport:
        """Shared back half of a compiled run: write mmaps back to host,
        fill stats, diagnose stalls, build the report."""
        fires = np.asarray(fires)
        maxocc = np.asarray(maxocc)
        sizes = np.asarray(sizes)
        self.n_sweeps = self.switches = int(sweeps)
        self._writeback(plan, mm_final)
        self._fill_stats(plan, fires, maxocc)
        totals = np.asarray([tp.total for tp in plan.tasks], np.int32)
        stuck = bool(np.any(fires < totals))
        for tp, f, tot in zip(plan.tasks, fires, totals):
            tp.inst.state = "finished" if f >= tot else "blocked"
        err = None
        if stuck:
            blocked = [tp.inst.name for tp, f, tot
                       in zip(plan.tasks, fires, totals) if f < tot]
            occ = {c.name: int(s)
                   for c, s in zip(plan.channels, sizes)}
            err = (f"synthesized graph stalled after {self.switches} "
                   f"sweeps; blocked tasks: {blocked}; channel "
                   f"occupancy at stall: {occ}")
            # unified diagnostic (docs/robustness.md): the same
            # structured payload the simulation engines attach
            self._deadlock_report = DeadlockReport(
                engine=self.name, reason="stall",
                blocked=[(n, "stalled") for n in blocked],
                occupancy=occ, clock=self.switches,
                switches=self.switches,
                wall_s=time.perf_counter() - t0)
        return self._report(not stuck, time.perf_counter() - t0, err,
                            result)

    def _writeback(self, plan: _Plan, mm_final: tuple) -> None:
        """Copy device results back into the host mmap buffers, so the
        same ``check()`` that verifies a simulation run verifies the
        compiled run."""
        written = set()
        for tp in plan.tasks:
            for ph in tp.phases:
                written.update(ph.mmap_stores)
        for mi in sorted(written):
            m = plan.mmaps[mi]
            out = np.asarray(mm_final[mi])
            if isinstance(m.data, np.ndarray):
                np.copyto(m.data, out)
            else:
                m.data = out

    def _writeback_ports(self, plan: _Plan, ports_final: tuple) -> None:
        for pi, (p, pc) in enumerate(zip(plan.ports, ports_final)):
            if "write" not in plan.port_dirs[pi]:
                continue
            out = np.asarray(pc[_P_DATA])
            if isinstance(p.data, np.ndarray):
                np.copyto(p.data, out)
            else:
                p.data = out

    def _fill_port_stats(self, plan: _Plan, ports_final: tuple) -> None:
        """Fill each port's always-on request counters from the compiled
        carry, so ``SimReport.interfaces`` carries real numbers — the
        compiled twin of ``AsyncMMap.pump``'s bookkeeping."""
        for p, pc in zip(plan.ports, ports_final):
            p.read_reqs = int(pc[_P_ACC_R])
            p.read_resps = int(pc[_P_DEL_R])
            p.write_reqs = int(pc[_P_ACC_W])
            p.write_resps = int(pc[_P_DEL_W])
            p.max_outstanding_reads = int(pc[_P_MAX_R])
            p.max_outstanding_writes = int(pc[_P_MAX_W])
            # service-side member-channel totals (the task side is
            # reconstructed from firing counters in _fill_stats)
            p._raddr.total_read += p.read_reqs
            p._rdata.total_written += p.read_resps
            p._waddr.total_read += p.write_reqs
            p._wdata.total_read += p.write_reqs
            p._wresp.total_written += p.write_resps

    def _fill_stats(self, plan: _Plan, fires: np.ndarray,
                    maxocc: np.ndarray) -> None:
        """Reconstruct per-channel token counts and occupancy highwater
        marks from the firing counters — the compiled analogue of the
        simulators' per-push statistics."""
        for tp, f in zip(plan.tasks, fires):
            start = 0
            for ph in tp.phases:
                k = int(np.clip(int(f) - start, 0, ph.count))
                start += ph.count
                for ci, r in ph.reads.items():
                    plan.channels[ci].total_read += r * k
                for ci, w in ph.writes.items():
                    plan.channels[ci].total_written += w * k
                if self.track_stats:
                    for mi, n in ph.mmap_loads.items():
                        plan.mmaps[mi].loads += ph.mmap_load_ops[mi] * k
                        plan.mmaps[mi].load_elems += n * k
                    for mi, n in ph.mmap_stores.items():
                        plan.mmaps[mi].stores += ph.mmap_store_ops[mi] * k
                        plan.mmaps[mi].store_elems += n * k
        for c, occ in zip(plan.channels, maxocc):
            c.max_occupancy = int(occ)


def elaborate_step_graph(top: Callable, *args, **kwargs):
    """Elaborate a step-form graph without executing it.

    Runs the wiring bodies under a throwaway :class:`CompiledEngine` and
    returns ``(plan, graph, result)`` — the lowering plan (task order,
    phase I/O rates, channel/mmap tables), the validated graph IR, and
    the top body's return value.  Raises :class:`SynthesisError` for
    graphs outside the synthesizable subset.  This is the entry point
    the recovery subsystem uses to derive its abstract sweep schedule:
    the plan it returns is byte-for-byte the one ``CompiledEngine.run``
    would lower, so chunk quotas computed from it apply to every engine.

    NOTE: elaboration *executes the wiring bodies*, which binds channel
    endpoints to the throwaway engine's task instances.  Callers that
    re-run the same channel objects under another engine must reset the
    endpoints first (see ``repro.ft.recovery._reset_endpoints``).
    """
    eng = CompiledEngine()
    try:
        return eng._elaborate(top, *args, **kwargs)
    finally:
        clear_context()


ENGINES["compiled"] = CompiledEngine
