"""Ambient runtime context.

Task bodies are plain Python functions that call blocking stream methods
(``read``/``write``/``peek``/...).  How a blocked operation suspends depends
on which engine is running the task: the sequential engine raises, the
thread engine waits on a condition variable, the coroutine engine performs a
cooperative hand-off.  Streams discover the active engine (and the current
task handle) through this thread-local context.
"""

from __future__ import annotations

import threading
from typing import Any, Optional

_tls = threading.local()


def current_runtime() -> Optional[Any]:
    return getattr(_tls, "runtime", None)


def current_task() -> Optional[Any]:
    return getattr(_tls, "task", None)


def set_context(runtime: Any, task: Any) -> None:
    _tls.runtime = runtime
    _tls.task = task


def clear_context() -> None:
    _tls.runtime = None
    _tls.task = None


def current_builder_stack() -> list:
    """Stack of TaskBuilder objects being populated in the current context.

    ``repro.task()`` pushes onto this stack; the graph elaborator pops it to
    discover the children a parent task instantiated (Section 3.1.3).
    """
    if not hasattr(_tls, "builders"):
        _tls.builders = []
    return _tls.builders
