"""Task instantiation interface (paper Section 3.1.3, Listing 5).

A *task* is a plain Python function.  A *parent* task instantiates channels
and child tasks::

    def PageRank(...):
        vertex_req = repro.channel(capacity=2)
        repro.task() \
            .invoke(VertexHandler, vertex_req, ..., detach=True) \
            .invoke(Ctrl, vertex_req, ...)

mirroring ``tapa::task().invoke<tapa::detach>(...)``.  Children are spawned
immediately on ``invoke`` by the active engine; the parent joins all
non-detached children when its body returns (TAPA joins at the destructor of
the ``tapa::task()`` temporary — end-of-body is the Python analogue and is
also what ``with repro.task() as t:`` gives explicitly).

Interface binding (Section 3.1.2, Table 2): a ``Channel`` argument is
converted to an :class:`IStream` or :class:`OStream` view according to the
callee's parameter annotation; unannotated parameters receive a lazy
``AutoStream`` that binds its direction on first use.  ``MMap`` /
``AsyncMMap`` arguments bind as external-memory interfaces (a raw ndarray
passed for an ``MMap``-annotated parameter is wrapped on the way in),
``Scalar`` wrappers unwrap to their value, and plain Python scalars are
recorded as scalar interfaces.  Every binding registers endpoints for
graph metadata extraction (Section 3.4) — the per-definition interface
table — and is validated to the one-producer/one-consumer rule for
channels, the one-writer rule for mmaps, and the one-port rule for
async_mmaps (Section 3.1.1).
"""

from __future__ import annotations

import inspect
import itertools
from typing import Any, Callable, Optional

import numpy as np

from .channel import Channel, IStream, OStream
from .context import current_builder_stack, current_runtime, current_task
from .errors import ChannelMisuse
from .interface import (AsyncMMap, Interface, InterfaceBinding, MMap,
                        Scalar)

_inst_uid = itertools.count()


class TaskInstance:
    """One instantiation of a task definition (paper Table 3 distinguishes
    #Tasks from #Task Instances; this is the latter)."""

    __slots__ = ("uid", "fn", "args", "kwargs", "detach", "name", "parent",
                 "children", "state", "error", "level", "interfaces",
                 "wait_site")

    def __init__(self, fn: Callable, args: tuple, kwargs: dict,
                 detach: bool, parent: Optional["TaskInstance"],
                 name: Optional[str] = None):
        self.uid = next(_inst_uid)
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.detach = detach
        self.name = name or f"{getattr(fn, '__name__', 'task')}#{self.uid}"
        self.parent = parent
        self.children: list[TaskInstance] = []
        self.state = "created"   # created/running/blocked/finished/failed
        self.error: Optional[BaseException] = None
        self.wait_site: Optional[str] = None  # "read <chan>" etc. while blocked
        self.level = 0 if parent is None else parent.level + 1
        # per-parameter interface table (kind/dtype/direction), filled by
        # bind_streams — the row data behind Graph.definitions[*].interfaces
        self.interfaces: list[InterfaceBinding] = []

    @property
    def definition(self) -> Callable:
        """The task *definition* this instance stems from.  Hierarchical
        code generation (Section 3.3) compiles per-definition, not
        per-instance."""
        return self.fn

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<TaskInstance {self.name} {self.state}>"


class AutoStream:
    """Direction-unbound stream view; binds to IStream/OStream on first use.

    Used when a child's parameter has no IStream/OStream annotation.
    """

    def __init__(self, chan: Channel, owner: TaskInstance):
        self._chan = chan
        self._owner = owner
        self._view: Any = None

    def _as(self, cls):
        if self._view is None:
            side = "consumer" if cls is IStream else "producer"
            self._chan._bind(side, self._owner)
            self._view = cls(self._chan)
        elif not isinstance(self._view, cls):
            raise ChannelMisuse(
                f"task {self._owner.name} uses channel {self._chan.name!r} "
                f"as both producer and consumer")
        return self._view

    @property
    def channel(self) -> Channel:
        return self._chan

    # consumer ops
    def empty(self): return self._as(IStream).empty()
    def read(self): return self._as(IStream).read()
    def read_burst(self, n): return self._as(IStream).read_burst(n)
    def read_transaction(self): return self._as(IStream).read_transaction()
    def peek(self): return self._as(IStream).peek()
    def eot(self): return self._as(IStream).eot()
    def open(self): return self._as(IStream).open()
    def try_read(self): return self._as(IStream).try_read()
    def try_read_burst(self, n): return self._as(IStream).try_read_burst(n)
    def try_peek(self): return self._as(IStream).try_peek()
    def try_eot(self): return self._as(IStream).try_eot()
    def try_open(self): return self._as(IStream).try_open()
    def __iter__(self): return iter(self._as(IStream))
    # producer ops
    def full(self): return self._as(OStream).full()
    def write(self, v): return self._as(OStream).write(v)
    def write_burst(self, seq): return self._as(OStream).write_burst(seq)
    def close(self): return self._as(OStream).close()
    def try_write(self, v): return self._as(OStream).try_write(v)
    def try_write_burst(self, seq):
        return self._as(OStream).try_write_burst(seq)
    def try_close(self): return self._as(OStream).try_close()


_ANN_KINDS = (("IStream", IStream), ("OStream", OStream), ("AsyncMMap", AsyncMMap),
              ("MMap", MMap), ("Scalar", Scalar))


def _annotation_kind(ann: Any) -> Optional[type]:
    """Map a parameter annotation to its interface class — IStream/OStream/
    MMap/AsyncMMap/Scalar (handles string annotations from
    ``from __future__ import annotations``; AsyncMMap is matched before
    MMap, which is a substring of it)."""
    if ann is inspect.Parameter.empty:
        return None
    if isinstance(ann, str):
        for token, cls in _ANN_KINDS:
            if token in ann:
                return cls
        return None
    origin = getattr(ann, "__origin__", ann)
    for _, cls in _ANN_KINDS:
        if origin is cls or (inspect.isclass(origin) and
                             issubclass(origin, cls)):
            return cls
    return None


_annotation_direction = _annotation_kind        # pre-interface-layer alias

_SCALAR_TYPES = (bool, int, float, complex, str, bytes, np.integer,
                 np.floating, np.bool_)


def _record(inst: TaskInstance, name: str, kind: str, dtype: Any,
            ref: Any) -> InterfaceBinding:
    b = InterfaceBinding(name, kind, dtype, ref, inst)
    inst.interfaces.append(b)
    return b


def _convert_arg(val: Any, ann: Any, inst: TaskInstance, name: str) -> Any:
    """Convert one argument to its bound interface view and record the
    binding in the instance's interface table."""
    if isinstance(val, Channel):
        d = _annotation_kind(ann)
        if d is IStream:
            val._bind("consumer", inst)
            _record(inst, name, "istream", val.dtype, val)
            return IStream(val)
        if d is OStream:
            val._bind("producer", inst)
            _record(inst, name, "ostream", val.dtype, val)
            return OStream(val)
        # direction unannotated: binds on first use, table resolves late
        _record(inst, name, "stream", val.dtype, val)
        return AutoStream(val, inst)
    if isinstance(val, (MMap, AsyncMMap)):
        b = _record(inst, name, val.iface_kind, str(val.dtype), val)
        val._bind_task(b)
        return val
    if isinstance(val, Scalar):
        _record(inst, name, "scalar", val.dtype, val)
        return val.value
    if isinstance(val, np.ndarray) and _annotation_kind(ann) is MMap:
        # annotation-driven wrap: a raw array passed for an MMap parameter.
        # The wrapper is adopted from the engine (one per buffer per run)
        # so it joins interface_set and the one-writer rule holds across
        # tasks that received the same raw array.
        rt = current_runtime()
        wrapped = rt.adopt_mmap(val, name) if rt is not None \
            else MMap(val, name=name)
        b = _record(inst, name, "mmap", str(wrapped.dtype), wrapped)
        wrapped._bind_task(b)
        return wrapped
    if val is None:
        _record(inst, name, "null", "none", None)
        return val
    if isinstance(val, _SCALAR_TYPES):
        _record(inst, name, "scalar", type(val).__name__, None)
        return val
    if isinstance(val, (list, tuple)) and any(
            isinstance(v, (Channel, Interface)) for v in val):
        conv = [_convert_arg(v, ann, inst, f"{name}[{i}]")
                for i, v in enumerate(val)]
        return type(val)(conv) if isinstance(val, tuple) else conv
    _record(inst, name, "other", type(val).__name__, None)
    return val


def bind_streams(inst: TaskInstance) -> tuple[tuple, dict]:
    """Resolve the instance's channel/interface args into bound views,
    registering endpoints and the per-parameter interface table.  Called by
    engines just before running the body."""
    fn = inst.fn
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        params = []
    inst.interfaces = []
    args = []
    for i, a in enumerate(inst.args):
        ann = inspect.Parameter.empty
        name = f"arg{i}"
        if i < len(params):
            p = params[i]
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
                ann = p.annotation
                name = p.name
            elif p.kind is p.VAR_POSITIONAL:
                ann = p.annotation
                name = f"{p.name}[{i - len(params) + 1}]"
        elif params and params[-1].kind is params[-1].VAR_POSITIONAL:
            ann = params[-1].annotation
            name = f"{params[-1].name}[{i - len(params) + 1}]"
        args.append(_convert_arg(a, ann, inst, name))
    by_name = {p.name: p.annotation for p in params}
    kwargs = {
        k: _convert_arg(v, by_name.get(k, inspect.Parameter.empty), inst, k)
        for k, v in inst.kwargs.items()
    }
    return tuple(args), kwargs


class TaskBuilder:
    """``repro.task()`` — collects ``invoke`` calls and joins at body end.

    Children are spawned *immediately* by the active engine (so detached
    infinite tasks such as the paper's VertexHandler can serve requests
    while the parent is still invoking siblings).
    """

    def __init__(self):
        self._children: list[TaskInstance] = []
        self._joined = False
        rt = current_runtime()
        if rt is None:
            raise RuntimeError(
                "repro.task() outside a running program; use repro.run(...)")
        self._rt = rt
        self._parent = current_task()
        current_builder_stack().append(self)

    def invoke(self, fn: Callable, *args, detach: bool = False,
               name: Optional[str] = None, **kwargs) -> "TaskBuilder":
        # an explicit ``name`` is preserved exactly (no uid suffix) —
        # crash-fault sites and recovery chunk re-invocation
        # (ft/recovery.py) rely on stable instance names across restarts
        inst = TaskInstance(fn, args, kwargs, detach, self._parent, name)
        if self._parent is not None:
            self._parent.children.append(inst)
        self._children.append(inst)
        self._rt.spawn(inst)
        return self

    # ``invoke(fn, ...) * 4`` sugar is intentionally absent: the paper's
    # interface repeats .invoke once per instance; we keep that shape.

    def join(self) -> None:
        """Wait for all non-detached children (parent-finishes-last rule,
        Section 3.1.3)."""
        if self._joined:
            return
        self._joined = True
        stack = current_builder_stack()
        if self in stack:
            stack.remove(self)
        self._rt.join([c for c in self._children if not c.detach])

    # context-manager form
    def __enter__(self) -> "TaskBuilder":
        return self

    def __exit__(self, et, ev, tb) -> None:
        if et is None:
            self.join()
        else:
            # error path: don't mask the original exception with a join
            self._joined = True
            stack = current_builder_stack()
            if self in stack:
                stack.remove(self)


def task() -> TaskBuilder:
    """``tapa::task()`` (Listing 5)."""
    return TaskBuilder()


def builder_stack_depth() -> int:
    """Engines snapshot this before running a task body, so that nested
    (sequential-engine) elaboration only joins the body's own builders."""
    return len(current_builder_stack())


def join_pending_builders(depth: int = 0) -> None:
    """Join builders the current task body created but did not join —
    engines call this when a task body returns, emulating TAPA's
    end-of-full-expression destructor join."""
    stack = current_builder_stack()
    while len(stack) > depth:
        stack[-1].join()
