"""Task instantiation interface (paper Section 3.1.3, Listing 5).

A *task* is a plain Python function.  A *parent* task instantiates channels
and child tasks::

    def PageRank(...):
        vertex_req = repro.channel(capacity=2)
        repro.task() \
            .invoke(VertexHandler, vertex_req, ..., detach=True) \
            .invoke(Ctrl, vertex_req, ...)

mirroring ``tapa::task().invoke<tapa::detach>(...)``.  Children are spawned
immediately on ``invoke`` by the active engine; the parent joins all
non-detached children when its body returns (TAPA joins at the destructor of
the ``tapa::task()`` temporary — end-of-body is the Python analogue and is
also what ``with repro.task() as t:`` gives explicitly).

Stream-direction binding: a ``Channel`` argument is converted to an
:class:`IStream` or :class:`OStream` view according to the callee's
parameter annotation; unannotated parameters receive a lazy ``AutoStream``
that binds its direction on first use.  Either way the channel's
producer/consumer endpoints are registered for graph metadata extraction
(Section 3.4) and validated to the one-producer/one-consumer rule
(Section 3.1.1).
"""

from __future__ import annotations

import inspect
import itertools
from typing import Any, Callable, Optional

from .channel import Channel, IStream, OStream
from .context import current_builder_stack, current_runtime, current_task
from .errors import ChannelMisuse

_inst_uid = itertools.count()


class TaskInstance:
    """One instantiation of a task definition (paper Table 3 distinguishes
    #Tasks from #Task Instances; this is the latter)."""

    __slots__ = ("uid", "fn", "args", "kwargs", "detach", "name", "parent",
                 "children", "state", "error", "level")

    def __init__(self, fn: Callable, args: tuple, kwargs: dict,
                 detach: bool, parent: Optional["TaskInstance"],
                 name: Optional[str] = None):
        self.uid = next(_inst_uid)
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.detach = detach
        self.name = name or f"{getattr(fn, '__name__', 'task')}#{self.uid}"
        self.parent = parent
        self.children: list[TaskInstance] = []
        self.state = "created"   # created/running/blocked/finished/failed
        self.error: Optional[BaseException] = None
        self.level = 0 if parent is None else parent.level + 1

    @property
    def definition(self) -> Callable:
        """The task *definition* this instance stems from.  Hierarchical
        code generation (Section 3.3) compiles per-definition, not
        per-instance."""
        return self.fn

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<TaskInstance {self.name} {self.state}>"


class AutoStream:
    """Direction-unbound stream view; binds to IStream/OStream on first use.

    Used when a child's parameter has no IStream/OStream annotation.
    """

    def __init__(self, chan: Channel, owner: TaskInstance):
        self._chan = chan
        self._owner = owner
        self._view: Any = None

    def _as(self, cls):
        if self._view is None:
            side = "consumer" if cls is IStream else "producer"
            self._chan._bind(side, self._owner)
            self._view = cls(self._chan)
        elif not isinstance(self._view, cls):
            raise ChannelMisuse(
                f"task {self._owner.name} uses channel {self._chan.name!r} "
                f"as both producer and consumer")
        return self._view

    @property
    def channel(self) -> Channel:
        return self._chan

    # consumer ops
    def empty(self): return self._as(IStream).empty()
    def read(self): return self._as(IStream).read()
    def read_burst(self, n): return self._as(IStream).read_burst(n)
    def read_transaction(self): return self._as(IStream).read_transaction()
    def peek(self): return self._as(IStream).peek()
    def eot(self): return self._as(IStream).eot()
    def open(self): return self._as(IStream).open()
    def try_read(self): return self._as(IStream).try_read()
    def try_read_burst(self, n): return self._as(IStream).try_read_burst(n)
    def try_peek(self): return self._as(IStream).try_peek()
    def try_eot(self): return self._as(IStream).try_eot()
    def try_open(self): return self._as(IStream).try_open()
    def __iter__(self): return iter(self._as(IStream))
    # producer ops
    def full(self): return self._as(OStream).full()
    def write(self, v): return self._as(OStream).write(v)
    def write_burst(self, seq): return self._as(OStream).write_burst(seq)
    def close(self): return self._as(OStream).close()
    def try_write(self, v): return self._as(OStream).try_write(v)
    def try_write_burst(self, seq):
        return self._as(OStream).try_write_burst(seq)
    def try_close(self): return self._as(OStream).try_close()


def _annotation_direction(ann: Any) -> Optional[type]:
    """Map a parameter annotation to IStream/OStream (handles string
    annotations from ``from __future__ import annotations``)."""
    if ann is inspect.Parameter.empty:
        return None
    if isinstance(ann, str):
        if "IStream" in ann:
            return IStream
        if "OStream" in ann:
            return OStream
        return None
    origin = getattr(ann, "__origin__", ann)
    if origin is IStream or (inspect.isclass(origin) and
                             issubclass(origin, IStream)):
        return IStream
    if origin is OStream or (inspect.isclass(origin) and
                             issubclass(origin, OStream)):
        return OStream
    return None


def _convert_arg(val: Any, ann: Any, inst: TaskInstance) -> Any:
    """Convert channel arguments to directed stream views."""
    if isinstance(val, Channel):
        d = _annotation_direction(ann)
        if d is IStream:
            val._bind("consumer", inst)
            return IStream(val)
        if d is OStream:
            val._bind("producer", inst)
            return OStream(val)
        return AutoStream(val, inst)
    if isinstance(val, (list, tuple)) and any(
            isinstance(v, Channel) for v in val):
        conv = [_convert_arg(v, ann, inst) for v in val]
        return type(val)(conv) if isinstance(val, tuple) else conv
    return val


def bind_streams(inst: TaskInstance) -> tuple[tuple, dict]:
    """Resolve the instance's channel args into stream views, registering
    channel endpoints.  Called by engines just before running the body."""
    fn = inst.fn
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        params = []
    args = []
    for i, a in enumerate(inst.args):
        ann = inspect.Parameter.empty
        if i < len(params):
            p = params[i]
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
                ann = p.annotation
            elif p.kind is p.VAR_POSITIONAL:
                ann = p.annotation
        args.append(_convert_arg(a, ann, inst))
    by_name = {p.name: p.annotation for p in params}
    kwargs = {
        k: _convert_arg(v, by_name.get(k, inspect.Parameter.empty), inst)
        for k, v in inst.kwargs.items()
    }
    return tuple(args), kwargs


class TaskBuilder:
    """``repro.task()`` — collects ``invoke`` calls and joins at body end.

    Children are spawned *immediately* by the active engine (so detached
    infinite tasks such as the paper's VertexHandler can serve requests
    while the parent is still invoking siblings).
    """

    def __init__(self):
        self._children: list[TaskInstance] = []
        self._joined = False
        rt = current_runtime()
        if rt is None:
            raise RuntimeError(
                "repro.task() outside a running program; use repro.run(...)")
        self._rt = rt
        self._parent = current_task()
        current_builder_stack().append(self)

    def invoke(self, fn: Callable, *args, detach: bool = False,
               name: Optional[str] = None, **kwargs) -> "TaskBuilder":
        inst = TaskInstance(fn, args, kwargs, detach, self._parent, name)
        if self._parent is not None:
            self._parent.children.append(inst)
        self._children.append(inst)
        self._rt.spawn(inst)
        return self

    # ``invoke(fn, ...) * 4`` sugar is intentionally absent: the paper's
    # interface repeats .invoke once per instance; we keep that shape.

    def join(self) -> None:
        """Wait for all non-detached children (parent-finishes-last rule,
        Section 3.1.3)."""
        if self._joined:
            return
        self._joined = True
        stack = current_builder_stack()
        if self in stack:
            stack.remove(self)
        self._rt.join([c for c in self._children if not c.detach])

    # context-manager form
    def __enter__(self) -> "TaskBuilder":
        return self

    def __exit__(self, et, ev, tb) -> None:
        if et is None:
            self.join()
        else:
            # error path: don't mask the original exception with a join
            self._joined = True
            stack = current_builder_stack()
            if self in stack:
                stack.remove(self)


def task() -> TaskBuilder:
    """``tapa::task()`` (Listing 5)."""
    return TaskBuilder()


def builder_stack_depth() -> int:
    """Engines snapshot this before running a task body, so that nested
    (sequential-engine) elaboration only joins the body's own builders."""
    return len(current_builder_stack())


def join_pending_builders(depth: int = 0) -> None:
    """Join builders the current task body created but did not join —
    engines call this when a task body returns, emulating TAPA's
    end-of-full-expression destructor join."""
    stack = current_builder_stack()
    while len(stack) > depth:
        stack[-1].join()
