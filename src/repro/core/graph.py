"""Task-graph metadata extraction (paper Section 3.4, Table 3).

After elaboration/simulation the engine holds every :class:`TaskInstance`
and :class:`Channel`.  This module turns that into a queryable IR:

* the set of task *definitions* vs task *instances* (the distinction that
  drives hierarchical code generation, Section 3.3),
* the communication topology (which instance produces/consumes which
  channel, token "types", capacities),
* validation of the one-producer/one-consumer/same-parent rule
  (Section 3.1.1),
* a Graphviz/DOT export for inspection.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .channel import Channel
from .compile_cache import _enc, _stable_repr, structural_digest
from .engines import EngineBase, SimReport, ENGINES
from .errors import GraphValidationError
from .interface import AsyncMMap, MMap, Scalar
from .task import TaskInstance


@dataclass(frozen=True)
class InterfaceInfo:
    """One parameter row of a definition's interface table — the analogue
    of the argument metadata TAPA's Clang pass extracts from a kernel
    signature (paper Section 3.4 / Table 2): which interface *kind* each
    parameter binds (stream / mmap / async_mmap / scalar), its token or
    element dtype, and the observed transfer direction."""
    param: str
    kind: str        # istream/ostream/mmap/async_mmap/scalar/null/other
    dtype: str
    direction: str   # in/out/read/write/readwrite/unused


def _merge_interface_rows(insts: list) -> tuple:
    """Fold per-instance binding rows into one per-definition table.

    Instances of one definition may disagree benignly (an edge PE gets
    ``None`` where an interior PE gets a channel; an unused mmap binding
    records no direction) — ``null``/``unused`` defer to any concrete
    observation.  Genuinely conflicting kinds (istream in one instance,
    ostream in another) are preserved as ``mixed`` so ``validate`` can
    reject them.
    """
    order: list = []
    kinds: dict = {}
    dtypes: dict = {}
    dirs: dict = {}
    for inst in insts:
        for b in inst.interfaces:
            k = b.resolved_kind()
            d = b.resolved_direction()
            if b.param not in kinds:
                order.append(b.param)
                kinds[b.param], dtypes[b.param], dirs[b.param] = \
                    k, str(b.dtype), {d}
                continue
            cur = kinds[b.param]
            if cur in ("null", "stream") and k not in ("null", "stream"):
                kinds[b.param], dtypes[b.param] = k, str(b.dtype)
            elif k not in ("null", "stream", cur) and cur != "null":
                kinds[b.param] = "mixed"
            dirs[b.param].add(d)
    def direction(p):
        ds = dirs[p] - {"unused"}
        if not ds:
            return "unused"
        if ds == {"read", "write"} or "readwrite" in ds:
            return "readwrite"
        return ds.pop() if len(ds) == 1 else "mixed"
    return tuple(InterfaceInfo(p, kinds[p], dtypes[p], direction(p))
                 for p in order)


@dataclass(frozen=True)
class ChannelInfo:
    """One row of the graph's channel table — the typed, fixed-capacity
    FIFO record whole-graph synthesis sizes its ring buffers from
    (hlslib: channels must be typed hardware objects for the lowering to
    exist).  ``producer``/``consumer`` are instance names (None when
    unbound); ``dtype``/``shape`` are the declared element spec (None when
    undeclared — simulation tolerates it, synthesis refuses)."""
    name: str
    capacity: int
    dtype: Any
    shape: Optional[tuple]
    producer: Optional[str]
    consumer: Optional[str]


@dataclass(frozen=True)
class DefinitionInfo:
    """One task definition and all instances stamped out from it."""
    fn: Callable
    name: str
    n_instances: int
    instance_names: tuple
    defn_hash: str = ""
    # per-parameter interface table (paper Table 2 kinds), merged across
    # the definition's instances
    interfaces: tuple = ()


@dataclass
class Graph:
    """Elaborated task graph."""
    instances: list[TaskInstance]
    channels: list[Channel]
    interfaces: list = field(default_factory=list)   # MMap/AsyncMMap objects
    report: Optional[SimReport] = None
    _defs: dict = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------
    @property
    def definitions(self) -> list[DefinitionInfo]:
        """Unique task definitions (paper Table 3 "#Tasks").

        Keyed by the *structural* hash from
        :mod:`repro.core.compile_cache` — the same key hierarchical codegen
        dedups on — so two separately-created closures with the same body
        count as one definition, exactly as they compile as one.
        """
        if not self._defs:
            by_hash: dict[str, list[TaskInstance]] = {}
            # per-sweep digest memo: N instances of K definitions need K
            # content hashes (ids are stable while self.instances pins
            # the fn objects)
            digests: dict = {}
            for i in self.instances:
                d = digests.get(id(i.fn))
                if d is None:
                    d = digests[id(i.fn)] = structural_digest(i.fn)
                by_hash.setdefault(d, []).append(i)
            self._defs = {
                h: DefinitionInfo(
                    fn=insts[0].fn,
                    name=getattr(insts[0].fn, "__name__",
                                 repr(insts[0].fn)),
                    n_instances=len(insts),
                    instance_names=tuple(x.name for x in insts),
                    defn_hash=h,
                    interfaces=_merge_interface_rows(insts))
                for h, insts in by_hash.items()
            }
        return list(self._defs.values())

    @property
    def n_tasks(self) -> int:
        return len(self.definitions)

    @property
    def n_instances(self) -> int:
        return len(self.instances)

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def dedup_factor(self) -> float:
        """instances / definitions — the repetition hierarchical codegen
        exploits (e.g. gaussian: 564/15 in the paper's Table 3)."""
        return self.n_instances / max(1, self.n_tasks)

    # ------------------------------------------------------------------
    @property
    def channel_info(self) -> list[ChannelInfo]:
        """The per-channel table (name/capacity/element spec/endpoints) —
        what synthesis consumes, and what Table 3's "#Channels" column
        summarizes."""
        return [
            ChannelInfo(
                name=c.name, capacity=c.capacity, dtype=c.dtype,
                shape=c.shape,
                producer=getattr(c.producer, "name", None),
                consumer=getattr(c.consumer, "name", None))
            for c in self.channels if c.iface is None]

    def structural_hash(self) -> str:
        """Stable digest of the whole graph's *structure*: every instance's
        definition hash plus its argument wiring — channels by dense index
        + capacity + element spec, mmaps/async_mmaps by aval and identity
        index, scalars and plain values by content — and the parent tree.

        Equal hashes mean "lowering this graph produces the same program
        for the same input avals": mmap buffer *values* and instance/
        channel *names* are excluded, so N graphs over N datasets share
        one whole-graph compile (the key ``repro.core.synth`` caches on).
        """
        chan_idx = {id(c): i for i, c in enumerate(self.channels)}
        iface_idx = {id(m): i for i, m in enumerate(self.interfaces)}
        inst_idx = {id(i): n for n, i in enumerate(self.instances)}
        digests: dict[int, str] = {}
        h = hashlib.sha256()

        def enc_arg(v: Any) -> None:
            if isinstance(v, Channel):
                h.update(
                    f"chan:{chan_idx.get(id(v), -1)}:{v.capacity}:"
                    f"{v.dtype}:{v.shape}".encode())
            elif isinstance(v, AsyncMMap):
                # latency and depth shape the lowered latency queue (the
                # in-flight window is part of the compiled carry), so two
                # ports differing only in timing compile separately
                h.update(f"{v.iface_kind}:{iface_idx.get(id(v), -1)}:"
                         f"{v.dtype}:{tuple(v.shape)}:"
                         f"lat{v.latency}:d{v.depth}".encode())
            elif isinstance(v, MMap):
                h.update(f"{v.iface_kind}:{iface_idx.get(id(v), -1)}:"
                         f"{v.dtype}:{tuple(v.shape)}".encode())
            elif isinstance(v, Scalar):
                h.update(f"scalar:{_stable_repr(v.value)}".encode())
            elif isinstance(v, (list, tuple)):
                h.update(f"seq{len(v)}".encode())
                for x in v:
                    enc_arg(x)
            elif isinstance(v, dict):
                h.update(f"map{len(v)}".encode())
                for k in sorted(v, key=_stable_repr):
                    h.update(_stable_repr(k).encode())
                    enc_arg(v[k])
            else:
                _enc(h, v)

        for inst in self.instances:
            d = digests.get(id(inst.fn))
            if d is None:
                d = digests[id(inst.fn)] = structural_digest(inst.fn)
            h.update(b"inst")
            h.update(d.encode())
            h.update(f"p{inst_idx.get(id(inst.parent), -1)}"
                     f"d{int(inst.detach)}".encode())
            for a in inst.args:
                enc_arg(a)
            for k in sorted(inst.kwargs):
                h.update(k.encode())
                enc_arg(inst.kwargs[k])
        return h.hexdigest()

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Enforce Section 3.1.1: every channel has exactly one producer and
        one consumer, both instantiated under the same parent task; every
        mmap has at most one writer; no definition binds one parameter to
        conflicting interface kinds across its instances."""
        errs = []
        for c in self.channels:
            if c.iface is not None:
                continue    # async_mmap port channel: memory is an endpoint
            # static-depth rule: a channel's capacity is part of its type
            # (tapa::channel<T, capacity>) and must stay a positive static
            # int for the ring-buffer lowering to exist
            if not isinstance(c.capacity, int) or \
                    isinstance(c.capacity, bool) or c.capacity < 1:
                errs.append(f"channel {c.name!r} has non-static depth "
                            f"{c.capacity!r}")
            if c.producer is None:
                errs.append(f"channel {c.name!r} has no producer")
            if c.consumer is None:
                errs.append(f"channel {c.name!r} has no consumer")
            if c.producer is not None and c.consumer is not None:
                if c.producer is c.consumer:
                    errs.append(f"channel {c.name!r} loops back to "
                                f"{c.producer.name}")
                elif c.producer.parent is not c.consumer.parent:
                    errs.append(
                        f"channel {c.name!r} connects tasks from different "
                        f"parents ({c.producer.name} / {c.consumer.name})")
        for m in self.interfaces:
            if isinstance(m, MMap):
                writers = {b.inst.name for b in m._by_inst.values()
                           if "write" in b.direction}
                if len(writers) > 1:
                    errs.append(f"mmap {m.name!r} has multiple writers "
                                f"{sorted(writers)} (one-writer rule)")
        for d in self.definitions:
            for row in d.interfaces:
                if row.kind == "mixed" or row.direction == "mixed":
                    errs.append(
                        f"definition {d.name!r} binds parameter "
                        f"{row.param!r} to conflicting interface kinds "
                        f"across instances")
        if errs:
            raise GraphValidationError("; ".join(errs))

    # ------------------------------------------------------------------
    def to_dot(self, placement=None) -> str:
        """GraphViz rendering; pass a ``floorplan.Placement`` (or any
        object with parallel ``task_names`` / ``owners``) to color leaf
        tasks by their assigned device and bold the cut channels."""
        owner_of = {}
        if placement is not None:
            owner_of = dict(zip(placement.task_names, placement.owners))
        # one fill per device, cycled: readable up to ~8-way meshes
        palette = ["lightblue", "palegreen", "lightsalmon", "plum",
                   "khaki", "lightpink", "aquamarine", "wheat"]
        lines = ["digraph G {", "  rankdir=LR;"]
        for i in self.instances:
            shape = "box" if i.children else "ellipse"
            style = ""
            if i.name in owner_of:
                d = int(owner_of[i.name])
                style = (f', style=filled, '
                         f'fillcolor="{palette[d % len(palette)]}"')
                lines.append(f'  t{i.uid} [label="{i.name}\\ndev{d}", '
                             f'shape={shape}{style}];')
                continue
            lines.append(f'  t{i.uid} [label="{i.name}", shape={shape}];')
        for m in self.interfaces:
            lines.append(f'  m{m.uid} [label="{m.name}\\n{m.iface_kind}", '
                         f'shape=cylinder];')
        for c in self.channels:
            if c.iface is not None:
                continue    # drawn as one memory edge per port, below
            if c.producer is not None and c.consumer is not None:
                cut = (owner_of.get(c.producer.name) is not None
                       and owner_of.get(c.consumer.name) is not None
                       and owner_of[c.producer.name]
                       != owner_of[c.consumer.name])
                style = ', style=bold, color=red' if cut else ''
                lines.append(
                    f'  t{c.producer.uid} -> t{c.consumer.uid} '
                    f'[label="{c.name}/{c.capacity}"{style}];')
        for m in self.interfaces:
            if isinstance(m, AsyncMMap):
                if m.owner is not None:
                    lines.append(f'  t{m.owner.uid} -> m{m.uid} '
                                 f'[dir=both, style=dashed, '
                                 f'label="lat={m.latency}/d={m.depth}"];')
                continue
            for b in m._by_inst.values():
                d = b.resolved_direction()
                if d in ("write", "readwrite"):
                    lines.append(f'  t{b.inst.uid} -> m{m.uid} '
                                 f'[style=dashed];')
                if d in ("read", "readwrite", "unused"):
                    lines.append(f'  m{m.uid} -> t{b.inst.uid} '
                                 f'[style=dashed];')
        lines.append("}")
        return "\n".join(lines)

    def summary(self) -> str:
        return (f"tasks={self.n_tasks} instances={self.n_instances} "
                f"channels={self.n_channels} "
                f"interfaces={len(self.interfaces)} "
                f"dedup={self.dedup_factor():.1f}x")


def extract_graph(engine: EngineBase,
                  report: Optional[SimReport] = None) -> Graph:
    """Build the metadata IR from a finished engine run (Section 3.4)."""
    chans = sorted(engine.channel_set, key=lambda c: c.uid)
    ifaces = sorted(engine.interface_set, key=lambda i: i.uid)
    return Graph(instances=list(engine.instances), channels=chans,
                 interfaces=ifaces, report=report)


def elaborate(top: Callable, *args, engine: str = "coroutine",
              validate: bool = True, **kwargs) -> Graph:
    """Run the program once in simulation and return its task graph.

    TAPA extracts metadata with a Clang pass over source; the Python-native
    equivalent is an elaboration run.  Simulation doubles as the
    correctness-verification cycle (Fig. 2), so nothing is wasted.
    """
    eng = ENGINES[engine]()
    report = eng.run(top, *args, **kwargs)
    g = extract_graph(eng, report)
    if validate and report.ok:
        g.validate()
    return g
