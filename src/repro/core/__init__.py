"""repro.core — the paper's contribution as a composable module.

C1: channels with peek/EoT/transactions + typed task interfaces
    (streams / mmap / async_mmap / scalar) + hierarchical instantiation
C2: universal software simulation (sequential / thread / coroutine engines)
C3: hierarchical (definition-deduplicated, parallel) compilation
"""

from .channel import (EOT, Channel, IStream, OStream, channel, select,
                      READABLE, WRITABLE)
from .compile_cache import (CacheStats, CompileCache, aval_signature,
                            default_cache, instance_key, lower_spec,
                            runtime_value, set_default_cache,
                            structural_digest)
from .engines import (ENGINES, CoroutineEngine, EngineBase, SequentialEngine,
                      SimReport, ThreadEngine, run)
from .errors import (ChannelMisuse, CrashFault, Deadlock, DeadlockError,
                     DeadlockReport, EndOfTransaction, GraphValidationError,
                     InjectedFault, PoisonError, ReproError,
                     SequentialSimulationError, SynthesisError, TaskKilled,
                     TransientFault)
from .faults import FaultInjector, FaultPlan
from .graph import (ChannelInfo, DefinitionInfo, Graph, InterfaceInfo,
                    elaborate, extract_graph)
from .hier_compile import (CompileReport, DataflowProgram, StageInstance,
                           build_dataflow, compile_stages, diff_definitions)
from .interface import (AsyncMMap, Interface, InterfaceBinding, MMap,
                        Scalar, async_mmap, mmap, scalar)
from .invoke import invoke
from .synth import (CompiledEngine, StepTask,   # registers ENGINES["compiled"]
                    elaborate_step_graph)
from .cost import HW, probe_compiled, task_cost
from .floorplan import Placement, placement_key, plan_placement
from .task import TaskBuilder, TaskInstance, task

__all__ = [
    "EOT", "Channel", "IStream", "OStream", "channel", "select", "READABLE",
    "WRITABLE", "ENGINES", "CoroutineEngine", "EngineBase",
    "SequentialEngine", "SimReport", "ThreadEngine", "run", "ChannelMisuse",
    "Deadlock", "DeadlockError", "DeadlockReport", "EndOfTransaction",
    "FaultInjector", "FaultPlan", "GraphValidationError", "InjectedFault",
    "PoisonError", "ReproError", "TransientFault",
    "SequentialSimulationError", "TaskKilled", "DefinitionInfo", "Graph",
    "InterfaceInfo", "elaborate", "extract_graph", "CompileReport",
    "DataflowProgram", "StageInstance", "build_dataflow", "compile_stages",
    "diff_definitions", "TaskBuilder",
    "TaskInstance", "task", "invoke", "CacheStats", "CompileCache",
    "aval_signature", "default_cache", "set_default_cache", "instance_key",
    "lower_spec", "runtime_value", "structural_digest",
    "AsyncMMap", "Interface", "InterfaceBinding", "MMap", "Scalar",
    "async_mmap", "mmap", "scalar",
    "ChannelInfo", "CompiledEngine", "StepTask", "SynthesisError",
    "CrashFault", "elaborate_step_graph",
    "HW", "probe_compiled", "task_cost",
    "Placement", "placement_key", "plan_placement",
]
