"""repro.core — the paper's contribution as a composable module.

C1: channels with peek/EoT/transactions + hierarchical task instantiation
C2: universal software simulation (sequential / thread / coroutine engines)
C3: hierarchical (definition-deduplicated, parallel) compilation
"""

from .channel import (EOT, Channel, IStream, OStream, channel, select,
                      READABLE, WRITABLE)
from .compile_cache import (CacheStats, CompileCache, aval_signature,
                            default_cache, instance_key, set_default_cache,
                            structural_digest)
from .engines import (ENGINES, CoroutineEngine, EngineBase, SequentialEngine,
                      SimReport, ThreadEngine, run)
from .errors import (ChannelMisuse, Deadlock, EndOfTransaction,
                     GraphValidationError, ReproError,
                     SequentialSimulationError, TaskKilled)
from .graph import DefinitionInfo, Graph, elaborate, extract_graph
from .hier_compile import (CompileReport, DataflowProgram, StageInstance,
                           build_dataflow, compile_stages, diff_definitions)
from .invoke import invoke
from .task import TaskBuilder, TaskInstance, task

__all__ = [
    "EOT", "Channel", "IStream", "OStream", "channel", "select", "READABLE",
    "WRITABLE", "ENGINES", "CoroutineEngine", "EngineBase",
    "SequentialEngine", "SimReport", "ThreadEngine", "run", "ChannelMisuse",
    "Deadlock", "EndOfTransaction", "GraphValidationError", "ReproError",
    "SequentialSimulationError", "TaskKilled", "DefinitionInfo", "Graph",
    "elaborate", "extract_graph", "CompileReport", "DataflowProgram",
    "StageInstance", "build_dataflow", "compile_stages",
    "diff_definitions", "TaskBuilder",
    "TaskInstance", "task", "invoke", "CacheStats", "CompileCache",
    "aval_signature", "default_cache", "set_default_cache", "instance_key",
    "structural_digest",
]
