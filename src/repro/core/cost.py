"""Cost probes: FLOPs / bytes / collective estimates per compiled unit.

Two consumers share this module (one memoized code path, per the QoR
loop's "measure cheap, measure once" rule):

* the **floorplanner** (:mod:`repro.core.floorplan`) prices every
  :class:`~repro.core.synth.StepTask` firing so the min-cut/load-balance
  objective has real per-task weights instead of a hash of the task
  name — :func:`task_cost` / :func:`phase_cost`;
* the **perf_iter benchmark** (``benchmarks/perf_iter.py``) measures
  whole training/decode step builds — :func:`probe_compiled`, the
  refactored body of its old private ``meas`` helper.

Both paths are memoized in the compile cache's JSON store
(``memo_get``/``memo_put``): a probe key folds in the *probed
function's own structural digest* plus its binding specs, so editing one
task definition dirties exactly one cost cell — every untouched cell is
a digest lookup, in this process (dict) and across processes (disk).

Step-task probes lower the single-firing body (the same
``_phase_probe`` trace the whole-graph program inlines) and read XLA's
``cost_analysis`` from the *lowered* module — no backend compile, so
pricing a 100-task graph costs milliseconds per distinct cell.
``probe_compiled`` runs the full ``lower().compile()`` pipeline because
its callers need optimized-HLO collective traffic and memory analysis.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np

from .compile_cache import (_stable_repr, default_cache, instance_key,
                            structural_digest)
from .synth import (_ChanRef, _MMapRef, _PortRef, _canon_dtype, _chan_specs,
                    _mmap_specs, _phase_probe, _state_spec)

COST_SCHEMA = "cost1"

# Reference hardware terms (one TPU-class chip + ICI link): the floorplan
# objective and perf_iter's fit-corrected terms both convert raw counters
# into seconds with these, so "compute seconds" and "cut-traffic seconds"
# are commensurable.  Placement decisions only use ratios, so the exact
# numbers matter less than their being shared.
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
      "hbm_capacity": 16e9}

# in-process cost cells (the disk memo's L1): probe key -> result dict
_CELLS: dict[str, dict] = {}


def clear_cost_cells() -> None:
    """Drop the in-process cost-cell cache (tests)."""
    _CELLS.clear()


def _normalize_cost(cost: Any) -> dict:
    """``cost_analysis`` returns a dict, or a per-device list on some
    jax versions, or None when the backend offers nothing."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _extract_compiled(compiled) -> dict:
    from ..launch.dryrun import collective_bytes   # lazy: launch is heavy
    cost = _normalize_cost(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    if isinstance(mem, (list, tuple)):
        mem = mem[0] if mem else None
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0))}


def probe_compiled(fn: Callable, args: tuple = (), kwargs=None, *,
                   mesh=None, in_shardings=None, out_shardings=None,
                   donate_argnums=None, memo_key: Optional[str] = None,
                   cache: Any = None) -> dict:
    """``jit(fn).lower(*args).compile()`` and return its cost split.

    Returns ``{"flops", "bytes", "coll", "arg_bytes", "temp_bytes"}``
    (optimized-HLO counters; ``coll`` is the collective traffic parsed
    from the compiled module).  With ``memo_key`` set the result is
    memoized in ``cache`` (default: the process compile cache;
    ``cache=False`` disables memoization) — a hit never touches XLA.
    """
    cc = default_cache() if cache is None else (cache or None)
    if memo_key is not None and cc is not None:
        hit = cc.memo_get(memo_key)
        if hit is not None:
            return hit
    jit_kw = {}
    if in_shardings is not None:
        jit_kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        jit_kw["out_shardings"] = out_shardings
    if donate_argnums is not None:
        jit_kw["donate_argnums"] = donate_argnums
    if mesh is not None:
        with mesh:
            compiled = jax.jit(fn, **jit_kw).lower(
                *args, **(kwargs or {})).compile()
    else:
        compiled = jax.jit(fn, **jit_kw).lower(
            *args, **(kwargs or {})).compile()
    out = _extract_compiled(compiled)
    if memo_key is not None and cc is not None:
        cc.memo_put(memo_key, out)
    return out


# ---------------------------------------------------------------------------
# step-task probes (the floorplanner's price list)
# ---------------------------------------------------------------------------

def _template_sig(plan, t: Any) -> Any:
    """Stable signature of one bound argument template: everything that
    shapes the lowered firing body *except* the phase function itself
    (which the probe key hashes separately via its structural digest)."""
    if isinstance(t, _ChanRef):
        c = plan.channels[t.ci]
        return ("chan", c.capacity, str(_canon_dtype(c.dtype)),
                tuple(c.shape))
    if isinstance(t, _MMapRef):
        m = plan.mmaps[t.mi]
        return ("mmap", tuple(m.shape), str(m.dtype))
    if isinstance(t, _PortRef):
        p = plan.ports[t.pi]
        return ("port", tuple(p.shape), str(p.dtype), p.latency, p.depth)
    if isinstance(t, (list, tuple)):
        return ("seq",) + tuple(_template_sig(plan, x) for x in t)
    return ("const", _stable_repr(t))


def phase_key(plan, tp, ph) -> str:
    """The cost cell's content address: phase-function digest + binding
    specs + ring impl + toolchain.  Depends on nothing outside this one
    task's definition and its port shapes, so editing another task — or
    re-wiring an unrelated corner of the graph — leaves this cell warm.
    """
    sig = (tuple(_template_sig(plan, t) for t in tp.t_args),
           tuple(sorted((k, _template_sig(plan, t))
                        for k, t in tp.t_kwargs.items())))
    state = _stable_repr(jax.tree.map(
        lambda x: (tuple(x.shape), str(x.dtype)), _state_spec(tp.state0)))
    return instance_key(
        ph.fn, (), {},
        extra=("step_cost", COST_SCHEMA, plan.ring_impl, ph.label,
               sig, state))


def phase_cost(plan, tp, ph, *, cache: Any = None) -> dict:
    """Per-firing ``{"flops", "bytes", "coll"}`` for one phase of one
    task plan — lowered-module counters, memoized under
    :func:`phase_key`."""
    key = phase_key(plan, tp, ph)
    hit = _CELLS.get(key)
    if hit is not None:
        return hit
    cc = default_cache() if cache is None else (cache or None)
    if cc is not None:
        hit = cc.memo_get(key)
        if hit is not None:
            _CELLS[key] = hit
            return hit
    probe = _phase_probe(plan, tp, ph.fn, rec=None)
    low = jax.jit(probe).lower(_state_spec(tp.state0),
                               _chan_specs(plan, tp),
                               _mmap_specs(plan, tp))
    cost = _normalize_cost(low.cost_analysis())
    if not cost:                        # backend offered nothing lowered:
        cost = _normalize_cost(low.compile().cost_analysis())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           # a single step firing is device-local by construction; the
           # interconnect traffic it *causes* is priced per channel by
           # the floorplanner, not here
           "coll": 0.0}
    _CELLS[key] = out
    if cc is not None:
        cc.memo_put(key, out)
    return out


def task_cost(plan, tp, *, cache: Any = None, hw: Optional[dict] = None
              ) -> dict:
    """Whole-budget cost of one task instance: per-phase firing cost x
    firing count, plus the roofline-converted ``seconds`` the floorplan
    objective balances."""
    hw = hw or HW
    tot = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    per_phase = []
    for ph in tp.phases:
        c = phase_cost(plan, tp, ph, cache=cache)
        per_phase.append({"label": ph.label, "count": ph.count, **c})
        for k in tot:
            tot[k] += c[k] * ph.count
    seconds = (tot["flops"] / hw["peak_flops"]
               + tot["bytes"] / hw["hbm_bw"]
               + tot["coll"] / hw["ici_bw"])
    return {**tot, "seconds": seconds, "phases": per_phase}


def graph_cost_salt(plan) -> str:
    """Digest of every task's phase-function digests — a cheap way for
    placement artifacts to notice a task edit without re-probing."""
    import hashlib
    h = hashlib.sha256()
    for tp in plan.tasks:
        for ph in tp.phases:
            h.update(structural_digest(ph.fn).encode())
    return h.hexdigest()
