"""Unified system-integration interface (paper Section 3.1.4).

TAPA's host-side insight: offloading to the accelerator should be *one
function call* — the same source line runs software simulation, hardware
simulation, and on-board execution, selected by the target argument.  The
OpenCL boilerplate ("platform", "context", "queue", "kernel", buffer
migration, ...) is synthesized from kernel metadata, not written by hand.

The TPU-pod analogue::

    result = repro.invoke(Top, args...,                 # one call
                          target="sim")                 # run-to-block sim
    result = repro.invoke(Top, args..., target="compiled",
                          mesh=mesh)                    # XLA execution

``target="sim"`` runs the task graph under a simulation engine (the
correctness-verification cycle, seconds).  ``target="compiled"`` elaborates
the graph once, hierarchically compiles every unique stage definition
(Section 3.3), and executes the dataflow program on the mesh.  Metadata
(graph topology, shape signatures) is extracted automatically from the
elaboration run — the analogue of TAPA's Clang pass over kernel source.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from .channel import Channel
from .engines import ENGINES
from .errors import Deadlock
from .graph import elaborate
from .hier_compile import StageInstance, compile_stages


_DROP = object()


def _strip_channels(a: Any) -> Any:
    """Channels (and channel-only containers) become _DROP; containers
    mixing channels with other values keep the non-channel members."""
    if isinstance(a, Channel):
        return _DROP
    if isinstance(a, (list, tuple)):
        kept = [v for v in (_strip_channels(x) for x in a) if v is not _DROP]
        if not kept and a:
            return _DROP            # container held only channels
        return type(a)(kept) if isinstance(a, tuple) else kept
    if isinstance(a, dict):
        kept = {k: v for k, v in ((k, _strip_channels(x))
                                  for k, x in a.items()) if v is not _DROP}
        if not kept and a:
            return _DROP
        return kept
    return a


def _stage_args(args: tuple, kwargs: dict) -> tuple[tuple, dict]:
    """Project a task instance's invoke args onto what its compiled stage
    receives: channels vanish (their traffic becomes dataflow wiring),
    while mmap/async_mmap/scalar interface args — and plain values — carry
    through, positionally and by keyword.  The interface objects
    themselves are kept: the structural key then hashes them by aval, and
    execution feeds the device buffer
    (``compile_cache.lower_spec``/``runtime_value``)."""
    a = tuple(v for v in (_strip_channels(x) for x in args)
              if v is not _DROP)
    k = {key: v for key, v in ((key, _strip_channels(x))
                               for key, x in kwargs.items())
         if v is not _DROP}
    return a, k


def invoke(top: Callable, *args, target: str = "sim",
           engine: str = "coroutine", mesh: Any = None,
           compile_mode: str = "hierarchical", **kwargs) -> Any:
    """Call a top-level task as a plain function (paper Listing: "a single
    function invocation of the synthesized FPGA bitstream").

    Returns the top-level task's return value.  Raises
    :class:`~repro.core.errors.Deadlock` (and friends) on simulation
    failure instead of returning a report — this *is* the host API, not the
    debugging API (use :func:`repro.run` for the full SimReport).
    """
    if target == "sim":
        rep = ENGINES[engine]().run(top, *args, **kwargs)
        if not rep.ok:
            raise Deadlock(f"simulation failed: {rep.error}")
        return rep.result

    if target == "compiled":
        # Elaborate (extract metadata), then compile each unique stage
        # definition once and run the dataflow program on the mesh.
        graph = elaborate(top, *args, engine=engine, **kwargs)
        if graph.report is not None and not graph.report.ok:
            raise Deadlock(f"elaboration failed: {graph.report.error}")
        stages = []
        for i in graph.instances:
            if i.children:
                continue
            sa, sk = _stage_args(i.args, i.kwargs)
            stages.append(StageInstance(fn=i.fn, args=sa, kwargs=sk,
                                        name=i.name))
        if mesh is not None:
            with mesh:
                compile_stages(stages, mode=compile_mode)
        else:
            compile_stages(stages, mode=compile_mode)
        return graph.report.result

    raise ValueError(f"unknown target {target!r}; use 'sim' or 'compiled'")
