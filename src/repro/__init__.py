"""TAPA-JAX: task-parallel dataflow programming, simulation and compilation
for TPU pods — a JAX reproduction and extension of

    "Extending High-Level Synthesis for Task-Parallel Programs"
    (Chi, Guo, Choi, Wang, Cong — UCLA, 2020)

Public API mirrors the paper's (Table 2 / Listings 4-5)::

    import repro

    def Producer(out: repro.OStream, n: int):
        for i in range(n):
            out.write(i)
        out.close()                      # end-of-transaction

    def Consumer(inp: repro.IStream, result: list):
        for v in inp:                    # drains one transaction
            result.append(v)

    def Top(n, result):
        ch = repro.channel(capacity=2)
        repro.task() \
            .invoke(Producer, ch, n) \
            .invoke(Consumer, ch, result)

    report = repro.run(Top, 8, [], engine="coroutine")
"""

from .core import (EOT, Channel, IStream, OStream, channel, select, run,
                   task, invoke,
                   MMap, AsyncMMap, Scalar, mmap, async_mmap, scalar,
                   elaborate, Graph, InterfaceInfo, SimReport, ENGINES,
                   Deadlock, DeadlockError, DeadlockReport,
                   FaultInjector, FaultPlan, InjectedFault, PoisonError,
                   TransientFault,
                   SequentialSimulationError, EndOfTransaction,
                   ChannelMisuse, StageInstance, compile_stages,
                   DataflowProgram,
                   ChannelInfo, CompiledEngine, StepTask, SynthesisError)

__version__ = "1.1.0"

__all__ = [
    "EOT", "Channel", "IStream", "OStream", "channel", "select", "run",
    "task", "invoke",
    "MMap", "AsyncMMap", "Scalar", "mmap", "async_mmap", "scalar",
    "elaborate", "Graph", "InterfaceInfo", "SimReport", "ENGINES",
    "Deadlock", "DeadlockError", "DeadlockReport",
    "FaultInjector", "FaultPlan", "InjectedFault", "PoisonError",
    "TransientFault",
    "SequentialSimulationError", "EndOfTransaction", "ChannelMisuse",
    "StageInstance", "compile_stages", "DataflowProgram",
    "ChannelInfo", "CompiledEngine", "StepTask", "SynthesisError",
    "__version__",
]
