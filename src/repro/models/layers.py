"""Neural-net building blocks (pure functional JAX).

Every layer is a pair of functions: ``init_*(rng, cfg) -> params-pytree``
and ``apply(params, x, ...) -> y``.  Parameters are plain nested dicts so
they shard trivially under pjit and stack trivially for ``lax.scan`` over
layers (the in-program form of the paper's compile-each-definition-once
insight — see core/hier_compile.py).

Compute dtype is bf16 by default with fp32 accumulation for softmax, norms
and SSD state recurrences.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(rng, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def _embed_init(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02) \
        .astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                     # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]             # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + RoPE + optional qk-norm + optional sliding window)
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig, dtype) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": _dense_init(ks[0], d, nh * hd, dtype),
        "wk": _dense_init(ks[1], d, nkv * hd, dtype),
        "wv": _dense_init(ks[2], d, nkv * hd, dtype),
        "wo": _dense_init(ks[3], nh * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
         rope: bool = True):
    B, S, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, nh, hd)
    k = (x @ p["wk"]).reshape(B, S, nkv, hd)
    v = (x @ p["wv"]).reshape(B, S, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool, q_offset: jax.Array | int = 0,
         kv_len: Optional[jax.Array] = None,
         window: Optional[int] = None) -> jax.Array:
    """Grouped-query scaled dot-product attention, fp32 softmax.

    q: [B, Sq, nh, hd]; k/v: [B, Sk, nkv, hd].  ``q_offset`` is the absolute
    position of q[0] (decode: cache length).  ``kv_len`` masks cache slots
    >= kv_len.  ``window`` enables sliding-window attention.

    ``q_offset``/``kv_len`` may be scalars or per-row ``[B]`` vectors — the
    vector form is the ragged-length path used by the packed serving batch,
    where every slot sits at a different decode position.
    """
    B, Sq, nh, hd = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qg = q.reshape(B, Sq, nkv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    off = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (-1, 1, 1))
    qpos = jnp.arange(Sq)[None, :, None] + off      # [B|1, Sq, 1]
    kpos = jnp.arange(Sk)[None, None, :]            # [1, 1, Sk]
    mask = jnp.ones((1, Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    if kv_len is not None:
        kl = jnp.reshape(jnp.asarray(kv_len, jnp.int32), (-1, 1, 1))
        mask = mask & (kpos < kl)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, nh, hd).astype(q.dtype)


def sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: Optional[int] = None,
                 chunk: int = 1024) -> jax.Array:
    """Memory-efficient attention: online softmax over KV chunks.

    Pure-XLA statement of the flash-attention recurrence (lax.scan over KV
    blocks, fp32 running max/sum) — the [Sq, Sk] score matrix is never
    materialized, so peak HBM traffic drops from O(Sq*Sk) to
    O(Sq*chunk) per head.  Differentiable (scan bwd recomputes per block,
    flash-style).  This is the beyond-paper memory-term optimization used
    by the S:Perf hillclimb; the Pallas kernel is its TPU-core twin.
    """
    B, Sq, nh, hd = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // chunk
    qg = q.reshape(B, Sq, nkv, g, hd).astype(jnp.float32) / math.sqrt(hd)
    kb = jnp.moveaxis(k.reshape(B, nblk, chunk, nkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, chunk, nkv, hd), 1, 0)
    qpos = jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, bi = blk
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc.astype(jnp.float32))
        kpos = bi * chunk + jnp.arange(chunk)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask &= (kpos < Sk)[None, :]                  # padded tail
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nkv, g, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, nkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, nkv, g, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # [B,nkv,g,Sq,hd]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, nh, hd)
    return out.astype(q.dtype)


def attention(p: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, *, causal: Optional[bool] = None,
              kv: Optional[tuple] = None, use_kernel: bool = False) -> jax.Array:
    """Full-sequence attention (train/prefill).  ``kv`` overrides k/v for
    cross-attention.  ``cfg.attn_impl`` selects naive / chunked / kernel."""
    causal = cfg.causal if causal is None else causal
    q, k, v = _qkv(p, cfg, x, positions, rope=kv is None)
    if kv is not None:
        k, v = kv
    if use_kernel or cfg.attn_impl == "kernel":
        from ..kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal,
                                   window=cfg.sliding_window)
    elif cfg.attn_impl == "chunked":
        out = sdpa_chunked(q, k, v, causal=causal,
                           window=cfg.sliding_window)
    elif cfg.attn_impl == "noscore":
        out = _noscore_attention(q, k, v)
    else:
        out = sdpa(q, k, v, causal=causal, window=cfg.sliding_window)
    B, S = x.shape[:2]
    return out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]


def _noscore_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Measurement stand-in (S:Perf only): keeps the q/k/v/o projections
    alive but removes the O(Sq*Sk) score computation entirely.  The
    difference (full build − noscore build) isolates the score-path cost;
    adding the Pallas flash kernel's analytic HBM traffic (q+k+v+o once)
    on top models the ``attn_impl="kernel"`` roofline on real hardware,
    where score blocks live in VMEM and never touch HBM."""
    g = q.shape[2] // k.shape[2]
    return q + 0.5 * jnp.repeat(k + v, g, axis=2)


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(position, head) symmetric int8 quantization of K or V.

    t: [B, S, n, hd] -> (int8 values, fp16 scales [B, S, n]).  Halves the
    KV cache's HBM footprint — the decode-capacity lever for pod-scale
    serving (grok-1: 4.3 -> 2.2 GB/chip at 32k context).
    """
    m = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1), 1e-6)
    scale = m / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]) \
        .astype(dtype)


def attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     cache_len: jax.Array,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None) -> tuple:
    """One-token decode against a KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, nkv, hd]; cache_len: [] or [B] int32.
    Returns (out [B,1,d], new_k, new_v[, new_k_scale, new_v_scale]).
    With ``cfg.kv_quant`` the caches are int8 + per-(pos, head) scales.

    A vector ``cache_len`` selects the ragged-length path (packed serving
    batch): each row scatters its new K/V at its own position and attends
    to its own prefix, routed through the flash-decode dispatch in
    ``kernels/ops.decode_attention``.  A row at length 0 is a dead slot —
    its output is garbage-but-finite and the caller masks its token.
    """
    B = x.shape[0]
    cache_len = jnp.asarray(cache_len, jnp.int32)
    ragged = cache_len.ndim == 1
    positions = jnp.broadcast_to(jnp.reshape(cache_len, (-1, 1)), (B, 1))
    q, k, v = _qkv(p, cfg, x, positions)

    def scatter(cache, new):
        """Write the one-token [B, 1, ...] update at each row's length.

        The ragged form is a per-row scatter touching only B rows (not a
        full-cache select): under donation XLA updates in place, so the
        write traffic per step is O(B), independent of S_max.  A row whose
        length equals S_max scatters out of bounds, which jax drops — the
        capacity-stop no-op the engine relies on."""
        new = new.astype(cache.dtype)
        if not ragged:
            return jax.lax.dynamic_update_slice_in_dim(cache, new,
                                                       cache_len, axis=1)
        return cache.at[jnp.arange(B), cache_len].set(
            new[:, 0], mode="drop")

    if cfg.kv_quant:
        qk, sk = quantize_kv(k)
        qv, sv = quantize_kv(v)
        ck = scatter(cache_k, qk)
        cv = scatter(cache_v, qv)
        nks = scatter(k_scale, sk)
        nvs = scatter(v_scale, sv)
        kd = dequantize_kv(ck, nks, q.dtype)
        vd = dequantize_kv(cv, nvs, q.dtype)
        out = sdpa(q, kd, vd, causal=False, q_offset=cache_len,
                   kv_len=cache_len + 1, window=cfg.sliding_window)
        return (out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"],
                ck, cv, nks, nvs)
    ck = scatter(cache_k, k)
    cv = scatter(cache_v, v)
    if cfg.sliding_window is None and (ragged or cfg.attn_impl == "kernel"):
        # flash-decode path: sequential KV-block grid with VMEM-carried
        # softmax state, per-row lengths scalar-prefetched so the unfilled
        # cache tail is skipped (kernels/decode_attention.py).  The ragged
        # serving batch always routes here; ops.decode_attention dispatches
        # real Pallas on TPU and the vectorized reference elsewhere.
        from ..kernels import ops as kops
        out = kops.decode_attention(q[:, 0], ck, cv, cache_len + 1)[:, None]
    else:
        out = sdpa(q, ck, cv, causal=False, q_offset=cache_len,
                   kv_len=cache_len + 1, window=cfg.sliding_window)
    return out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"], ck, cv


def init_cross_attention(rng, cfg: ModelConfig, dtype) -> Params:
    # full multi-head (whisper uses MHA); reuse attention params shape
    return init_attention(rng, dataclasses.replace(
        cfg, n_kv_heads=cfg.n_heads, qk_norm=False), dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "wg": _dense_init(ks[0], d, ff, dtype),
        "wu": _dense_init(ks[1], d, ff, dtype),
        "wd": _dense_init(ks[2], ff, d, dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_mlp2(rng, d: int, ff: int, dtype) -> Params:
    """Two-matrix GELU MLP (whisper-style)."""
    ks = jax.random.split(rng, 2)
    return {"w1": _dense_init(ks[0], d, ff, dtype),
            "b1": jnp.zeros((ff,), dtype),
            "w2": _dense_init(ks[1], ff, d, dtype),
            "b2": jnp.zeros((d,), dtype)}


def mlp2(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Mixture of Experts with capacity-based token dispatch (GShard/Switch style)
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, E, ff = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(rng, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": _dense_init(ks[0], d, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
               * scale).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
               * scale).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               * (1.0 / math.sqrt(ff))).astype(dtype),
    }


def moe_layer(p: Params, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """Token-dropping top-k MoE.  Returns (y, aux_load_balance_loss).

    Two dispatch implementations (cfg.moe_impl):

    * ``scatter`` (baseline): tokens scattered into a per-expert buffer
      ``[E, C, d]`` with ``.at[].add`` and gathered back by index — compact
      flops, but GSPMD lowers the scatter/gather across the EP-sharded
      expert axis into expensive all-reduces (measured in S:Perf).
    * ``dense`` (GShard einsum): a one-hot dispatch mask [T, E, C] turns
      dispatch/combine into plain einsums — more raw flops but collective-
      free up to the EP boundary, which is what the MXU wants.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, K)                     # [T, K]
    topw = topw / jnp.sum(topw, -1, keepdims=True)           # renormalize

    # load-balance aux loss (Switch eq. 4)
    me = jnp.mean(probs, axis=0)                             # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(tope, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce) * m.load_balance_coef

    if cfg.moe_impl == "dense":
        return _moe_dense_grouped(p, cfg, x, probs, aux, capacity_factor)

    C = int(math.ceil(T * K / E * capacity_factor))
    C = max(C, 4)
    flat_e = tope.reshape(-1)                                # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [T*K, E]
    # slot assignment = exclusive prefix sum over the token axis.  The
    # baseline jnp.cumsum lowers to a quadratic reduce-window on long axes
    # (measured 1.4e14 counted flops at 8.4M tokens); "scatter_fast" swaps
    # in the log-depth associative scan (1.9e9) — see S:Perf.
    if cfg.moe_impl == "scatter_fast":
        pos = jax.lax.associative_scan(jnp.add, onehot, axis=0) - onehot
    else:
        pos = jnp.cumsum(onehot, axis=0) - onehot            # exclusive
    slot = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]  # [T*K]
    keep = slot < C
    tok_idx = jnp.repeat(jnp.arange(T), K)

    disp = jnp.zeros((E, C, d), x.dtype)
    disp = disp.at[flat_e, jnp.clip(slot, 0, C - 1)].add(
        jnp.where(keep[:, None], xt[tok_idx], 0).astype(x.dtype))

    h = jnp.einsum("ecd,edf->ecf", disp, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", disp, p["wu"])
    eo = jnp.einsum("ecf,efd->ecd", h, p["wd"])              # [E, C, d]

    gathered = eo[flat_e, jnp.clip(slot, 0, C - 1)]          # [T*K, d]
    w = (topw.reshape(-1) * keep).astype(jnp.float32)
    y = jnp.zeros((T, d), jnp.float32).at[tok_idx].add(
        gathered.astype(jnp.float32) * w[:, None])
    return y.reshape(B, S, d).astype(x.dtype), aux


def _moe_dense_grouped(p: Params, cfg: ModelConfig, x: jax.Array,
                       probs: jax.Array, aux: jax.Array,
                       capacity_factor: float) -> tuple:
    """GShard einsum dispatch, grouped by batch row (arXiv:2006.16668).

    Tokens are grouped along the batch dimension — the same dimension the
    data axis shards — so the [B, S, E, C] dispatch/combine masks and every
    einsum stay local to the data shard; no scatter/gather ops exist for
    GSPMD to mis-shard.  Capacity is per group: C = ceil(S*K/E * factor).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    C = max(int(math.ceil(S * K / E * capacity_factor)), 4)

    pr = probs.reshape(B, S, E)
    topw, tope = jax.lax.top_k(pr, K)                        # [B, S, K]
    topw = topw / jnp.sum(topw, -1, keepdims=True)

    # slot index of each (token, k) copy within its expert, per group
    oh = jax.nn.one_hot(tope, E, dtype=jnp.int32)            # [B, S, K, E]
    # rank tokens per expert in (s, k) order: exclusive prefix-sum over
    # (S*K), log-depth (see moe_layer for why not jnp.cumsum)
    flat = oh.reshape(B, S * K, E)
    pos = jax.lax.associative_scan(jnp.add, flat, axis=1) - flat
    slot = jnp.sum(pos.reshape(B, S, K, E) * oh, axis=-1)    # [B, S, K]
    keep = slot < C

    oh_c = jax.nn.one_hot(jnp.clip(slot, 0, C - 1), C,
                          dtype=jnp.float32)                 # [B, S, K, C]
    w = jnp.where(keep, topw, 0.0).astype(jnp.float32)
    combine = jnp.einsum("bske,bskc,bsk->bsec",
                         oh.astype(jnp.float32), oh_c, w)    # [B, S, E, C]
    dispatch = (combine > 0).astype(x.dtype)

    disp = jnp.einsum("bsec,bsd->becd", dispatch, x)         # [B, E, C, d]
    h = jnp.einsum("becd,edf->becf", disp, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", disp, p["wu"])
    eo = jnp.einsum("becf,efd->becd", h, p["wd"])            # [B, E, C, d]
    y = jnp.einsum("bsec,becd->bsd", combine, eo.astype(jnp.float32))
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba2 / SSD (state-space duality) — pure-jnp chunked reference
# ---------------------------------------------------------------------------

def init_mamba2(rng, cfg: ModelConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    G, N = s.n_groups, s.d_state
    conv_ch = di + 2 * G * N
    ks = jax.random.split(rng, 5)
    return {
        # fused input projection: [z (di) | x (di) | B (G*N) | C (G*N) | dt (nh)]
        "in_proj": _dense_init(ks[0], d, 2 * di + 2 * G * N + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> tuple:
    """Depthwise causal conv1d.  x: [B, S, C]; w: [W, C].  Returns (y, new
    conv state = last W-1 inputs)."""
    W = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+W-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    return jax.nn.silu(y), xp[:, -(W - 1):] if W > 1 else pad


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, D: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                use_kernel: bool = False) -> tuple:
    """SSD (Mamba-2) sequence mixing.

    x:  [B, S, H, P]   inputs per head
    dt: [B, S, H]      softplus-ed step sizes
    A:  [H]            negative decay rates
    Bm: [B, S, G, N]   input->state projection  (G groups broadcast to H)
    Cm: [B, S, G, N]   state->output projection
    Returns (y [B,S,H,P], final_state [B,H,P,N]).

    Chunked algorithm (arXiv:2405.21060 §6): intra-chunk quadratic attention
    with decay mask + inter-chunk state recurrence.  fp32 state math.
    """
    if use_kernel:
        from ..kernels import ops as kops
        return kops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                             init_state=init_state)
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    pad = (-S) % chunk
    if pad:
        # dt=0 on padded steps => exp(0·A)=1 decay and zero input: padding
        # is state-neutral, so trimming y afterwards is exact
        y, final = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            D, chunk, init_state)
        return y[:, :S], final
    nc = S // chunk
    rep = H // G

    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2) \
        .reshape(B, nc, chunk, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2) \
        .reshape(B, nc, chunk, H, N)

    dA = dtf * A[None, None, None, :]              # [B,nc,Q,H] (negative)
    cum = jnp.cumsum(dA, axis=2)                   # inclusive cumsum
    # decay from step j (exclusive) to step i (inclusive), i >= j.
    # Mask the *exponent* (not the exp) so masked entries never produce
    # inf forward / NaN backward.
    Lmask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    decay = jnp.exp(jnp.where(Lmask, diff, -jnp.inf))       # [B,nc,Q,Q,H]

    xdt = xf * dtf[..., None]                      # dt-weighted inputs
    # intra-chunk: y[i] = sum_{j<=i} C_i·B_j decay(i,j) x_j dt_j
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cf, Bf)  # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", cb, decay, xdt)

    # chunk summary states: S_c = sum_j decay(end..j) B_j x_j dt_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum)        # decay j -> chunk end
    chunk_state = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Bf, tail, xdt)

    # inter-chunk recurrence over chunk states
    total = jnp.exp(cum[:, :, -1, :])              # [B,nc,H] full-chunk decay
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def step(carry, inp):
        tot, cs = inp                              # [B,H], [B,H,P,N]
        new = carry * tot[:, :, None, None] + cs
        return new, carry                          # emit state *entering* chunk

    total_t = jnp.moveaxis(total, 1, 0)            # [nc,B,H]
    cs_t = jnp.moveaxis(chunk_state, 1, 0)         # [nc,B,H,P,N]
    final, entering = jax.lax.scan(step, s0, (total_t, cs_t))
    entering = jnp.moveaxis(entering, 0, 1)        # [B,nc,H,P,N]

    # inter-chunk contribution: y[i] += C_i · (decay(start..i) * state_in)
    head = jnp.exp(cum)                            # decay start -> i
    y_inter = jnp.einsum("bcihn,bcih,bchpn->bcihp", Cf, head, entering)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final


def mamba2_layer(p: Params, cfg: ModelConfig, x: jax.Array,
                 use_kernel: bool = False) -> jax.Array:
    """Full Mamba2 block (train/prefill): in_proj -> conv -> SSD -> gate ->
    out_proj.  x: [B, S, d]."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh = s.d_inner(d), s.n_heads(d)
    G, N = s.n_groups, s.d_state
    B, S, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(
        xin.reshape(B, S, nh, s.head_dim), dtv, A,
        Bc.reshape(B, S, G, N), Cc.reshape(B, S, G, N), p["D"],
        chunk=min(s.chunk, S), use_kernel=use_kernel)
    y = y.reshape(B, S, di)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                  ssm_state: jax.Array, conv_state: jax.Array) -> tuple:
    """Single-token recurrent step.  x: [B, 1, d];
    ssm_state: [B, H, P, N]; conv_state: [B, W-1, conv_ch]."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh = s.d_inner(d), s.n_heads(d)
    G, N = s.n_groups, s.d_state
    B = x.shape[0]

    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)    # [B, 1, C]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      state=conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtv * A[None, :])                        # [B, nh]
    xh = xin[:, 0].reshape(B, nh, s.head_dim).astype(jnp.float32)
    rep = nh // G
    Bh = jnp.repeat(Bc[:, 0].reshape(B, G, N), rep, 1).astype(jnp.float32)
    Ch = jnp.repeat(Cc[:, 0].reshape(B, G, N), rep, 1).astype(jnp.float32)

    upd = (dtv[..., None] * xh)[..., None] * Bh[:, :, None, :]  # [B,nh,P,N]
    new_state = ssm_state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_state.astype(ssm_state.dtype), new_conv


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 z_loss: float = 0.0) -> jax.Array:
    """Token-mean cross entropy with optional z-loss, fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], -1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
