"""Model configuration for every assigned architecture family.

One `ModelConfig` dataclass covers dense / MoE / SSM / hybrid / enc-dec /
VLM-backbone families; family-specific sub-configs are optional fields.
The exact published dimensions live in ``repro.configs.<arch_id>``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert hidden width
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (state-space duality, arXiv:2405.21060)."""
    d_state: int
    head_dim: int = 64
    expand: int = 2            # d_inner = expand * d_model
    chunk: int = 256           # SSD chunk length
    conv_width: int = 4
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + a single *shared* attention block
    instantiated every ``attn_period`` layers (arXiv:2411.15242).  The
    shared block is the paper's one-definition/many-instances pattern
    realized with literally shared weights."""
    attn_period: int = 6


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder; conv frontend is a stub that takes
    precomputed frame embeddings per the assignment."""
    n_encoder_layers: int = 12
    n_audio_ctx: int = 1500     # frames after conv stride (whisper: 30s)


@dataclass(frozen=True)
class VLMConfig:
    """Phi-3-vision-style: the transformer backbone consumes precomputed
    CLIP patch embeddings (frontend stubbed per the assignment)."""
    n_patches: int = 576
    d_patch: int = 1024         # projected to d_model by a learned matrix


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int               # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # attention behaviour
    causal: bool = True
    sliding_window: Optional[int] = None   # starcoder2 uses 4096 in HF cfg
    # implementation selectors (S:Perf levers; defaults = paper-faithful
    # baseline)
    attn_impl: str = "naive"               # naive | chunked | kernel
    moe_impl: str = "scatter"              # scatter | dense (GShard einsum)
    kv_quant: bool = False                 # int8 KV cache (serving)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (sub-quadratic sequence cost)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encdec is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        n = V * d                      # token embedding
        if not self.tie_embeddings:
            n += V * d                 # lm head
        n += d                         # final norm
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            hd = self.hd
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            attn = q + kv + o + (2 * self.n_heads * hd if self.qk_norm else 0)
            if self.moe is not None:
                ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                ff += d * self.moe.n_experts      # router
            else:
                ff = 3 * d * self.d_ff            # gate/up/down
            per_layer = attn + ff + 2 * d         # two norms
        elif self.family == "ssm":
            per_layer = self._ssm_layer_params()
        elif self.family == "hybrid":
            per_layer = self._ssm_layer_params()
        n += L * per_layer
        if self.family == "hybrid":
            # one shared attention+MLP block
            hd = self.hd
            shared = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd +
                      self.n_heads * hd * d + 3 * d * self.d_ff + 2 * d)
            n += shared
        if self.encdec is not None:
            # encoder layers: self-attn + mlp; decoder layers counted above
            hd = self.hd
            enc_layer = (4 * d * self.n_heads * hd + 3 * d * self.d_ff +
                         2 * d)
            n += self.encdec.n_encoder_layers * enc_layer
            # decoder cross-attention blocks
            n += L * (4 * d * self.n_heads * hd + d)
        if self.vlm is not None:
            n += self.vlm.d_patch * d             # patch projection
        return n

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        n = d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj (zxbcdt)
        n += s.conv_width * (di + 2 * s.n_groups * s.d_state)  # conv1d
        n += nh * 2                                # A_log, D
        n += di                                    # dt_bias ~ nh, norm di
        n += di * d                                # out_proj
        n += d                                     # pre-norm
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        inactive = self.n_layers * (self.moe.n_experts - self.moe.top_k) \
            * 3 * d * self.moe.d_ff_expert
        return full - inactive

    def with_reduced(self, **kw) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        nh = 4 if self.n_heads else 0
        # preserve the attention class: MHA stays MHA, GQA stays grouped
        nkv = nh if self.n_kv_heads == self.n_heads else \
            (min(self.n_kv_heads, 2) if self.n_heads else 0)
        base = dict(
            n_layers=2, d_model=64,
            n_heads=nh, n_kv_heads=nkv,
            d_ff=128, vocab=256, head_dim=16,
            max_seq_len=512,
        )
        if self.moe is not None:
            base["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32)
        if self.ssm is not None:
            base["ssm"] = SSMConfig(d_state=16, head_dim=16, chunk=16)
        if self.hybrid is not None:
            base["hybrid"] = HybridConfig(attn_period=2)
        if self.encdec is not None:
            base["encdec"] = EncDecConfig(n_encoder_layers=2, n_audio_ctx=32)
        if self.vlm is not None:
            base["vlm"] = VLMConfig(n_patches=8, d_patch=32)
        base.update(kw)
        return replace(self, **base)


# ---------------------------------------------------------------------------
# input shapes assigned to the LM family (seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Cell-applicability rules from the assignment.

    * ``long_500k`` needs sub-quadratic attention — only SSM/hybrid run it.
    * encoder-only archs would skip decode shapes (none assigned are).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch; 512k-token decode "
                       "requires sub-quadratic sequence mixing")
    return True, ""
