"""Model assembly: init / forward / decode for every assigned family.

Layer parameters are *stacked* along a leading ``[L, ...]`` axis and the
forward pass runs ``lax.scan`` over them: one traced/compiled copy of the
layer body regardless of depth — the in-program realization of the paper's
hierarchical "compile each definition once" insight (core/hier_compile.py).
``scan_layers=False`` switches to an unrolled Python loop, which is the
monolithic baseline measured in benchmarks/codegen_time.py.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig

Params = Any


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_layers(rng, n: int, init_one):
    """Initialize n layers and stack leaves along axis 0."""
    ks = jax.random.split(rng, n)
    trees = [init_one(k) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    dt = _cdtype(cfg)
    d = cfg.d_model
    r = jax.random.split(rng, 8)
    p: dict = {"embed": L._embed_init(r[0], cfg.vocab, d, dt),
               "final_norm": L.init_rmsnorm(d, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(r[1], d, cfg.vocab, dt)

    if cfg.family in ("dense", "vlm"):
        def one(k):
            ka, km = jax.random.split(k)
            return {"attn_norm": L.init_rmsnorm(d, dt),
                    "attn": L.init_attention(ka, cfg, dt),
                    "mlp_norm": L.init_rmsnorm(d, dt),
                    "mlp": L.init_mlp(km, d, cfg.d_ff, dt)}
        p["layers"] = _stack_layers(r[2], cfg.n_layers, one)
        if cfg.vlm is not None:
            p["patch_proj"] = L._dense_init(r[3], cfg.vlm.d_patch, d, dt)

    elif cfg.family == "moe":
        def one(k):
            ka, km = jax.random.split(k)
            return {"attn_norm": L.init_rmsnorm(d, dt),
                    "attn": L.init_attention(ka, cfg, dt),
                    "mlp_norm": L.init_rmsnorm(d, dt),
                    "moe": L.init_moe(km, cfg, dt)}
        p["layers"] = _stack_layers(r[2], cfg.n_layers, one)

    elif cfg.family == "ssm":
        def one(k):
            return {"norm": L.init_rmsnorm(d, dt),
                    "mamba": L.init_mamba2(k, cfg, dt)}
        p["layers"] = _stack_layers(r[2], cfg.n_layers, one)

    elif cfg.family == "hybrid":
        def one(k):
            return {"norm": L.init_rmsnorm(d, dt),
                    "mamba": L.init_mamba2(k, cfg, dt)}
        p["layers"] = _stack_layers(r[2], cfg.n_layers, one)
        ka, km = jax.random.split(r[3])
        p["shared_attn"] = {          # ONE set of weights, many call sites
            "attn_norm": L.init_rmsnorm(d, dt),
            "attn": L.init_attention(ka, cfg, dt),
            "mlp_norm": L.init_rmsnorm(d, dt),
            "mlp": L.init_mlp(km, d, cfg.d_ff, dt)}

    elif cfg.family == "audio":
        ed = cfg.encdec
        full = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads)
        def enc_one(k):
            ka, km = jax.random.split(k)
            return {"attn_norm": L.init_layernorm(d, dt),
                    "attn": L.init_attention(ka, full, dt),
                    "mlp_norm": L.init_layernorm(d, dt),
                    "mlp": L.init_mlp2(km, d, cfg.d_ff, dt)}
        def dec_one(k):
            ka, kx, km = jax.random.split(k, 3)
            return {"attn_norm": L.init_layernorm(d, dt),
                    "attn": L.init_attention(ka, full, dt),
                    "xattn_norm": L.init_layernorm(d, dt),
                    "xattn": L.init_attention(kx, full, dt),
                    "mlp_norm": L.init_layernorm(d, dt),
                    "mlp": L.init_mlp2(km, d, cfg.d_ff, dt)}
        p["enc_layers"] = _stack_layers(r[2], ed.n_encoder_layers, enc_one)
        p["layers"] = _stack_layers(r[4], cfg.n_layers, dec_one)
        p["enc_pos"] = (jax.random.normal(
            r[5], (ed.n_audio_ctx, d), jnp.float32) * 0.01).astype(dt)
        p["enc_final_norm"] = L.init_layernorm(d, dt)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """Shape/dtype skeleton (no allocation) — used by the dry-run."""
    return jax.eval_shape(partial(init_params, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block(lp, cfg: ModelConfig, h, positions, use_kernel):
    h = h + L.attention(lp["attn"], cfg,
                        L.rms_norm(lp["attn_norm"], h, cfg.norm_eps),
                        positions, use_kernel=use_kernel)
    h = h + L.mlp(lp["mlp"], L.rms_norm(lp["mlp_norm"], h, cfg.norm_eps))
    return h


def _moe_block(lp, cfg: ModelConfig, h, positions, use_kernel):
    h = h + L.attention(lp["attn"], cfg,
                        L.rms_norm(lp["attn_norm"], h, cfg.norm_eps),
                        positions, use_kernel=use_kernel)
    y, aux = L.moe_layer(lp["moe"],
                         cfg, L.rms_norm(lp["mlp_norm"], h, cfg.norm_eps))
    return h + y, aux


def _mamba_block(lp, cfg: ModelConfig, h, use_kernel):
    return h + L.mamba2_layer(lp["mamba"],
                              cfg, L.rms_norm(lp["norm"], h, cfg.norm_eps),
                              use_kernel=use_kernel)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            extra: Optional[dict] = None, scan_layers: bool = True,
            remat: bool = False, use_kernel: bool = False) -> jax.Array:
    """Token logits for a full sequence (training / prefill).

    tokens: [B, S] int32.  ``extra`` carries modality-stub inputs:
    ``patches`` [B, n_patches, d_patch] (vlm) or ``frames`` [B, Ta, d]
    (audio).  Returns logits [B, S, vocab].
    """
    extra = extra or {}
    B, S = tokens.shape
    h = params["embed"][tokens]                     # [B, S, d]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.vlm is not None and "patches" in extra:
        pe = (extra["patches"] @ params["patch_proj"]).astype(h.dtype)
        npatch = min(cfg.vlm.n_patches, S)
        h = jax.lax.dynamic_update_slice(h, pe[:, :npatch], (0, 0, 0))

    enc_out = None
    if cfg.family == "audio":
        enc_out = _encode_audio(params, cfg, extra["frames"],
                                scan_layers=scan_layers)

    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm"):
        def body(hh, lp):
            return _dense_block(lp, cfg, hh, positions, use_kernel), None
        h = _run_layers(params["layers"], h, body, scan_layers, remat)

    elif cfg.family == "moe":
        def body(hh, lp):
            hh, aux = _moe_block(lp, cfg, hh, positions, use_kernel)
            return hh, aux
        h, auxs = _run_layers(params["layers"], h, body, scan_layers, remat,
                              collect=True)
        aux_total = jnp.sum(auxs)

    elif cfg.family == "ssm":
        def body(hh, lp):
            return _mamba_block(lp, cfg, hh, use_kernel), None
        h = _run_layers(params["layers"], h, body, scan_layers, remat)

    elif cfg.family == "hybrid":
        period = cfg.hybrid.attn_period
        shared = params["shared_attn"]

        def body(carry, xs):
            hh = carry
            lp, idx = xs
            hh = _mamba_block(lp, cfg, hh, use_kernel)
            def with_attn(v):
                return _dense_block(shared, cfg, v, positions, use_kernel)
            hh = jax.lax.cond((idx % period) == period - 1,
                              with_attn, lambda v: v, hh)
            return hh, None
        idxs = jnp.arange(cfg.n_layers)
        bfn = jax.checkpoint(body) if remat else body
        if scan_layers:
            h, _ = jax.lax.scan(bfn, h, (params["layers"], idxs))
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda x: x[i], params["layers"])
                h, _ = bfn(h, (lp, jnp.asarray(i)))

    elif cfg.family == "audio":
        def body(hh, lp):
            hh = hh + L.attention(
                lp["attn"], cfg,
                L.layer_norm(lp["attn_norm"], hh, cfg.norm_eps), positions)
            q_in = L.layer_norm(lp["xattn_norm"], hh, cfg.norm_eps)
            ek = (enc_out @ lp["xattn"]["wk"]).reshape(
                B, -1, cfg.n_heads, cfg.hd)
            ev = (enc_out @ lp["xattn"]["wv"]).reshape(
                B, -1, cfg.n_heads, cfg.hd)
            hh = hh + L.attention(lp["xattn"], cfg, q_in, positions,
                                  causal=False, kv=(ek, ev))
            hh = hh + L.mlp2(lp["mlp"],
                             L.layer_norm(lp["mlp_norm"], hh, cfg.norm_eps))
            return hh, None
        h = _run_layers(params["layers"], h, body, scan_layers, remat)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return logits, aux_total



def _scan_over(body, carry, xs, scan: bool):
    """``lax.scan`` or a Python-unrolled loop over stacked [L, ...] pytrees.

    The unrolled form re-inlines the body L times — the monolithic
    compilation baseline (and the exact-cost lowering used by the roofline
    fit, since XLA's cost analysis counts a while-loop body once regardless
    of trip count)."""
    if scan:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda v: v[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _run_layers(stacked, h, body, scan_layers, remat, collect=False):
    bfn = jax.checkpoint(body) if remat else body
    if scan_layers:
        h, ys = jax.lax.scan(bfn, h, stacked)
        return (h, ys) if collect else h
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        lp = jax.tree.map(lambda x: x[i], stacked)
        h, y = bfn(h, lp)
        ys.append(y)
    return (h, jnp.stack(ys)) if collect else h


def _encode_audio(params, cfg: ModelConfig, frames: jax.Array, *,
                  scan_layers: bool = True) -> jax.Array:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    B, Ta, d = frames.shape
    h = frames.astype(_cdtype(cfg)) + params["enc_pos"][None, :Ta]
    positions = jnp.broadcast_to(jnp.arange(Ta, dtype=jnp.int32), (B, Ta))

    def body(hh, lp):
        hh = hh + L.attention(lp["attn"], cfg,
                              L.layer_norm(lp["attn_norm"], hh, cfg.norm_eps),
                              positions, causal=False)
        hh = hh + L.mlp2(lp["mlp"],
                         L.layer_norm(lp["mlp_norm"], hh, cfg.norm_eps))
        return hh, None

    h = _run_layers(params["enc_layers"], h, body, scan_layers, False)
    return L.layer_norm(params["enc_final_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ModelConfig, batch: dict, *,
            scan_layers: bool = True, remat: bool = False,
            use_kernel: bool = False) -> jax.Array:
    logits, aux = forward(params, cfg, batch["tokens"],
                          extra={k: v for k, v in batch.items()
                                 if k in ("patches", "frames")},
                          scan_layers=scan_layers, remat=remat,
                          use_kernel=use_kernel)
    return L.softmax_xent(logits, batch["labels"], z_loss=1e-4) + aux


# ---------------------------------------------------------------------------
# prefill (full-sequence forward that also populates the decode cache)
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            extra: Optional[dict] = None, max_seq: Optional[int] = None,
            use_kernel: bool = False, scan_layers: bool = True,
            true_len: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """Process a prompt; return (last-token logits [B, vocab], cache).

    The cache layout matches ``init_decode_cache(cfg, B, max_seq)`` so
    ``decode_step`` continues from it directly.

    ``true_len`` ([B] int32) enables *bucketed* prefill: ``tokens`` is
    right-padded to a shared bucket length, logits are gathered at each
    row's last real token, and ``cache["len"]`` becomes the per-row vector.
    Right padding is sound for attention-cache families because causal
    attention never lets a real token see a later pad position, and decode
    masks cache slots >= len — so the pad rows of K/V are dead weight, not
    wrong values.  (Recurrent families fold pads into their state, so the
    serving adapter keeps them on the per-slot path.)
    """
    extra = extra or {}
    B, S = tokens.shape
    max_seq = max_seq or S
    dt = _cdtype(cfg)
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.vlm is not None and "patches" in extra:
        pe = (extra["patches"] @ params["patch_proj"]).astype(h.dtype)
        npatch = min(cfg.vlm.n_patches, S)
        h = jax.lax.dynamic_update_slice(h, pe[:, :npatch], (0, 0, 0))

    enc_out = None
    if cfg.family == "audio":
        enc_out = _encode_audio(params, cfg, extra["frames"],
                                scan_layers=scan_layers)

    def pad_kv(k):   # [B, S, n, hd] -> [B, max_seq, n, hd]
        if max_seq == S:
            return k
        return jnp.pad(k, ((0, 0), (0, max_seq - S), (0, 0), (0, 0)))

    def pad_scale(sc):   # [B, S, n] -> [B, max_seq, n]
        if max_seq == S:
            return sc
        return jnp.pad(sc, ((0, 0), (0, max_seq - S), (0, 0)))

    cache: dict = {"len": jnp.asarray(S, jnp.int32) if true_len is None
                   else jnp.asarray(true_len, jnp.int32)}

    if cfg.family in ("dense", "vlm", "moe"):
        def body(hh, lp):
            x = L.rms_norm(lp["attn_norm"], hh, cfg.norm_eps)
            q, k, v = L._qkv(lp["attn"], cfg, x, positions)
            if use_kernel or cfg.attn_impl == "kernel":
                from ..kernels import ops as kops
                o = kops.flash_attention(q, k, v, causal=True,
                                         window=cfg.sliding_window)
            elif cfg.attn_impl == "chunked":
                o = L.sdpa_chunked(q, k, v, causal=True,
                                   window=cfg.sliding_window)
            else:
                o = L.sdpa(q, k, v, causal=True, window=cfg.sliding_window)
            hh = hh + o.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
            if cfg.family == "moe":
                m, _ = L.moe_layer(
                    lp["moe"], cfg,
                    L.rms_norm(lp["mlp_norm"], hh, cfg.norm_eps))
                hh = hh + m
            else:
                hh = hh + L.mlp(lp["mlp"],
                                L.rms_norm(lp["mlp_norm"], hh, cfg.norm_eps))
            if cfg.kv_quant:
                qk, sk = L.quantize_kv(k)
                qv, sv = L.quantize_kv(v)
                return hh, (pad_kv(qk), pad_kv(qv),
                            pad_scale(sk), pad_scale(sv))
            return hh, (pad_kv(k.astype(dt)), pad_kv(v.astype(dt)))
        if cfg.kv_quant:
            h, (ck, cv, ks, vs) = _scan_over(body, h, params["layers"],
                                             scan_layers)
            cache.update(k=ck, v=cv, k_scale=ks, v_scale=vs)
        else:
            h, (ck, cv) = _scan_over(body, h, params["layers"], scan_layers)
            cache.update(k=ck, v=cv)

    elif cfg.family == "ssm":
        def body(hh, lp):
            x = L.rms_norm(lp["norm"], hh, cfg.norm_eps)
            y, st, conv = _mamba_prefill(lp["mamba"], cfg, x, use_kernel)
            return hh + y, (st, conv)
        h, (st, conv) = _scan_over(body, h, params["layers"], scan_layers)
        cache.update(ssm=st, conv=conv)

    elif cfg.family == "hybrid":
        period = cfg.hybrid.attn_period
        shared = params["shared_attn"]
        n_attn = cfg.n_layers // period
        kall = jnp.zeros((n_attn, B, max_seq, cfg.n_kv_heads, cfg.hd), dt)
        vall = jnp.zeros_like(kall)

        def body(carry, xs):
            hh, kall, vall = carry
            lp, idx = xs
            x = L.rms_norm(lp["norm"], hh, cfg.norm_eps)
            y, st, conv = _mamba_prefill(lp["mamba"], cfg, x, use_kernel)
            hh = hh + y

            def with_attn(op):
                hh, kall, vall = op
                g = idx // period
                x2 = L.rms_norm(shared["attn_norm"], hh, cfg.norm_eps)
                q, k, v = L._qkv(shared["attn"], cfg, x2, positions)
                o = L.sdpa(q, k, v, causal=True)
                hh = hh + o.reshape(B, S, cfg.n_heads * cfg.hd) \
                    @ shared["attn"]["wo"]
                hh = hh + L.mlp(
                    shared["mlp"],
                    L.rms_norm(shared["mlp_norm"], hh, cfg.norm_eps))
                kall = jax.lax.dynamic_update_index_in_dim(
                    kall, pad_kv(k.astype(dt)), g, 0)
                vall = jax.lax.dynamic_update_index_in_dim(
                    vall, pad_kv(v.astype(dt)), g, 0)
                return hh, kall, vall

            hh, kall, vall = jax.lax.cond(
                (idx % period) == period - 1, with_attn, lambda op: op,
                (hh, kall, vall))
            return (hh, kall, vall), (st, conv)

        idxs = jnp.arange(cfg.n_layers)
        (h, kall, vall), (st, conv) = _scan_over(
            body, (h, kall, vall), (params["layers"], idxs), scan_layers)
        cache.update(ssm=st, conv=conv, k=kall, v=vall)

    elif cfg.family == "audio":
        ed = cfg.encdec
        def body(hh, lp):
            x = L.layer_norm(lp["attn_norm"], hh, cfg.norm_eps)
            q, k, v = L._qkv(lp["attn"], cfg, x, positions)
            o = L.sdpa(q, k, v, causal=True)
            hh = hh + o.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
            q_in = L.layer_norm(lp["xattn_norm"], hh, cfg.norm_eps)
            xk = (enc_out @ lp["xattn"]["wk"]).reshape(
                B, -1, cfg.n_heads, cfg.hd)
            xv = (enc_out @ lp["xattn"]["wv"]).reshape(
                B, -1, cfg.n_heads, cfg.hd)
            hh = hh + L.attention(lp["xattn"], cfg, q_in, positions,
                                  causal=False, kv=(xk, xv))
            hh = hh + L.mlp2(lp["mlp"],
                             L.layer_norm(lp["mlp_norm"], hh, cfg.norm_eps))
            return hh, (pad_kv(k.astype(dt)), pad_kv(v.astype(dt)),
                        xk.astype(dt), xv.astype(dt))
        h, (ck, cv, xk, xv) = _scan_over(body, h, params["layers"],
                                         scan_layers)
        cache.update(k=ck, v=cv, xk=xk, xv=xv)

    if true_len is None:
        h = h[:, -1:]
    else:
        # gather each row's last *real* token (bucket pad sits after it);
        # an empty row (len 0) clamps to position 0 — the caller treats it
        # as a dead row and discards its logits
        idx = jnp.clip(jnp.asarray(true_len, jnp.int32) - 1, 0, S - 1)
        h = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h[:, 0] @ head), cache


def _mamba_prefill(p, cfg: ModelConfig, x, use_kernel):
    """Mamba2 block that also returns (ssm_state, conv_state)."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh = s.d_inner(d), s.n_heads(d)
    G, N = s.n_groups, s.d_state
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dtv = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_state = L._causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = L.ssd_chunked(
        xin.reshape(B, S, nh, s.head_dim), dtv, A,
        Bc.reshape(B, S, G, N), Cc.reshape(B, S, G, N), p["D"],
        chunk=min(s.chunk, S), use_kernel=use_kernel)
    y = y.reshape(B, S, di)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], state.astype(jnp.float32), conv_state


# ---------------------------------------------------------------------------
# decode (one new token against a cache)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      abstract: bool = False) -> dict:
    """Cache pytree for serve_step.  With ``abstract=True`` returns
    ShapeDtypeStructs (dry-run, no allocation)."""
    dt = _cdtype(cfg)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
        else (lambda s, d: jnp.zeros(s, d))
    c: dict = {"len": mk((), jnp.int32)}
    Lc, d = cfg.n_layers, cfg.d_model
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.kv_quant:
            c["k"] = mk((Lc, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                        jnp.int8)
            c["v"] = mk((Lc, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                        jnp.int8)
            c["k_scale"] = mk((Lc, batch, max_seq, cfg.n_kv_heads),
                              jnp.float16)
            c["v_scale"] = mk((Lc, batch, max_seq, cfg.n_kv_heads),
                              jnp.float16)
        else:
            c["k"] = mk((Lc, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
            c["v"] = mk((Lc, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
    elif cfg.family == "ssm":
        s = cfg.ssm
        c["ssm"] = mk((Lc, batch, s.n_heads(d), s.head_dim, s.d_state),
                      jnp.float32)
        c["conv"] = mk((Lc, batch, s.conv_width - 1,
                        s.d_inner(d) + 2 * s.n_groups * s.d_state), dt)
    elif cfg.family == "hybrid":
        s = cfg.ssm
        n_attn = cfg.n_layers // cfg.hybrid.attn_period
        c["ssm"] = mk((Lc, batch, s.n_heads(d), s.head_dim, s.d_state),
                      jnp.float32)
        c["conv"] = mk((Lc, batch, s.conv_width - 1,
                        s.d_inner(d) + 2 * s.n_groups * s.d_state), dt)
        c["k"] = mk((n_attn, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
        c["v"] = mk((n_attn, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
    elif cfg.family == "audio":
        c["k"] = mk((Lc, batch, max_seq, cfg.n_heads, cfg.hd), dt)
        c["v"] = mk((Lc, batch, max_seq, cfg.n_heads, cfg.hd), dt)
        ed = cfg.encdec
        c["xk"] = mk((Lc, batch, ed.n_audio_ctx, cfg.n_heads, cfg.hd), dt)
        c["xv"] = mk((Lc, batch, ed.n_audio_ctx, cfg.n_heads, cfg.hd), dt)
    return c


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: dict, *, scan_layers: bool = True) -> tuple:
    """serve_step: one new token for every sequence in the batch.

    token: [B] int32.  Returns (logits [B, vocab], new cache).  Runs a
    ``lax.scan`` over the stacked per-layer cache slices so the decode body
    is compiled once per *definition*, not per layer.
    """
    B = token.shape[0]
    h = params["embed"][token][:, None, :]           # [B, 1, d]
    clen = cache["len"]
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(hh, xs):
            if cfg.kv_quant:
                lp, ck, cv, ks, vs = xs
                y, nk, nv, nks, nvs = L.attention_decode(
                    lp["attn"], cfg,
                    L.rms_norm(lp["attn_norm"], hh, cfg.norm_eps), ck, cv,
                    clen, k_scale=ks, v_scale=vs)
            else:
                lp, ck, cv = xs
                y, nk, nv = L.attention_decode(
                    lp["attn"], cfg,
                    L.rms_norm(lp["attn_norm"], hh, cfg.norm_eps), ck, cv,
                    clen)
            hh = hh + y
            if cfg.family == "moe":
                m, _ = L.moe_layer(lp["moe"], cfg,
                                   L.rms_norm(lp["mlp_norm"], hh,
                                              cfg.norm_eps))
                hh = hh + m
            else:
                hh = hh + L.mlp(lp["mlp"],
                                L.rms_norm(lp["mlp_norm"], hh, cfg.norm_eps))
            return hh, ((nk, nv, nks, nvs) if cfg.kv_quant else (nk, nv))
        if cfg.kv_quant:
            h, (nk, nv, nks, nvs) = _scan_over(
                body, h, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]), scan_layers)
            new_cache.update(k=nk, v=nv, k_scale=nks, v_scale=nvs)
        else:
            h, (nk, nv) = _scan_over(
                body, h, (params["layers"], cache["k"], cache["v"]),
                scan_layers)
            new_cache.update(k=nk, v=nv)

    elif cfg.family == "ssm":
        def body(hh, xs):
            lp, ss, cs = xs
            y, nss, ncs = L.mamba2_decode(
                lp["mamba"], cfg,
                L.rms_norm(lp["norm"], hh, cfg.norm_eps), ss, cs)
            return hh + y, (nss, ncs)
        h, (nss, ncs) = _scan_over(
            body, h, (params["layers"], cache["ssm"], cache["conv"]),
            scan_layers)
        new_cache.update(ssm=nss, conv=ncs)

    elif cfg.family == "hybrid":
        period = cfg.hybrid.attn_period
        shared = params["shared_attn"]

        # Interleave shared-attn blocks exactly as in forward(): after mamba
        # layers period-1, 2*period-1, ...  The per-block KV caches ride in
        # the scan carry and are dynamically indexed by block id.
        def body(carry, xs):
            hh, kall, vall = carry
            lp, ss, cs, idx = xs
            y, nss, ncs = L.mamba2_decode(
                lp["mamba"], cfg,
                L.rms_norm(lp["norm"], hh, cfg.norm_eps), ss, cs)
            hh = hh + y

            def with_attn(op):
                hh, kall, vall = op
                g = idx // period                    # block id
                ck = jax.lax.dynamic_index_in_dim(kall, g, 0, False)
                cv = jax.lax.dynamic_index_in_dim(vall, g, 0, False)
                y2, nk, nv = L.attention_decode(
                    shared["attn"], cfg,
                    L.rms_norm(shared["attn_norm"], hh, cfg.norm_eps),
                    ck, cv, clen)
                hh = hh + y2
                hh = hh + L.mlp(
                    shared["mlp"],
                    L.rms_norm(shared["mlp_norm"], hh, cfg.norm_eps))
                kall = jax.lax.dynamic_update_index_in_dim(kall, nk, g, 0)
                vall = jax.lax.dynamic_update_index_in_dim(vall, nv, g, 0)
                return hh, kall, vall

            hh, kall, vall = jax.lax.cond(
                (idx % period) == period - 1, with_attn, lambda op: op,
                (hh, kall, vall))
            return (hh, kall, vall), (nss, ncs)

        idxs = jnp.arange(cfg.n_layers)
        (h, nk, nv), (nss, ncs) = _scan_over(
            body, (h, cache["k"], cache["v"]),
            (params["layers"], cache["ssm"], cache["conv"], idxs),
            scan_layers)
        new_cache.update(ssm=nss, conv=ncs, k=nk, v=nv)

    elif cfg.family == "audio":
        def body(hh, xs):
            lp, ck, cv, xk, xv = xs
            y, nk, nv = L.attention_decode(
                lp["attn"], cfg,
                L.layer_norm(lp["attn_norm"], hh, cfg.norm_eps), ck, cv,
                clen)
            hh = hh + y
            q_in = L.layer_norm(lp["xattn_norm"], hh, cfg.norm_eps)
            hh = hh + L.attention(lp["xattn"], cfg, q_in,
                                  jnp.zeros((B, 1), jnp.int32),
                                  causal=False, kv=(xk, xv))
            hh = hh + L.mlp2(lp["mlp"],
                             L.layer_norm(lp["mlp_norm"], hh, cfg.norm_eps))
            return hh, (nk, nv)
        h, (nk, nv) = _scan_over(
            body, h, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]), scan_layers)
        new_cache.update(k=nk, v=nv)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h[:, 0] @ head)
    new_cache["len"] = clen + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# packed-slot serving: one batched decode step for the whole slot array
# ---------------------------------------------------------------------------
#
# The serving engine keeps ONE cache pytree of shape [..., slots, ...] (the
# batch axis of every leaf is axis 1, mirroring init_decode_cache) plus a
# per-slot ``len`` vector.  Admission writes a prefilled request's rows into
# a slot, retirement zeroes its length, and the decode step runs once per
# iteration over all slots — live or dead — with dead slots masked by
# ``len == 0``.  See docs/serving.md.

def init_packed_cache(cfg: ModelConfig, slots: int, max_seq: int,
                      abstract: bool = False) -> dict:
    """Decode cache for ``slots`` packed sequences with per-slot lengths."""
    c = init_decode_cache(cfg, slots, max_seq, abstract=abstract)
    c["len"] = (jax.ShapeDtypeStruct((slots,), jnp.int32) if abstract
                else jnp.zeros((slots,), jnp.int32))
    return c


def write_slot(packed: dict, cache: dict, row: jax.Array,
               slot: jax.Array) -> dict:
    """Copy row ``row`` of a prefill ``cache`` into slot ``slot`` of the
    packed cache.  ``cache["len"]`` must be the per-row vector form
    (``prefill(..., true_len=...)``).  Pure; jit with the packed cache
    donated so XLA updates the slot in place."""
    out = {}
    for key, dst in packed.items():
        if key == "len":
            val = jax.lax.dynamic_index_in_dim(
                jnp.asarray(cache["len"], jnp.int32), row, 0, False)
            out[key] = jax.lax.dynamic_update_index_in_dim(dst, val, slot, 0)
        else:
            src = jax.lax.dynamic_slice_in_dim(cache[key], row, 1, axis=1)
            out[key] = jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=1)
    return out


def retire_slot(packed: dict, slot: jax.Array) -> dict:
    """Free a slot: zero its length.  The stale K/V rows become dead weight
    (masked by ``len``) until the next admission overwrites them."""
    return dict(packed, len=packed["len"].at[slot].set(0))


def sample_tokens(logits: jax.Array, key: Optional[jax.Array] = None,
                  temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """On-device sampling epilogue: [B, V] logits -> [B] int32 tokens.

    ``temperature <= 0`` (or no key) is greedy argmax; otherwise
    temperature-scaled categorical, optionally truncated to the top-k
    logits.  Runs inside the jitted decode step so the host fetches one
    small token vector per step instead of per-slot logits."""
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k and top_k < scaled.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServingAdapter:
    """The batched-decode protocol consumed by ``ServingEngine``.

    ``prefill_fn(tokens[B,S], true_len[B], step) -> (first_tok[B], cache)``
    ``step_fn(tokens[slots], packed, step) -> (next_tok[slots], packed)``
    ``write_slot_fn(packed, cache, row, slot) -> packed``
    ``retire_fn(packed, slot) -> packed``

    All four are pure jax functions (NOT pre-jitted): the engine compiles
    them through the persistent compile cache so a fresh process resolves
    every previously-seen shape from disk.  ``step`` is a traced int32
    scalar (the global step counter) feeding the sampler's fold_in — it
    does not trigger recompiles.
    """
    cfg: ModelConfig
    max_seq: int
    prefill_fn: Any
    step_fn: Any
    write_slot_fn: Any
    retire_fn: Any
    temperature: float = 0.0
    top_k: int = 0

    def init_slots(self, slots: int, abstract: bool = False) -> dict:
        return init_packed_cache(self.cfg, slots, self.max_seq,
                                 abstract=abstract)


def serving_adapter(params: Params, cfg: ModelConfig, *, max_seq: int,
                    temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                    scan_layers: bool = True) -> ServingAdapter:
    """Build the packed-slot batched decode adapter for a model.

    Only attention-cache families qualify: right-padded bucketed prefill is
    exact for them (see ``prefill``).  Recurrent state (ssm/hybrid) and
    encoder-decoder extras (audio) would absorb pad tokens, so those
    families stay on the engine's per-slot fallback.
    """
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(
            f"batched serving supports attention-cache families "
            f"(dense/vlm/moe), not {cfg.family!r}; use the per-slot path")
    base_key = jax.random.PRNGKey(seed)

    def _sample(logits, step):
        key = jax.random.fold_in(base_key, step)
        return sample_tokens(logits, key, temperature, top_k)

    def prefill_fn(tokens, true_len, step):
        logits, cache = prefill(params, cfg, tokens, max_seq=max_seq,
                                true_len=true_len, scan_layers=scan_layers)
        return _sample(logits, step), cache

    def step_fn(tokens, packed, step):
        live = packed["len"] > 0
        logits, ncache = decode_step(params, cfg, tokens, packed,
                                     scan_layers=scan_layers)
        # dead slots must stay at len 0 (liveness is derived from it) and
        # emit a harmless pad token
        ncache["len"] = jnp.where(live, packed["len"] + 1, 0)
        nxt = _sample(logits, step)
        return jnp.where(live, nxt, 0).astype(jnp.int32), ncache

    return ServingAdapter(cfg=cfg, max_seq=max_seq,
                          prefill_fn=prefill_fn, step_fn=step_fn,
                          write_slot_fn=write_slot, retire_fn=retire_slot,
                          temperature=temperature, top_k=top_k)
