"""Crash recovery: engine-agnostic task-graph snapshots + supervised restart.

PR 6 made faults *detectable* (chaos harness, watchdogs); this module makes
detected faults *survivable*:

* :class:`GraphSnapshot` — the complete execution state of a step-form task
  graph at a quiescent point: per-task firing counters, per-task state
  pytrees, channel ring contents, and mmap buffer copies, keyed by
  ``Graph.structural_hash()``.  The representation is **engine-agnostic**:
  it is exactly the ``lax.while_loop`` carry of the synthesized program
  (:mod:`repro.core.synth`), which the Python engines reproduce token-for-
  token, so a snapshot captured under one engine restores under any other
  and the run finishes with bit-identical mmap outputs.

* :class:`SnapshotStore` — persistence via the digest-verified
  :class:`~repro.ckpt.manager.CheckpointManager` path: atomic publish,
  sha256 manifests, and ``restore_latest`` falling past corrupt snapshots.

* :func:`run_recoverable` — chunked execution: the run is cut at sweep
  boundaries of the *abstract schedule* (a pure-Python replay of the
  compiled sweep semantics over token counts alone); each boundary is
  quiescent by construction and snapshots there.  Under ``CompiledEngine``
  each chunk is one budgeted ``lax.while_loop`` invocation whose carry is
  the snapshot; under the Python engines each chunk re-invokes every task
  with a per-chunk firing quota derived from the same schedule.

* :func:`run_supervised` — bounded restarts with exponential backoff.  A
  :class:`~repro.core.errors.CrashFault` (the ``FaultPlan.crash`` kind)
  aborts the run mid-chunk; the supervisor restores the latest snapshot
  and resumes, so final outputs match the fault-free run.

Why sweep boundaries are consistent cuts: the abstract schedule is a valid
execution order, so the firing-count vector at any of its prefixes is
reachable under every fair blocking engine (the KPN argument: firing counts
determine channel contents, task states and mmap contents deterministically
for the step-function subset — no peek/select/EoT, static I/O rates).

``async_mmap`` ports are recoverable on the compiled engine: the port's
latency queue (per-direction addr/due/value rings, FIFO heads/sizes, and
request counters) lives in the resumable while_loop carry, so snapshots
carry those rows verbatim — due stamps rebased to "sweeps remaining" at
each chunk boundary — and the abstract schedule replays the port service
step (accept/deliver, FIFO order, latency stamping) over token counts.
On the *Python* engines port graphs still refuse: a per-chunk firing
quota cannot bound the simulators' event-driven port pumps at a sweep
boundary.

What is *not* recoverable at all (documented in docs/robustness.md):
graphs outside the step subset (EoT termination, ``peek``/``select``
routing) have no schedule-independent cut; for those
:func:`run_supervised` degrades to restart-from-scratch supervision.  The
container-level :func:`capture_port` / :func:`restore_port` helpers still
snapshot an ``AsyncMMap``'s outstanding-request state (accepted-but-
undelivered requests re-queue and re-issue on restore) for host-driven
checkpointing of async graphs.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.manager import CheckpointManager
from ..core.channel import Channel
from ..core.engines import ENGINES, SimReport
from ..core.errors import CrashFault, SynthesisError
from ..core.faults import FaultInjector, FaultPlan
from ..core.interface import AsyncMMap
from ..core.synth import (_build_program, _canon_dtype, _port_carry0,
                          _twin_view, elaborate_step_graph)
from ..core.task import task


# ---------------------------------------------------------------------------
# container-level capture/restore (any channel, any engine)
# ---------------------------------------------------------------------------

@dataclass
class ChannelState:
    """Raw contents of one channel: data tokens in order, with EoT tokens
    in place (the EOT singleton), plus the derived EoT count."""

    tokens: list
    eot_count: int = 0


def capture_channel(chan: Channel) -> ChannelState:
    return ChannelState(tokens=list(chan._q), eot_count=chan._eot_count)


def restore_channel(chan: Channel, st: ChannelState) -> None:
    """Overwrite a channel's queue with a captured state.  Waiter lists are
    cleared — restore happens between runs, when no task is parked."""
    chan._q = deque(st.tokens)
    chan._eot_count = st.eot_count
    chan._rwait.clear()
    chan._wwait.clear()


@dataclass
class PortState:
    """Outstanding-request state of one ``AsyncMMap`` port.

    ``queues`` holds the five port channels (issued-but-unaccepted requests
    and delivered-but-unread responses); ``inflight_*`` the accepted-but-
    undelivered requests, which otherwise live only as closures in the
    engine's event heap.  Restore re-queues them *ahead* of the unaccepted
    requests, so the next pump re-accepts and re-schedules them — same
    result values, fresh latency."""

    data: Any
    queues: list = field(default_factory=list)       # [ChannelState] x5
    inflight_reads: list = field(default_factory=list)
    inflight_writes: list = field(default_factory=list)  # [(addr, value)]


def capture_port(amap: AsyncMMap) -> PortState:
    buf = np.asarray(amap.data)
    return PortState(
        data=np.array(buf, copy=True),
        queues=[capture_channel(c) for c in amap.channels()],
        inflight_reads=list(amap._inflight_reads),
        inflight_writes=list(amap._inflight_writes),
    )


def restore_port(amap: AsyncMMap, st: PortState) -> None:
    if isinstance(amap.data, np.ndarray):
        np.copyto(amap.data, st.data)
    else:
        amap.data = np.array(st.data, copy=True)
    for c, cs in zip(amap.channels(), st.queues):
        restore_channel(c, cs)
    # accepted-but-undelivered requests go back to the head of the request
    # FIFOs, in acceptance order, ahead of anything not yet accepted
    for addr in reversed(st.inflight_reads):
        amap._raddr._q.appendleft(addr)
    for addr, value in reversed(st.inflight_writes):
        amap._wdata._q.appendleft(value)
        amap._waddr._q.appendleft(addr)
    amap._pending_reads = amap._pending_writes = 0
    amap._inflight_reads = []
    amap._inflight_writes = []
    # the re-queued requests will be re-accepted: rewind the acceptance
    # counters so stats don't double-count them
    amap.read_reqs -= len(st.inflight_reads)
    amap.write_reqs -= len(st.inflight_writes)


# ---------------------------------------------------------------------------
# graph snapshots (step-form subset, engine-agnostic)
# ---------------------------------------------------------------------------

@dataclass
class GraphSnapshot:
    """Execution state of a step-form graph at a sweep boundary.

    ``chans`` stores each channel as a zero-padded ``(capacity, *elem)``
    buffer plus an occupancy count, head-normalized to index 0 — the ring's
    head position is value-irrelevant (all indexing is modular), so this is
    the canonical form every engine round-trips through."""

    graph_hash: str
    sweep: int
    fires: np.ndarray                  # (n_tasks,) int32 firing counters
    states: list                       # per-task state pytrees
    chans: list                        # [(buf ndarray, size int)]
    mmaps: list                        # [ndarray copy] per plan mmap
    engine: str = ""
    meta: dict = field(default_factory=dict)
    # per-port latency-queue rows: the 16-entry ``_port_carry0`` tuple as
    # np arrays (data buffer; read addr/due rings + head/size; write
    # addr/due/value rings + head/size; 6 request counters), with due
    # stamps rebased to "sweeps remaining" by the resumable program
    ports: list = field(default_factory=list)


def _snapshot_python(plan, graph_hash: str, sweep: int, fires, states,
                     caps: list, engine: str) -> GraphSnapshot:
    """Capture from live host state: channel deques + host mmap buffers."""
    chans = []
    for ci, c in enumerate(plan.channels):
        shape = (caps[ci],) + c.shape
        buf = np.zeros(shape, _canon_dtype(c.dtype))
        toks = list(c._q)
        if len(toks) > caps[ci]:
            raise ValueError(
                f"channel {c.name!r} holds {len(toks)} tokens at a sweep "
                f"boundary but snapshots reserve capacity {caps[ci]}")
        for i, t in enumerate(toks):
            buf[i] = np.asarray(t)
        chans.append((buf, len(toks)))
    mmaps = [np.array(np.asarray(jnp.asarray(m.data)), copy=True)
             for m in plan.mmaps]
    return GraphSnapshot(
        graph_hash=graph_hash, sweep=sweep,
        fires=np.asarray(fires, np.int32),
        states=[jax.tree.map(np.asarray, s) for s in states],
        chans=chans, mmaps=mmaps, engine=engine)


def _snapshot_carry(plan, graph_hash: str, sweep: int, chans, states,
                    mmaps, fires, engine: str,
                    ports: tuple = ()) -> GraphSnapshot:
    """Capture from a resumable compiled carry — the carry *is* the
    snapshot; this only head-normalizes the rings and host-copies.
    Port rows copy verbatim (the program already rebased their due
    stamps to chunk-relative form)."""
    out_chans = []
    for (buf, head, size), c in zip(chans, plan.channels):
        b = np.asarray(buf)
        h, n = int(head), int(size)
        cap = b.shape[0]
        b = b[(h + np.arange(cap)) % cap]
        b[n:] = 0                       # canonical: tail slots zeroed
        out_chans.append((b, n))
    return GraphSnapshot(
        graph_hash=graph_hash, sweep=sweep,
        fires=np.asarray(fires, np.int32),
        states=[jax.tree.map(np.asarray, s) for s in states],
        mmaps=[np.array(np.asarray(m), copy=True) for m in mmaps],
        chans=out_chans, engine=engine,
        ports=[[np.asarray(x) for x in pc] for pc in ports])


def _restore_python(plan, snap: GraphSnapshot, caps: list) -> None:
    """Write a snapshot back into live host state: channel deques refill
    (healing any torn mid-chunk pushes) and mmap buffers restore."""
    for ci, (c, (buf, size)) in enumerate(zip(plan.channels, snap.chans)):
        c.capacity = caps[ci]           # heal sequential capacity growth
        c._q = deque(jnp.asarray(buf[i]) for i in range(int(size)))
        c._eot_count = 0
        c._rwait.clear()
        c._wwait.clear()
    _restore_mmaps(plan, snap)


def _restore_mmaps(plan, snap: GraphSnapshot) -> None:
    for m, saved in zip(plan.mmaps, snap.mmaps):
        if isinstance(m.data, np.ndarray):
            np.copyto(m.data, saved)
        else:
            m.data = np.array(saved, copy=True)


def _carry_from_snapshot(plan, snap: GraphSnapshot):
    chans = tuple(
        (jnp.asarray(buf), jnp.zeros((), jnp.int32),
         jnp.asarray(np.int32(size)))
        for buf, size in snap.chans)
    states = tuple(jax.tree.map(jnp.asarray, s) for s in snap.states)
    mmaps = tuple(jnp.asarray(m) for m in snap.mmaps)
    fires = jnp.asarray(snap.fires, jnp.int32)
    if len(snap.ports) == len(plan.ports):
        ports = tuple(tuple(jnp.asarray(x) for x in pc)
                      for pc in snap.ports)
    else:                               # pre-port snapshot of a port graph
        ports = tuple(_port_carry0(p) for p in plan.ports)
    return chans, states, mmaps, ports, fires


def _initial_snapshot(plan, graph_hash: str, caps: list,
                      engine: str) -> GraphSnapshot:
    """The sweep-0 snapshot: empty channels, initial states, and — the
    load-bearing part — a copy of every mmap's *initial* contents (and
    every port's backing buffer), so a restart can heal host buffers
    torn by a crash mid-chunk."""
    chans = [(np.zeros((caps[ci],) + c.shape, _canon_dtype(c.dtype)), 0)
             for ci, c in enumerate(plan.channels)]
    return GraphSnapshot(
        graph_hash=graph_hash, sweep=0,
        fires=np.zeros((len(plan.tasks),), np.int32),
        states=[jax.tree.map(np.asarray, tp.state0) for tp in plan.tasks],
        chans=chans,
        mmaps=[np.array(np.asarray(jnp.asarray(m.data)), copy=True)
               for m in plan.mmaps],
        engine=engine,
        ports=[[np.asarray(x) for x in _port_carry0(p)]
               for p in plan.ports])


# ---------------------------------------------------------------------------
# persistence (CheckpointManager-backed)
# ---------------------------------------------------------------------------

class SnapshotStore:
    """Persist :class:`GraphSnapshot` objects through the digest-verified
    checkpoint path: atomic tmp→rename publish, per-leaf sha256 manifests,
    and restore-latest falling past corrupt snapshots.  Snapshots are
    keyed by sweep number (the "step") and carry the graph's structural
    hash in the manifest — a snapshot of a *different* graph is never
    restored."""

    def __init__(self, directory, keep: int = 3, faults: Any = None):
        self.mgr = CheckpointManager(directory, keep=keep, faults=faults)

    @staticmethod
    def _like(plan, caps: list) -> dict:
        tree = {
            "fires": jnp.zeros((len(plan.tasks),), jnp.int32),
            "chans": [
                {"buf": jnp.zeros((caps[ci],) + c.shape,
                                  _canon_dtype(c.dtype)),
                 "size": jnp.zeros((), jnp.int32)}
                for ci, c in enumerate(plan.channels)],
            "states": [jax.tree.map(jnp.asarray, tp.state0)
                       for tp in plan.tasks],
            "mmaps": [jnp.zeros(tuple(m.shape),
                                jax.dtypes.canonicalize_dtype(
                                    np.dtype(m.dtype)))
                      for m in plan.mmaps],
        }
        if plan.ports:
            # schema rows for the latency queue — present only for port
            # graphs, so port-free snapshots stay byte-compatible with
            # every earlier store
            tree["ports"] = [
                [jnp.zeros_like(jnp.asarray(x))
                 for x in _port_carry0(p)]
                for p in plan.ports]
        return tree

    def save(self, snap: GraphSnapshot) -> None:
        tree = {
            "fires": jnp.asarray(snap.fires, jnp.int32),
            "chans": [{"buf": jnp.asarray(buf),
                       "size": jnp.asarray(np.int32(size))}
                      for buf, size in snap.chans],
            "states": [jax.tree.map(jnp.asarray, s) for s in snap.states],
            "mmaps": [jnp.asarray(m) for m in snap.mmaps],
        }
        if snap.ports:
            tree["ports"] = [[jnp.asarray(x) for x in pc]
                             for pc in snap.ports]
        self.mgr.save(snap.sweep, tree, {}, extra={
            "graph_hash": snap.graph_hash, "sweep": snap.sweep,
            "engine": snap.engine, **snap.meta})

    def load_latest(self, plan, graph_hash: str,
                    caps: Optional[list] = None) -> Optional[GraphSnapshot]:
        caps = caps if caps is not None \
            else [c.capacity for c in plan.channels]
        try:
            got = self.mgr.restore_latest(self._like(plan, caps), {})
        except Exception:
            # a snapshot of a structurally different graph in this
            # directory: its leaf files don't line up with our like-tree.
            # Treat as "no usable snapshot" rather than poisoning the run.
            return None
        if got is None:
            return None
        step, tree, _, extra = got
        if extra.get("graph_hash") != graph_hash:
            return None
        return GraphSnapshot(
            graph_hash=graph_hash,
            sweep=int(extra.get("sweep", step)),
            fires=np.asarray(tree["fires"], np.int32),
            states=[jax.tree.map(np.asarray, s) for s in tree["states"]],
            chans=[(np.asarray(c["buf"]), int(c["size"]))
                   for c in tree["chans"]],
            mmaps=[np.asarray(m) for m in tree["mmaps"]],
            engine=str(extra.get("engine", "")),
            ports=[[np.asarray(x) for x in pc]
                   for pc in tree.get("ports", [])])


# ---------------------------------------------------------------------------
# the abstract schedule (pure-Python replay of the compiled sweep)
# ---------------------------------------------------------------------------

def _abstract_schedule(plan) -> tuple[list, bool]:
    """Replay ``_build_program``'s sweep semantics over token counts alone.

    Returns ``(cuts, stalled)``: ``cuts[s]`` is the per-task firing vector
    after ``s`` sweeps (``cuts[0]`` all-zero), mirroring the compiled body
    exactly — plan-order task iteration, *start-of-sweep* guard
    visibility (the fused ``eval_guards`` semantics: every task's fire
    predicate is computed from the occupancy vector as the sweep begins,
    then effects apply in task order), bounds-based phase selection,
    read-available / write-fits guards — so ``cuts[s]`` equals the
    compiled ``fires`` after ``s`` sweeps and is a consistent cut for
    every engine.

    Port graphs replay the service step too (after the task loop, in
    ``_service_ports``'s exact order: deliver due reads, deliver due
    writes, accept reads, accept writes — up to ``depth`` each): the
    in-flight windows are pure-Python FIFOs of due sweeps, and sweeps
    where the only progress is an in-flight request maturing ("waiting")
    append duplicate cut entries, exactly like the compiled loop.

    ``stalled`` is True when the schedule stopped making progress before
    every task fired out (the abstract twin of the compiled stall /
    simulated deadlock)."""
    caps = [c.capacity for c in plan.channels]
    sizes = [0] * len(caps)
    fires = [0] * len(plan.tasks)
    totals = [tp.total for tp in plan.tasks]
    cuts = [tuple(fires)]
    read_q = [[] for _ in plan.ports]     # due sweeps, FIFO per port
    write_q = [[] for _ in plan.ports]
    sweeps = 0
    while any(f < t for f, t in zip(fires, totals)) or \
            any(read_q[pi] or write_q[pi] for pi in range(len(plan.ports))):
        progress = False
        sizes0 = list(sizes)    # start-of-sweep snapshot (fused guards)
        for ti, tp in enumerate(plan.tasks):
            f = fires[ti]
            if f >= totals[ti]:
                continue
            phase = sum(f >= b for b in tp.bounds[:-1])
            ph = tp.phases[phase]
            ok = all(sizes0[ci] >= r for ci, r in ph.reads.items()) and \
                all(caps[ci] - sizes0[ci] >= w
                    for ci, w in ph.writes.items())
            if ok:
                for ci, r in ph.reads.items():
                    sizes[ci] -= r
                for ci, w in ph.writes.items():
                    sizes[ci] += w
                fires[ti] = f + 1
                progress = True
        for pi, port in enumerate(plan.ports):
            d, lat = port.depth, port.latency
            ra, rd, wa, wd, wr = plan.port_chan_ids[pi]
            for _ in range(d):          # deliver due reads
                if read_q[pi] and read_q[pi][0] <= sweeps \
                        and sizes[rd] < caps[rd]:
                    read_q[pi].pop(0)
                    sizes[rd] += 1
                    progress = True
            for _ in range(d):          # deliver due writes
                if write_q[pi] and write_q[pi][0] <= sweeps \
                        and sizes[wr] < caps[wr]:
                    write_q[pi].pop(0)
                    sizes[wr] += 1
                    progress = True
            for _ in range(d):          # accept queued reads
                if sizes[ra] > 0 and len(read_q[pi]) < d:
                    sizes[ra] -= 1
                    read_q[pi].append(sweeps + lat)
                    progress = True
            for _ in range(d):          # accept queued writes (addr+value)
                if sizes[wa] > 0 and sizes[wd] > 0 and len(write_q[pi]) < d:
                    sizes[wa] -= 1
                    sizes[wd] -= 1
                    write_q[pi].append(sweeps + lat)
                    progress = True
            # an in-flight request due in the future counts as progress
            # pending, same as the compiled ``waiting`` flag
            progress = progress or any(
                due > sweeps for due in read_q[pi] + write_q[pi])
        if not progress:
            return cuts, True
        cuts.append(tuple(fires))
        sweeps += 1
    return cuts, False


def _reset_endpoints(plan) -> None:
    """Clear channel endpoint bindings so the same channel objects can be
    re-bound by the next chunk's fresh task instances (elaboration and
    every chunk each create their own :class:`TaskInstance` set; the
    one-producer/one-consumer rule is enforced per chunk)."""
    for c in plan.channels:
        c.producer = c.consumer = c.parent = None
        c._rwait.clear()
        c._wwait.clear()


# ---------------------------------------------------------------------------
# chunk execution
# ---------------------------------------------------------------------------

def _chunk_task_body(tp, start: int, stop: int, states: list,
                     ti: int) -> Callable:
    """A task body that runs firings ``start..stop`` of one StepTask
    instance against live blocking streams — the per-chunk slice of the
    simulation twin.  State is carried across chunks in ``states``."""
    bounds = tp.bounds
    phases = tp.phases

    def body(*args, **kwargs):
        views = tuple(_twin_view(a) for a in args)
        kw = {k: _twin_view(v) for k, v in kwargs.items()}
        state = states[ti]
        for f in range(start, stop):
            pi = 0
            while f >= bounds[pi]:
                pi += 1
            state = phases[pi].fn(state, *views, **kw)
        states[ti] = state

    body.__name__ = tp.inst.name.split("#", 1)[0]
    return body


def _run_python_chunk(plan, engine: str, fires0, fires1, states: list,
                      faults: Optional[FaultInjector]) -> SimReport:
    _reset_endpoints(plan)
    states_dev = [jax.tree.map(jnp.asarray, s) for s in states]

    def recovery_chunk():
        tb = task()
        for ti, tp in enumerate(plan.tasks):
            tb.invoke(_chunk_task_body(tp, int(fires0[ti]), int(fires1[ti]),
                                       states_dev, ti),
                      *tp.inst.args, name=tp.inst.name, **tp.inst.kwargs)

    rep = ENGINES[engine](faults=faults).run(recovery_chunk)
    if rep.ok:
        for ti in range(len(plan.tasks)):
            states[ti] = states_dev[ti]
    return rep


def _synth_report(engine: str, ok: bool, wall: float, err: Optional[str],
                  result: Any, switches: int, plan,
                  failure: Optional[BaseException] = None) -> SimReport:
    return SimReport(
        engine=engine, ok=ok, wall_s=wall, switches=switches,
        n_instances=len(plan.tasks), n_channels=len(plan.channels),
        tokens=0, error=err, result=result, failure=failure)


def run_recoverable(engine: str, top: Callable, *args,
                    store: Optional[SnapshotStore] = None,
                    snapshot_every: int = 8,
                    faults: Any = None, **kwargs) -> SimReport:
    """Run a step-form graph in snapshot-bounded chunks.

    Elaborates the graph once (:func:`elaborate_step_graph` — raises
    :class:`SynthesisError` outside the step subset), derives the abstract
    sweep schedule, resumes from the latest matching snapshot in ``store``
    (if any), then executes chunk by chunk — snapshotting at every
    boundary.  A :class:`CrashFault` injected mid-chunk propagates to the
    caller (the supervisor's restart signal); everything the crash tore is
    healed by the snapshot restore on the next attempt.

    ``engine`` may be any of the four engines.  The sequential engine runs
    as a single chunk (its only quiescent points are start and finish: it
    cannot honor channel capacity mid-run, so intermediate cuts are not
    capturable) and fails on the same graphs plain sequential simulation
    fails on.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"choose from {sorted(ENGINES)}")
    inj = faults.injector() if isinstance(faults, FaultPlan) else faults
    t0 = time.perf_counter()
    plan, graph, result = elaborate_step_graph(top, *args, **kwargs)
    if getattr(plan, "ports", None) and engine != "compiled":
        # compiled chunks carry the latency queue in the resumable
        # while_loop carry (snapshot rows since this schema); the Python
        # engines' event-driven port pumps cannot be cut at a sweep
        # boundary by a firing quota.  Refuse so the supervisor degrades
        # to restart-from-scratch (run_supervised).
        raise SynthesisError(
            f"recoverable execution of async_mmap ports "
            f"({[p.name for p in plan.ports]}) requires "
            f"engine='compiled': the simulation engines' in-flight port "
            f"requests live in the event heap, outside the sweep-"
            f"boundary snapshot; run engine='compiled' or under "
            f"restart-from-scratch supervision")
    ghash = graph.structural_hash()
    caps = [c.capacity for c in plan.channels]
    cuts, stalled = _abstract_schedule(plan)
    total_sweeps = len(cuts) - 1
    every = max(1, int(snapshot_every))
    if engine == "sequential":
        every = max(total_sweeps, 1)

    snap = store.load_latest(plan, ghash, caps) if store is not None \
        else None
    if snap is not None:
        if snap.sweep > total_sweeps or \
                not np.array_equal(snap.fires, np.asarray(cuts[snap.sweep],
                                                          np.int32)):
            snap = None             # stale/foreign snapshot: start over
    if snap is None:
        snap = _initial_snapshot(plan, ghash, caps, engine)
        if store is not None:
            store.save(snap)

    switches = 0
    if engine == "compiled":
        program = jax.jit(_build_program(plan, resumable=True))
        chans, states, mmaps, ports, fires = _carry_from_snapshot(plan,
                                                                  snap)
        s0 = snap.sweep
        while s0 < total_sweeps:
            if inj is not None:
                inj.crash_point("chunk")
            s1 = min(s0 + every, total_sweeps)
            (chans, states, mmaps, ports, fires, progress, sweeps, _,
             _) = program(states, mmaps, chans, ports, fires,
                          np.int32(s1 - s0))
            switches += int(sweeps)
            s0 = s1
            if store is not None:
                store.save(_snapshot_carry(plan, ghash, s0, chans, states,
                                           mmaps, fires, engine,
                                           ports=ports))
            if not bool(progress):
                break
        # write device results back into the host buffers (all mmaps: for
        # a resumed-at-completion run this re-publishes the snapshot)
        for m, dev in zip(plan.mmaps, mmaps):
            out = np.asarray(dev)
            if isinstance(m.data, np.ndarray):
                np.copyto(m.data, out)
            else:
                m.data = out
        for p, pc in zip(plan.ports, ports):
            out = np.asarray(pc[0])     # _P_DATA: the port's buffer
            if isinstance(p.data, np.ndarray):
                np.copyto(p.data, out)
            else:
                p.data = out
        fires = np.asarray(fires)
    else:
        _restore_python(plan, snap, caps)
        states = [jax.tree.map(jnp.asarray, s) for s in snap.states]
        s0 = snap.sweep
        fires = np.asarray(snap.fires, np.int32)
        while s0 < total_sweeps:
            if inj is not None:
                inj.crash_point("chunk")
            s1 = min(s0 + every, total_sweeps)
            rep = _run_python_chunk(plan, engine, cuts[s0], cuts[s1],
                                    states, inj)
            switches += rep.switches
            if not rep.ok:
                if isinstance(rep.failure, CrashFault):
                    raise rep.failure
                return _synth_report(engine, False,
                                     time.perf_counter() - t0, rep.error,
                                     result, switches, plan, rep.failure)
            s0 = s1
            fires = np.asarray(cuts[s0], np.int32)
            if store is not None:
                store.save(_snapshot_python(plan, ghash, s0, fires, states,
                                            caps, engine))

    totals = np.asarray([tp.total for tp in plan.tasks], np.int32)
    done = bool(np.all(fires >= totals))
    err = None
    if not done:
        blocked = [tp.inst.name for tp, f, t in zip(plan.tasks, fires,
                                                    totals) if f < t]
        err = (f"recoverable run stalled after {switches} sweeps; "
               f"blocked tasks: {blocked}")
    return _synth_report(engine, done, time.perf_counter() - t0, err,
                         result, switches, plan)


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------

@dataclass
class RestartPolicy:
    """Bounded-restart policy: at most ``max_restarts`` restarts, sleeping
    ``backoff_s * backoff_factor**k`` before the k-th one."""

    max_restarts: int = 3
    backoff_s: float = 0.01
    backoff_factor: float = 2.0


def run_supervised(engine: str, top: Callable, *args,
                   policy: Optional[RestartPolicy] = None,
                   store: Optional[SnapshotStore] = None,
                   snapshot_every: int = 8,
                   faults: Any = None, **kwargs) -> SimReport:
    """Supervised execution: run, and on a :class:`CrashFault` restore the
    latest snapshot and restart — bounded restarts, exponential backoff.

    With ``store`` set and the graph inside the step subset, restarts
    resume from the last sweep-boundary snapshot (:func:`run_recoverable`).
    With ``store`` unset, the run delegates *directly* to the plain engine
    (zero snapshot overhead — the benchmarked path) and a crash restarts
    from scratch.  Graphs outside the step subset (SynthesisError at
    elaboration) likewise fall back to restart-from-scratch supervision.

    The fault injector is shared across attempts, so a ``FaultPlan.crash``
    site fires exactly once: the retried run sails past the crash point,
    which is precisely what the recovery parity tests assert.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"choose from {sorted(ENGINES)}")
    policy = policy if policy is not None else RestartPolicy()
    inj = faults.injector() if isinstance(faults, FaultPlan) else faults
    use_chunks = store is not None
    restarts = 0
    delay = policy.backoff_s
    last_exc: Optional[BaseException] = None
    while True:
        try:
            if use_chunks:
                try:
                    return run_recoverable(
                        engine, top, *args, store=store,
                        snapshot_every=snapshot_every, faults=inj,
                        **kwargs)
                except SynthesisError:
                    use_chunks = False      # outside the step subset
                    continue
            rep = ENGINES[engine](faults=inj).run(top, *args, **kwargs)
            if rep.ok or not isinstance(rep.failure, CrashFault):
                return rep
            last_exc = rep.failure
        except CrashFault as e:
            last_exc = e
        restarts += 1
        if restarts > policy.max_restarts:
            raise CrashFault(
                f"supervised run still crashing after "
                f"{policy.max_restarts} restarts") from last_exc
        time.sleep(delay)
        delay *= policy.backoff_factor
