from .elastic import (ElasticMesh, PreemptionGuard, StragglerDetector,
                      resume_or_init)
from .recovery import (GraphSnapshot, RestartPolicy, SnapshotStore,
                       capture_channel, capture_port, restore_channel,
                       restore_port, run_recoverable, run_supervised)

__all__ = ["ElasticMesh", "PreemptionGuard", "StragglerDetector",
           "resume_or_init", "GraphSnapshot", "RestartPolicy",
           "SnapshotStore", "capture_channel", "capture_port",
           "restore_channel", "restore_port", "run_recoverable",
           "run_supervised"]
