from .elastic import (ElasticMesh, PreemptionGuard, StragglerDetector,
                      resume_or_init)

__all__ = ["ElasticMesh", "PreemptionGuard", "StragglerDetector",
           "resume_or_init"]
