"""Fault tolerance: preemption-safe training, stragglers, elastic re-mesh.

At 1000+ nodes failures are the steady state, not the exception.  Three
mechanisms, each independent and composable with the train driver:

* :class:`PreemptionGuard` — converts SIGTERM/SIGINT into a cooperative
  "checkpoint and exit" at the next step boundary (TPU preemption notice,
  spot reclamation).  Exercisable in-process for tests via ``.trigger()``.

* :class:`StragglerDetector` — per-step wall-time EMA + deviation; a host
  whose step time exceeds ``mean + z * std`` persistently is flagged so the
  orchestrator can drop/replace it.  At the single-controller level this
  guards against data-loader stalls and host-side GC pauses; the pod-level
  signal aggregation uses the same math.

* :class:`ElasticMesh` — re-build the device mesh after losing nodes and
  re-shard state onto it.  Sharding specs in this repo are *functions of
  the mesh* (distributed/sharding.py), so elasticity is: make new mesh ->
  recompute specs -> ``jax.device_put`` the host snapshot (or checkpoint)
  with the new shardings -> continue.  ``shrink()`` returns the largest
  usable (data, model) grid for the surviving chip count, preferring to
  shrink the data axis (model-parallel groups must stay intact because
  parameter shards live there).

``resume_or_init`` is the standard restart protocol used by the train
driver: restore the latest complete checkpoint if one exists, else
initialize fresh — so a crashed/preempted/rescheduled job is always
``python train.py`` again, no flags.
"""

from __future__ import annotations

import math
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from ..ckpt import CheckpointManager


class PreemptionGuard:
    """SIGTERM/SIGINT -> finish the current step, checkpoint, exit clean.

    The handler lifecycle is explicit and re-entrant-safe: ``install()``
    saves the previous handlers exactly once, ``uninstall()`` restores
    them and forgets them (idempotent — a second call is a no-op, and a
    guard can be re-installed afterwards).  Nested guards therefore
    restore handlers correctly as long as they uninstall in LIFO order.
    Usable as a context manager: ``with PreemptionGuard() as g: ...``.
    """

    def __init__(self, install: bool = True):
        self.requested = False
        self.installed = False
        self._prev = {}
        if install:
            self.install()

    def install(self) -> None:
        if self.installed:
            raise ValueError("PreemptionGuard is already installed")
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:          # non-main thread (tests)
                pass
        self.installed = True

    def _handler(self, signum, frame):
        self.requested = True

    def trigger(self) -> None:
        """In-process preemption (tests / drills)."""
        self.requested = True

    def uninstall(self) -> None:
        if not self.installed:
            return
        for sig, h in self._prev.items():
            signal.signal(sig, h)
        self._prev = {}
        self.installed = False

    def __enter__(self) -> "PreemptionGuard":
        if not self.installed:
            self.install()
        return self

    def __exit__(self, *exc) -> bool:
        self.uninstall()
        return False


class StragglerDetector:
    """EMA step-time monitor; flags persistent outliers."""

    def __init__(self, z: float = 3.0, patience: int = 3,
                 alpha: float = 0.1):
        self.z = z
        self.patience = patience
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.var = 0.0
        self._strikes = 0
        self.flagged = False
        self.history: list[float] = []

    def observe(self, step_seconds: float) -> bool:
        """Feed one step time; returns True if this step is an outlier."""
        self.history.append(step_seconds)
        if self.mean is None:
            self.mean = step_seconds
            return False
        std = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
        outlier = step_seconds > self.mean + self.z * std
        if outlier:
            self._strikes += 1
            if self._strikes >= self.patience:
                self.flagged = True
        else:
            self._strikes = 0
            # only track healthy steps in the baseline
            d = step_seconds - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return outlier


class ElasticMesh:
    """Rebuild the mesh after node loss and re-shard state onto it."""

    def __init__(self, axis_names: tuple = ("data", "model")):
        self.axis_names = axis_names

    @staticmethod
    def shrink(n_devices: int, model_parallel: int) -> tuple[int, int]:
        """Largest (data, model) grid for the surviving chips; the model
        axis is preserved (its groups hold parameter shards), the data
        axis absorbs the loss."""
        if n_devices < model_parallel:
            raise ValueError(
                f"cannot keep model_parallel={model_parallel} with only "
                f"{n_devices} devices")
        data = n_devices // model_parallel
        return data, model_parallel

    def remesh(self, devices: Optional[list] = None,
               model_parallel: int = 1) -> Mesh:
        devices = devices if devices is not None else jax.devices()
        data, mp = self.shrink(len(devices), model_parallel)
        usable = np.asarray(devices[: data * mp]).reshape(data, mp)
        return Mesh(usable, self.axis_names)

    @staticmethod
    def reshard(tree: Any, shardings: Any) -> Any:
        """Move state onto the new mesh (host-hop on CPU; on TPU this is a
        resharding transfer)."""
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
            tree, shardings)


def resume_or_init(mgr: CheckpointManager, init_fn: Callable[[], tuple],
                   params_like: Any, opt_like: Any,
                   param_shardings: Any = None,
                   opt_shardings: Any = None) -> tuple:
    """Restart protocol: (step, params, opt_state, extra) from the latest
    complete checkpoint, else (0, *init_fn(), {})."""
    got = mgr.restore_latest(params_like, opt_like,
                             param_shardings=param_shardings,
                             opt_shardings=opt_shardings)
    if got is not None:
        return got
    params, opt_state = init_fn()
    return 0, params, opt_state, {}
