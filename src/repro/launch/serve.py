"""Serving driver: continuous batching over TAPA channels + jit'd decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 12

The request stream, the admission scheduler (peek) and the per-request
transactions (EoT) run as a task graph under the coroutine engine; the
compute inside is the jit'd prefill/decode pair of the selected model —
the same functions the dry-run lowers for the pod.
"""

from __future__ import annotations

import argparse
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm
from ..serve import Request, ServeConfig, ServingEngine, serve_requests


def serve(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.with_reduced()
    print(f"[serve] arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count()/1e6:.1f}M slots={args.slots}")

    params = lm.init_params(cfg, jax.random.key(args.seed))
    max_seq = args.max_seq

    @jax.jit
    def prefill_fn(tokens):
        logits, cache = lm.prefill(params, cfg, tokens, max_seq=max_seq)
        return logits, cache

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab, rng.integers(4, 17)).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]

    engine = ServingEngine(ServeConfig(batch_slots=args.slots,
                                       max_seq=max_seq),
                           prefill_fn, decode_fn)
    t0 = time.perf_counter()
    results = serve_requests(engine, reqs)
    wall = time.perf_counter() - t0
    n_new = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"[serve] req {rid}: prompt {len(reqs[rid].prompt):2d} tok "
              f"-> {results[rid]}")
    print(f"[serve] {len(results)} requests, {n_new} tokens in {wall:.2f}s "
          f"({n_new/max(wall,1e-9):.1f} tok/s incl. compile)")
    return 0 if len(results) == args.requests else 1


if __name__ == "__main__":
    sys.exit(serve())
