"""Serving driver: continuous batching over TAPA channels + jit'd decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 12

The request stream, the admission scheduler (peek) and the per-request
transactions (EoT) run as a task graph under the coroutine engine; the
compute inside is the batched packed-slot decode of the selected model:
one jitted step per iteration for every slot, on-device sampling, and
length-bucketed prefill AOT-resolved through the persistent compile cache
(``--per-slot`` selects the seed per-slot path instead; recurrent
families fall back to it automatically).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import get_config
from ..ft import PreemptionGuard
from ..models import lm
from ..serve import (AdmissionConfig, AdmissionController, Request,
                     RequestError, ServeConfig, ServeMetrics, ServingEngine,
                     TenantSpec, make_trace, serve_requests)


def _build_engine(cfg, params, scfg: ServeConfig, args) -> ServingEngine:
    if not args.per_slot:
        try:
            adapter = lm.serving_adapter(
                params, cfg, max_seq=scfg.max_seq,
                temperature=args.temperature, top_k=args.top_k,
                seed=args.seed)
            return ServingEngine(scfg, batched=adapter)
        except ValueError as e:       # recurrent family etc.
            print(f"[serve] batched path unavailable ({e}); "
                  f"falling back to per-slot")

    if args.temperature > 0 or args.top_k:
        print("[serve] WARNING: the per-slot path is greedy-only; "
              "--temperature/--top-k are ignored")
    max_seq = scfg.max_seq

    @jax.jit
    def prefill_fn(tokens):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq)

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    return ServingEngine(scfg, prefill_fn, decode_fn)


def _print_warmup(engine: ServingEngine, info: dict) -> None:
    if not info.get("ok"):
        print(f"[serve] warmup: eager fallback ({info.get('reason')})")
        return
    if "buckets" in info:
        hits = [k for k, v in info["buckets"].items() if v != "compiled"]
        fresh = [k for k, v in info["buckets"].items() if v == "compiled"]
        print(f"[serve] warmup: prefill buckets cached={hits or '-'} "
              f"fresh-compile={fresh or '-'}; "
              f"decode step: {info['decode']}")
    else:
        print(f"[serve] warmup: prefill={info['prefill']} "
              f"decode={info['decode']}")


def serve(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--per-slot", action="store_true",
                    help="seed path: one decode call per slot per token")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples on device")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget from admission")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal (JSONL).  A restarted "
                         "process given the same flags replays it: retired "
                         "requests answer from the journal, in-flight ones "
                         "resume at their last journaled token — "
                         "exactly-once results across SIGKILL")
    ap.add_argument("--traffic", choices=("poisson", "burst"), default=None,
                    help="open-loop traffic mode: seeded Poisson or bursty "
                         "on/off (MMPP) arrivals paced in wall time under "
                         "the thread engine, instead of a back-to-back "
                         "request list")
    ap.add_argument("--tenants", type=int, default=2,
                    help="number of traffic tenants (fair-queued)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrival rate per tenant (requests/s)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="traffic trace duration (seconds)")
    ap.add_argument("--shed-policy",
                    choices=("none", "reject-new", "drop-oldest"),
                    default="reject-new",
                    help="admission-control shed policy under --traffic; "
                         "'none' disables the admission controller (the "
                         "frontend blocks on a full queue)")
    ap.add_argument("--queue-limit", type=int, default=32,
                    help="admission-controller backlog bound (requests)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.with_reduced()
    print(f"[serve] arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count()/1e6:.1f}M slots={args.slots}")

    params = lm.init_params(cfg, jax.random.key(args.seed))
    scfg = ServeConfig(batch_slots=args.slots, max_seq=args.max_seq)
    engine = _build_engine(cfg, params, scfg, args)

    t0 = time.perf_counter()
    if engine.batched is not None:
        # warm every admission shape a serving process can meet: all
        # power-of-two prefill batch dims up to the slot count, plus the
        # slot count itself (a full wave pads to it when it is not pow2)
        sizes = tuple(sorted({min(2 ** k, args.slots)
                              for k in range(args.slots.bit_length())}
                             | {args.slots}))
        info = engine.warmup(batch_sizes=sizes)
        if not info.get("ok"):
            # a batched adapter has no eager path — serve per-slot instead
            print(f"[serve] batched warmup failed ({info.get('reason')}); "
                  f"falling back to per-slot")
            args.per_slot = True
            engine = _build_engine(cfg, params, scfg, args)
            info = engine.warmup()
    else:
        info = engine.warmup()
    warm = time.perf_counter() - t0
    mode = "batched" if engine.batched is not None else "per-slot"
    _print_warmup(engine, info)
    print(f"[serve] warmup took {warm:.2f}s mode={mode}")
    n_warm_log = len(engine.compile_log)

    sim_engine = "coroutine"
    metrics = None
    if args.traffic:
        # seeded open-loop traffic: the trace is a pure function of
        # (--seed, tenant mix, duration) — see repro/serve/traffic.py
        phases = {"on_s": 0.4, "off_s": 0.4, "on_scale": 3.0} \
            if args.traffic == "burst" else None
        tenants = [TenantSpec(name=f"t{i}", rate=args.rate,
                              max_new=(args.max_new, args.max_new),
                              deadline_s=args.deadline_s, phases=phases)
                   for i in range(args.tenants)]
        reqs = make_trace(tenants, args.duration, seed=args.seed,
                          vocab=cfg.vocab)
        metrics = engine.metrics = ServeMetrics()
        if args.shed_policy != "none":
            ctrl = AdmissionController(
                AdmissionConfig(shed_policy=args.shed_policy,
                                queue_limit=args.queue_limit),
                metrics=metrics)
            ctrl.register_tenants(tenants)
            engine.admission = ctrl
            ctrl.journal = engine.journal
        engine.pace = "wall"
        sim_engine = "thread"     # wall pacing needs preemptive tasks
        print(f"[serve] traffic={args.traffic} tenants={args.tenants} "
              f"rate={args.rate}/s x {args.duration}s -> "
              f"{len(reqs)} requests, shed-policy={args.shed_policy}")
    else:
        rng = np.random.default_rng(args.seed)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            0, cfg.vocab, rng.integers(4, 17)).tolist(),
                        max_new=args.max_new,
                        deadline_s=args.deadline_s)
                for i in range(args.requests)]

    # preemption-safe serving: SIGTERM/SIGINT flips the guard; the
    # scheduler then rejects queued admissions with "preempted" errors,
    # finishes the in-flight slots, flushes results and exits clean
    guard = PreemptionGuard()
    engine.stop_flag = lambda: guard.requested
    if args.journal:
        from ..serve import ServeJournal
        engine.journal = ServeJournal(args.journal)
        if engine.journal.completed or engine.journal.inflight:
            print(f"[serve] journal replay: "
                  f"{len(engine.journal.completed)} retired, "
                  f"{len(engine.journal.inflight)} in-flight")
    try:
        t0 = time.perf_counter()
        results = serve_requests(engine, reqs, sim_engine=sim_engine)
        wall = time.perf_counter() - t0
    finally:
        guard.uninstall()
    ok = {r: v for r, v in results.items() if not isinstance(v, RequestError)}
    failed = {r: v for r, v in results.items() if isinstance(v, RequestError)}
    n_new = sum(len(v) for v in ok.values())
    if not args.traffic:               # traffic mode prints a summary instead
        for rid in sorted(results):
            v = results[rid]
            if isinstance(v, RequestError):
                print(f"[serve] req {rid}: {v.status} ({v.detail})")
            else:
                print(f"[serve] req {rid}: prompt "
                      f"{len(reqs[rid].prompt):2d} tok -> {v}")
    lazy = [(k, s, src) for k, s, src in engine.compile_log[n_warm_log:]
            if src == "compiled"]
    if lazy:
        print(f"[serve] lazy compiles during serving: "
              f"{[(k, s) for k, s, _ in lazy]}")
    if engine.degraded is not None:
        print(f"[serve] degraded to {engine.degraded[0]}: "
              f"{engine.degraded[1]}")
    if guard.requested:
        print(f"[serve] preempted: {len(ok)} completed, "
              f"{len(failed)} rejected")
    print(f"[serve] {len(ok)} requests, {n_new} tokens in {wall:.2f}s "
          f"({n_new/max(wall,1e-9):.1f} tok/s, {mode} decode)")
    if metrics is not None:
        metrics.check_accounting()
        summ = metrics.summary(wall_s=wall)

        def _ms(v):
            return "-" if v is None else f"{v * 1e3:.0f}ms"

        print(f"[serve] overload: offered={summ['offered']} "
              f"admitted={summ['admitted']} shed={summ['shed']} "
              f"completed={summ['completed']} "
              f"goodput={summ['goodput_tok_s'] or 0:.1f} tok/s "
              f"ttft p50={_ms(summ['ttft_p50_s'])} "
              f"p99={_ms(summ['ttft_p99_s'])}")
        for name, row in summ["tenants"].items():
            print(f"[serve]   tenant {name}: offered={row['offered']} "
                  f"admitted={row['admitted']} shed={row['shed']} "
                  f"ttft p50={_ms(row['ttft_p50_s'])} "
                  f"p99={_ms(row['ttft_p99_s'])}")
        # open-loop contract: every offered request gets an answer —
        # tokens or a structured error — never a silent absence
        return 0 if len(results) == len(reqs) else 1
    # a preempted run that answered every request (some with structured
    # rejections) still exits clean — that is the graceful-drain contract
    if guard.requested:
        return 0 if len(results) == args.requests else 1
    return 0 if len(ok) == args.requests else 1


if __name__ == "__main__":
    sys.exit(serve())
