"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 200 --batch 8 --seq 128

Integrates every substrate layer: config registry, data pipeline, sharded
init, jit'd train step (scan-over-layers = the paper's compile-once
insight), AdamW(+ZeRO-1 state sharding), checkpoint/restart
(``--resume`` is implied — the driver *always* restores the latest complete
checkpoint if one exists, so preempted jobs just re-run the same command),
preemption guard, straggler detection and optional int8 gradient
compression.

On this CPU container the default is a reduced config; the full configs
are exercised by the dry-run (launch/dryrun.py).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..ckpt import CheckpointManager
from ..configs import get_config
from ..data import make_pipeline
from ..distributed import sharding as shd
from ..ft import PreemptionGuard, StragglerDetector, resume_or_init
from ..models import lm
from ..optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .mesh import make_host_mesh
from .steps import make_train_step


def train(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas attention/SSD kernels (interpret on CPU)")
    ap.add_argument("--metrics", default=None,
                    help="write JSONL metrics to this path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.with_reduced()
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    mesh = make_host_mesh(args.model_parallel)
    pol = shd.for_mesh(mesh)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))

    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.param_specs(cfg, mesh, pol))
    oshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          opt_state_specs(cfg, mesh, pol))

    data = make_pipeline(cfg.vocab, args.seq, args.batch, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    guard = PreemptionGuard()
    straggler = StragglerDetector()

    # ---- init or resume --------------------------------------------------
    # resume_or_init goes through digest-verified restore_latest: a
    # checkpoint corrupted after publish (torn file, bad digest) is
    # skipped and the scan falls back to the previous good step, so a
    # kill-and-rerun always lands on sound state (tests/test_launch.py)
    aparams = lm.abstract_params(cfg)
    aopt = jax.eval_shape(partial(adamw_init, c=opt), aparams)

    def _init():
        with mesh:
            params = jax.jit(
                partial(lm.init_params, cfg),
                out_shardings=pshard)(jax.random.key(args.seed))
            opt_state = jax.jit(partial(adamw_init, c=opt),
                                out_shardings=oshard)(params)
        return params, opt_state

    start, params, opt_state, extra = resume_or_init(
        mgr, _init, aparams, aopt,
        param_shardings=pshard, opt_shardings=oshard)
    if start > 0:
        data.load_state_dict(extra.get("data", {"step": start}))
        print(f"[train] resumed from checkpoint step {start}")

    step_fn = make_train_step(cfg, opt, use_kernel=args.use_kernel)
    bspec = shd.batch_spec(cfg, mesh, args.batch, pol)
    bshard = {k: NamedSharding(mesh, v) for k, v in bspec.items()}
    jitted = jax.jit(step_fn,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))

    metrics_f = open(args.metrics, "a") if args.metrics else None
    losses = []
    t_run = time.perf_counter()
    step = start
    if start >= args.steps:
        print(f"[train] checkpoint already at step {start} >= "
              f"--steps {args.steps}; nothing to do")
        return 0
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jax.device_put(v, bshard[k])
                 for k, v in data.next_batch().items()}
        params, opt_state, m = jitted(params, opt_state, batch)
        loss = float(m["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        slow = straggler.observe(dt)
        if (step + 1) % args.log_every == 0 or step == start:
            print(f"[train] step {step+1:5d} loss {loss:.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.3f}"
                  f" {dt*1e3:.0f}ms{'  [straggler]' if slow else ''}")
        if metrics_f:
            metrics_f.write(json.dumps(
                {"step": step + 1, "loss": loss, "dt": dt}) + "\n")
        if (step + 1) % args.ckpt_every == 0 or guard.requested:
            mgr.save(step + 1, params, opt_state,
                     extra={"data": data.state_dict()}, blocking=False)
        if guard.requested:
            mgr.wait()
            print(f"[train] preempted at step {step+1}; checkpoint saved")
            return 0

    mgr.save(step + 1, params, opt_state,
             extra={"data": data.state_dict()})
    wall = time.perf_counter() - t_run
    tok_s = (args.steps - start) * args.batch * args.seq / max(wall, 1e-9)
    print(f"[train] done: {args.steps - start} steps in {wall:.1f}s "
          f"({tok_s:,.0f} tok/s); loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if metrics_f:
        metrics_f.close()
    if len(losses) >= 20 and not (np.mean(losses[-5:]) <
                                  np.mean(losses[:5])):
        print("[train] WARNING: loss did not decrease")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(train())
