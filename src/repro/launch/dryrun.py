import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell:
    jax.jit(step, in_shardings=..., out_shardings=...)
        .lower(*abstract_inputs).compile()
then record memory_analysis(), cost_analysis() and the collective-transfer
bytes parsed from the optimized HLO — the inputs to EXPERIMENTS.md
S:Dry-run and S:Roofline.

The XLA_FLAGS line above MUST run before any other import so the host
platform exposes 512 placeholder devices; nothing here allocates on them
(ShapeDtypeStruct stand-ins only).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh single
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path
from typing import Optional

import jax

from ..configs import ARCH_IDS, get_config, SHAPES, shape_applicable
from ..models.config import InputShape, ModelConfig
from .mesh import make_production_mesh
from .steps import input_specs

OUT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "out"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _crosses_pod(line: str, pod_size: int) -> Optional[bool]:
    """Does this collective's replica grouping span a pod boundary?
    None when no explicit groups are printed (assume worst case)."""
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
        ids = [int(x) for x in grp.split(",") if x.strip()]
        if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
            return True
    return False


def collective_bytes(hlo_text: str, pod_size: Optional[int] = None) -> dict:
    """Sum transferred bytes per collective kind from optimized HLO.

    Convention: per-op bytes = result-shape bytes; all-reduce counts 2x
    (ring AR = reduce-scatter + all-gather).  ``-start`` async forms are
    counted, ``-done`` skipped.  This is the per-*device* shard size, i.e.
    bytes crossing that device's links (ring schedules move ~2x(n-1)/n of
    the shard per hop-sum, absorbed into the constant; we report the raw
    sum and divide by link bandwidth in the roofline).

    With ``pod_size`` set (e.g. 256), collectives whose replica groups span
    a pod boundary are additionally summed as ``cross_pod_bytes`` — the
    traffic that must traverse the (scarcer) inter-pod links.
    """
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    cross_pod = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        # result type is between '= ' and the op name
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        ty, op = m.group(1), m.group(2)
        base = op.removesuffix("-start")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        b = _shape_bytes(ty)
        if base == "all-reduce":
            b *= 2
        per_kind[base] += b
        counts[base] += 1
        if pod_size is not None:
            spans = _crosses_pod(s, pod_size)
            if spans or spans is None:
                cross_pod += b
    per_kind_counts = {f"n_{k}": v for k, v in counts.items() if v}
    out = {"total_bytes": sum(per_kind.values()),
           **{k: v for k, v in per_kind.items() if v}, **per_kind_counts}
    if pod_size is not None:
        out["cross_pod_bytes"] = cross_pod
    return out


def run_cell(cfg: ModelConfig, shape: InputShape, mesh, mesh_name: str,
             verbose: bool = True) -> dict:
    """Lower + compile one (arch, shape, mesh) cell; return its record."""
    t0 = time.time()
    spec = input_specs(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(spec["fn"], in_shardings=spec["in_shardings"],
                         out_shardings=spec["out_shardings"],
                         donate_argnums=spec["donate_argnums"])
        lowered = jitted.lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec: dict = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind, "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_devices": mesh.size,
    }
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        if verbose:
            print(f"  memory_analysis: {rec['memory']}")
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if k in ("flops", "bytes accessed", "transcendentals",
                                "optimal_seconds")
                       or k.startswith("bytes accessed")}
        if verbose:
            print(f"  cost_analysis: flops={rec['cost'].get('flops', 0):.3e}"
                  f" bytes={rec['cost'].get('bytes accessed', 0):.3e}")
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}
    try:
        rec["collectives"] = collective_bytes(compiled.as_text())
        if verbose:
            print(f"  collectives: {rec['collectives']}")
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(OUT_DIR / "dryrun.json"))
    ap.add_argument("--force", action="store_true",
                    help="re-run cells already in the output file")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results: dict[str, dict] = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for shp in shapes:
            shape = SHAPES[shp]
            ok, why = shape_applicable(cfg, shape)
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                key = f"{cfg.name}|{shape.name}|{mesh_name}"
                if key in results and results[key].get("ok") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                if not ok:
                    results[key] = {"arch": cfg.name, "shape": shape.name,
                                    "mesh": mesh_name, "skipped": why,
                                    "ok": True}
                    print(f"[skip]   {key}: {why}")
                    continue
                print(f"[run]    {key} ...", flush=True)
                try:
                    mesh = make_production_mesh(multi_pod=multi)
                    rec = run_cell(cfg, shape, mesh, mesh_name)
                    results[key] = rec
                    print(f"[ok]     {key} compile={rec['compile_s']}s")
                except Exception:
                    n_fail += 1
                    results[key] = {"arch": cfg.name, "shape": shape.name,
                                    "mesh": mesh_name, "ok": False,
                                    "error": traceback.format_exc(-4)}
                    print(f"[FAIL]   {key}\n{traceback.format_exc(-4)}")
                out_path.write_text(json.dumps(results, indent=1))
    out_path.write_text(json.dumps(results, indent=1))
    print(f"\nwrote {out_path} ({len(results)} cells, {n_fail} failures)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
