"""Step builders shared by train/serve drivers and the dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation) plus the matching
PartitionSpecs — the pattern required for .lower()/.compile() dry-runs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as shd
from ..models import lm
from ..models.config import InputShape, ModelConfig, SHAPES
from ..optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def abstract_batch(cfg: ModelConfig, B: int, S: int) -> dict:
    d: dict = {"tokens": SDS((B, S), jnp.int32),
               "labels": SDS((B, S), jnp.int32)}
    if cfg.vlm is not None:
        d["patches"] = SDS((B, cfg.vlm.n_patches, cfg.vlm.d_patch),
                           jnp.bfloat16)
    if cfg.encdec is not None:
        d["frames"] = SDS((B, cfg.encdec.n_audio_ctx, cfg.d_model),
                          jnp.bfloat16)
    return d


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                pol: Optional[shd.ShardingPolicy] = None,
                opt: Optional[AdamWConfig] = None,
                scan_layers: bool = True, remat: bool = True,
                use_kernel: bool = False) -> dict:
    """Everything a dry-run needs for one (arch x input-shape) cell:

    returns {"fn", "args" (abstract), "in_shardings", "out_shardings",
             "donate_argnums"} ready for
    ``jax.jit(fn, ...).lower(*args).compile()``.
    """
    pol = pol or shd.for_mesh(mesh, fsdp=cfg.param_count() > 5e10)
    opt = opt or AdamWConfig(
        state_dtype="bfloat16" if cfg.param_count() > 5e10 else "float32")
    pspec = shd.param_specs(cfg, mesh, pol)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
    aparams = lm.abstract_params(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        ospec = opt_state_specs(cfg, mesh, pol)
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospec)
        aopt = jax.eval_shape(partial(adamw_init, c=opt), aparams)
        bspec = shd.batch_spec(cfg, mesh, B, pol)
        bshard = {k: NamedSharding(mesh, v) for k, v in bspec.items()}
        abatch = abstract_batch(cfg, B, S)
        fn = make_train_step(cfg, opt, scan_layers=scan_layers,
                             remat=remat, use_kernel=use_kernel)
        return dict(
            fn=fn, args=(aparams, aopt, abatch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard,
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        bspec = shd.batch_spec(cfg, mesh, B, pol)
        abatch = abstract_batch(cfg, B, S)
        del abatch["labels"], bspec["labels"]
        bshard = {k: NamedSharding(mesh, v) for k, v in bspec.items()}
        cspec = shd.cache_specs(cfg, mesh, B, pol)
        cshard = {k: NamedSharding(mesh, v) for k, v in cspec.items()}
        fn = make_prefill_step(cfg, use_kernel=use_kernel,
                               scan_layers=scan_layers)
        return dict(
            fn=fn, args=(aparams, abatch),
            in_shardings=(pshard, bshard),
            out_shardings=(NamedSharding(
                mesh,
                P(pol.batch_spec_axes, None)
                if pol.batch_spec_axes is not None and
                B % shd._axis_size(mesh, pol.batch_spec_axes) == 0
                else P()), cshard),
            donate_argnums=(),
        )

    # decode: one new token against a full cache
    acache = lm.init_decode_cache(cfg, B, S, abstract=True)
    cspec = shd.cache_specs(cfg, mesh, B, pol)
    cshard = {k: NamedSharding(mesh, v) for k, v in cspec.items()}
    atok = SDS((B,), jnp.int32)
    ba = pol.batch_spec_axes
    bdim = ba if ba is not None and \
        B % shd._axis_size(mesh, ba) == 0 else \
        ("data" if ba is not None and B % mesh.shape["data"] == 0 else None)
    tshard = NamedSharding(mesh, P(bdim))
    fn = make_decode_step(cfg, scan_layers=scan_layers)
    return dict(
        fn=fn, args=(aparams, atok, acache),
        in_shardings=(pshard, tshard, cshard),
        out_shardings=(NamedSharding(mesh, P(bdim, None)), cshard),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *,
                    scan_layers: bool = True, remat: bool = True,
                    use_kernel: bool = False):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(
            params, cfg, batch, scan_layers=scan_layers, remat=remat,
            use_kernel=use_kernel)
        new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt)
        metrics["loss"] = loss
        return new_p, new_s, metrics
    train_step.__name__ = f"train_step_{cfg.name}"
    return train_step


def make_prefill_step(cfg: ModelConfig, use_kernel: bool = False,
                      scan_layers: bool = True):
    def prefill_step(params, batch):
        extra = {k: v for k, v in batch.items() if k in ("patches", "frames")}
        return lm.prefill(params, cfg, batch["tokens"], extra=extra,
                          use_kernel=use_kernel, scan_layers=scan_layers)
    prefill_step.__name__ = f"prefill_step_{cfg.name}"
    return prefill_step


def make_decode_step(cfg: ModelConfig, scan_layers: bool = True):
    def decode_step(params, token, cache):
        return lm.decode_step(params, cfg, token, cache,
                              scan_layers=scan_layers)
    decode_step.__name__ = f"decode_step_{cfg.name}"
    return decode_step
