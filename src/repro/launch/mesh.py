"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no JAX device state — the dry-run must set XLA_FLAGS before
first jax initialization.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); the 'pod' axis
    is outer data-parallel by default (the PP schedule may claim it)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
