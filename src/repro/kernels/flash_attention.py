"""Flash attention forward kernel for TPU (pl.pallas_call + BlockSpec).

TPU adaptation notes (hw-codesign):

* The grid's innermost dimension iterates KV blocks **sequentially** — on
  TPU, grid steps execute in order on the single core, so the online-softmax
  running state (m, l, acc) lives in VMEM scratch and is carried across KV
  iterations instead of needing atomics/shared-memory reductions as a GPU
  port would.
* Block shapes are MXU/VPU aligned: the score matmul is
  [block_q, hd] x [hd, block_k] with block_q = block_k = 128 by default and
  hd in {64, 128}; the softmax statistics are stored as (block_q, 128) f32
  tiles (lane-width aligned) of which only column 0 is meaningful.
* Causal and sliding-window masks are applied per-block, and blocks that are
  *entirely* masked are skipped with ``pl.when`` — the sequential grid makes
  this a genuine compute saving (GPU persistent kernels need explicit work
  scheduling for the same effect).
* GQA is expressed in the BlockSpec index maps: the K/V index map divides
  the query-head index by ``group`` so kv blocks are fetched once per kv
  head, not once per q head.

The backward pass uses the standard flash recomputation formulated in pure
jnp (fp32) via ``jax.custom_vjp`` — on a real TPU it would get its own
kernel; training paths in this repo default to the XLA attention anyway
(``use_kernel=False``), so the kernel's production role is prefill/serving.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
STATS_LANES = 128          # lane-aligned f32 tile for m/l statistics
NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref,          # inputs
                o_ref, lse_ref,               # outputs
                acc_ref, m_ref, l_ref,        # VMEM scratch
                *, scale: float, causal: bool, window: Optional[int],
                block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # Block-level skip: under a causal mask every k in this block is in the
    # future of every q; under a sliding window every k is out of reach.
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                 # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                 # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)                 # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                # [bq]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # rows that are entirely masked so far must not poison exp()
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)                  # fully-masked row
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[:, 0] + jnp.log(safe)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: Optional[int],
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True) -> tuple:
    """q: [B, nh, Sq, hd]; k/v: [B, nkv, Sk, hd] (head-major layout).

    Returns (out [B, nh, Sq, hd], lse [B, nh, Sq] fp32).
    """
    B, nh, Sq, hd = q.shape
    nkv, Sk = k.shape[1], k.shape[2]
    group = nh // nkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grid = (B, nh, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _fwd_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, block_q=block_q, block_k=block_k)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, nh, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse
