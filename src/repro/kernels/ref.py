"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).  These are
*naive* O(S^2)-memory implementations — clarity over speed.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0,
                        kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Grouped-query attention, fp32 softmax.

    q: [B, Sq, nh, hd]; k/v: [B, Sk, nkv, hd] with nh % nkv == 0.
    Returns [B, Sq, nh, hd] in q.dtype.
    """
    B, Sq, nh, hd = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qg = q.reshape(B, Sq, nkv, group, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, nh, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """Single-token decode attention against a KV cache.

    q: [B, nh, hd]; k/v: [B, S_max, nkv, hd]; kv_len: [] or [B] int32 —
    number of valid cache slots.  Returns [B, nh, hd].
    """
    B, nh, hd = q.shape
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    Sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qg = q.reshape(B, nkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32)) \
        / math.sqrt(hd)
    mask = jnp.arange(Sk)[None, :] < kv_len[:, None]          # [B, Sk]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return o.reshape(B, nh, hd).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, D: jax.Array, *,
                 init_state: Optional[jax.Array] = None) -> tuple:
    """Sequential (recurrent) reference for the SSD scan — the simplest
    possible statement of Mamba-2 semantics (arXiv:2405.21060 eq. 1):

        S_t = exp(dt_t * A) S_{t-1} + dt_t B_t x_t^T
        y_t = C_t . S_t + D x_t

    x: [B, S, H, P]; dt: [B, S, H]; A: [H]; Bm/Cm: [B, S, G, N]; D: [H].
    Returns (y [B,S,H,P], final_state [B,H,P,N]), both fp32 math.
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)   # [B,S,H,N]
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp          # [B,H,P], [B,H], [B,H,N], [B,H,N]
        dA = jnp.exp(dtt * Af[None])                        # [B,H]
        upd = (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        state = state * dA[..., None, None] + upd           # [B,H,P,N]
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                              # [B,S,H,P]
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final
