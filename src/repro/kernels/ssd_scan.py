"""Mamba-2 SSD (state-space duality) chunked-scan kernel for TPU.

The SSD algorithm (arXiv:2405.21060 §6) splits the sequence into chunks:
inside a chunk the recurrence is expanded into a small quadratic
"attention-like" form (MXU-friendly matmuls), and *between* chunks a tiny
[P, N] state is carried recurrently.  The published kernel is a GPU Triton
kernel that parallelizes chunks across SMs and then runs a separate
state-passing pass.

TPU adaptation: the Pallas grid executes **sequentially** on the core, so
the inter-chunk state pass needs no separate kernel — the [P, N] fp32 state
simply lives in VMEM scratch and is carried across grid steps along the
chunk axis (the same trick the flash kernel uses for softmax state).  One
kernel therefore fuses all three SSD stages:

    grid = (B, H, n_chunks)        # chunk axis innermost, sequential
    per step:  y  = (tril(C Bᵀ) ⊙ decay) (dt·x)      intra-chunk (MXU)
               y += (C ⊙ head-decay) @ state          inter-chunk read
            state = total-decay * state + (tail-decay·dt·x)ᵀ B
                                                       inter-chunk write

All state math is fp32; inputs may be bf16.  Chunk length and N=d_state
are 128-lane aligned for the assigned configs (chunk=256, N∈{64,128});
P=64 rides the sublane dimension.

The wrapper (ops.ssd_scan) precomputes dA = dt*A and xdt = dt*x outside the
kernel (cheap elementwise, keeps the kernel's input count small) and adds
the D-skip term outside.  Gradients: ``jax.custom_vjp`` recomputes through
the pure-jnp chunked reference (models/layers.ssd_chunked) — the standard
recompute-in-backward trade, noted in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, s0_ref,   # inputs
                y_ref, sout_ref,                          # outputs
                state_ref,                                # VMEM scratch
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    xdt = xdt_ref[0, 0].astype(jnp.float32)       # [Q, P]  dt-weighted input
    dA = dA_ref[0, 0].astype(jnp.float32)         # [1, Q]  dt * A  (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)          # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)          # [Q, N]

    cum = jnp.cumsum(dA[0])                       # [Q] inclusive
    # Intra-chunk decay factors decay[i,j] = exp(cum_i - cum_j), j <= i.
    # Mask the exponent (not the exp) so masked entries are exactly 0 and
    # no inf/NaN can leak through.
    diff = cum[:, None] - cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(tril, diff, -jnp.inf))        # [Q, Q]

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    y = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    # inter-chunk read: y[i] += (C_i * exp(cum_i)) @ state   ([Q,N]@[N,P])
    head = jnp.exp(cum)[:, None]                             # [Q, 1]
    y += jax.lax.dot_general(Cm * head, state_ref[...],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # inter-chunk write: state = exp(cum_end)*state + (tail·xdt)ᵀ B
    tail = jnp.exp(cum[-1] - cum)[:, None]                   # [Q, 1]
    new_state = state_ref[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xdt * tail, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [P, N]
    state_ref[...] = new_state

    @pl.when(ci == nc - 1)
    def _emit_state():
        sout_ref[0, 0] = new_state


def ssd_scan_fwd(xdt: jax.Array, dA: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, s0: jax.Array, *, chunk: int,
                 interpret: bool = True) -> tuple:
    """Head-major kernel entry.

    xdt: [B, H, S, P] (dt-weighted inputs); dA: [B, H, 1, S];
    Bm/Cm: [B, G, S, N]; s0: [B, H, P, N] fp32 initial state.
    Returns (y [B,H,S,P] fp32, final_state [B,H,P,N] fp32).
    """
    B, H, S, P = xdt.shape
    G, N = Bm.shape[1], Bm.shape[3]
    group = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, sout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, 0, c)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c: (b, h // group, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c: (b, h // group, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm, s0)
    return y, sout
