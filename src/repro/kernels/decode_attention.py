"""Flash-decode kernel: one new token attends to a long KV cache.

The decode shape is the degenerate flash case (Sq = 1), where the GPU
formulation (FlashDecoding, arXiv:2311.01282) *splits* the KV axis across
SMs and reduces partials.  On TPU there is one core per chip and the Pallas
grid is sequential, so the TPU-native formulation keeps the online-softmax
state in VMEM scratch across sequential KV blocks — no split/reduce pass.
What we keep from the paper's insight is the *batching over the GQA group*:
all ``group = nh/nkv`` query heads that share one KV head are processed as
a single [group, hd] tile, so each KV block is streamed from HBM exactly
once per kv head (the bandwidth-optimality argument of flash-decode).

The valid-cache-length is data-dependent (it is the running decode
position), so blocks past ``kv_len`` are skipped with ``pl.when`` on a
traced predicate — the sequential grid turns that into genuinely skipped
HBM traffic for the unfilled cache tail.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
STATS_LANES = 128
NEG_INF = -1e30


def _decode_kernel(len_ref,                     # scalar-prefetch [B] int32
                   q_ref, k_ref, v_ref,         # inputs
                   o_ref,                       # output
                   acc_ref, m_ref, l_ref,       # VMEM scratch
                   *, scale: float, block_k: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)                   # skip unfilled cache tail
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)      # [group, hd]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)      # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [group, bk]
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = jnp.broadcast_to(
            (l_prev * alpha + jnp.sum(p, axis=1))[:, None], l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array, *,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: Optional[bool] = None) -> jax.Array:
    """q: [B, nkv, group, hd]; k/v: [B, nkv, S_max, hd]; kv_len: [B] int32.

    Returns [B, nkv, group, hd].

    ``interpret=None`` auto-dispatches: real Pallas (Mosaic) on a TPU
    backend, the Pallas interpreter elsewhere.  Lengths are ragged per
    batch row; a row with ``kv_len == 0`` (a dead serving slot) skips every
    KV block and returns exact zeros (the ``l == 0`` guard in ``_finish``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, nkv, group, hd = q.shape
    Sk = k.shape[2]
    block_k = min(block_k, Sk)
    assert Sk % block_k == 0, (Sk, block_k)
    grid = (B, nkv, Sk // block_k)

    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(hd),
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda b, h, ki, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, ki, lens: (b, h, ki, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, ki, lens: (b, h, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda b, h, ki, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, hd), jnp.float32),
                pltpu.VMEM((group, STATS_LANES), jnp.float32),
                pltpu.VMEM((group, STATS_LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, group, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), q, k, v)
