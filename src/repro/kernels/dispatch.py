"""Shared backend dispatch for the Pallas kernels.

Every kernel-backed op in this package answers the same three questions:

1. did the caller force an implementation (``impl=``)?
2. did the environment force one (a per-op ``REPRO_*`` variable, so CI
   jobs and parity harnesses can steer a whole process)?
3. otherwise, are we on a TPU backend (real Mosaic lowering) or not
   (fall back to a reference implementation — on CPU the Pallas
   interpreter's sequential grid emulation is slower than the fused
   XLA reference, and the hot paths are latency-critical)?

The pattern used to live inline in :func:`ops.decode_attention`; it is
extracted here so the ring-buffer kernels (:mod:`repro.kernels.ring`)
and any future op resolve their backend identically.  ``"interpret"``
is always one of the choices: it runs the *kernel* semantics under the
Pallas interpreter on any backend, which is how the parity tests pin
bit-exactness without TPU hardware.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

import jax


def is_tpu() -> bool:
    """True when jax will actually lower Pallas kernels through Mosaic."""
    return jax.default_backend() == "tpu"


def resolve_impl(op: str, env: str, choices: Sequence[str], *,
                 fallback: str, tpu_default: str = "pallas",
                 impl: Optional[str] = None) -> str:
    """Resolve a kernel implementation name.

    Precedence: explicit ``impl=`` argument > ``$<env>`` > backend
    default (``tpu_default`` on TPU, ``fallback`` elsewhere).  Raises
    ``ValueError`` naming the op, the offending value and both override
    channels when the result is not one of ``choices``.
    """
    resolved = impl or os.environ.get(env) or \
        (tpu_default if is_tpu() else fallback)
    if resolved not in choices:
        raise ValueError(
            f"{op} impl {resolved!r}: expected one of "
            f"{tuple(choices)} (from impl= or ${env})")
    return resolved
