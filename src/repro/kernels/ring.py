"""Pallas kernels for the compiled interconnect (channel ring buffers).

``CompiledEngine`` lowers every channel to ``(buf[cap, *elem], head,
size)`` carried through a ``lax.while_loop`` — see ``core/synth.py``.
This module provides the three hot ops of that sweep loop as Pallas
kernels with a bit-exact XLA reference:

* :func:`ring_pop`   — pop ``n`` tokens: burst slice out of the ring
  with the head/size update fused into the same op.  The contiguous
  case (``head + n <= cap``) is ONE VMEM slice copy; the wraparound
  case splits into per-row copies of the two contiguous segments
  (the double-buffer halves of a hardware FIFO burst).
* :func:`ring_push`  — push ``n`` tokens at ``(head + size) % cap``,
  same contiguous-fast-path / wrap-split structure, writing through a
  full-ring VMEM copy so the op stays functional.
* :func:`eval_guards` — fused firing-predicate evaluation: ONE kernel
  computes every task's fire guard from the channel occupancy vector
  (``need_r <= size`` and ``need_w <= cap - size`` reduced over the
  channel axis), replacing N·C scalar ops per sweep with one tiled
  compare-and-reduce.

Backend dispatch mirrors :func:`repro.kernels.ops.decode_attention`
via :mod:`repro.kernels.dispatch`:

* ``"pallas"``    — Mosaic-lowered kernels (TPU default);
* ``"interpret"`` — the same kernels under the Pallas interpreter
  (bit-exact kernel semantics on any backend; the CI parity path);
* ``"xla"``       — the vectorized gather/scatter reference (non-TPU
  default; identical integer index math to the kernels, so every
  graph keeps a bit-exact reference lowering).

Select with ``impl=`` or ``$REPRO_RING_IMPL``.  All three impls are
exact integer/copy ops — no arithmetic reassociation — so parity is
bitwise, not approximate.
"""

from __future__ import annotations

from functools import partial, reduce
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import resolve_impl

RING_ENV = "REPRO_RING_IMPL"
RING_CHOICES = ("pallas", "interpret", "xla")

_SUB = 8      # sublane multiple for fp32/int32 VMEM tiles
_LANE = 128   # lane multiple


def _resolve(impl: Optional[str]) -> str:
    return resolve_impl("ring", RING_ENV, RING_CHOICES,
                        fallback="xla", impl=impl)


def _ceil(x: int, m: int) -> int:
    return -(-x // m) * m


def _flat(buf: jax.Array) -> tuple[jax.Array, int]:
    """[cap, *elem] -> [cap, E] (E >= 1) for the 2-D kernels."""
    cap = buf.shape[0]
    e = int(np.prod(buf.shape[1:], dtype=np.int64)) if buf.ndim > 1 else 1
    return buf.reshape(cap, max(e, 1)), max(e, 1)


def _kernel_dtype(dtype) -> np.dtype:
    """bools ride the kernels as int32 (TPU vregs have no 1-bit lanes);
    the wrappers cast back, which is exact for {0, 1}."""
    d = np.dtype(dtype)
    return np.dtype(np.int32) if d == np.bool_ else d


# ---------------------------------------------------------------------------
# pop
# ---------------------------------------------------------------------------

def _pop_kernel(n: int, cap: int, s_ref, buf_ref, out_ref):
    head = s_ref[0]

    @pl.when(head + n <= cap)
    def _contig():
        out_ref[pl.ds(0, n), :] = buf_ref[pl.ds(head, n), :]

    @pl.when(head + n > cap)
    def _wrap():
        for i in range(n):
            idx = jax.lax.rem(head + jnp.int32(i), jnp.int32(cap))
            out_ref[pl.ds(i, 1), :] = buf_ref[pl.ds(idx, 1), :]


def ring_pop(buf: jax.Array, head: jax.Array, size: jax.Array, n: int, *,
             impl: Optional[str] = None):
    """Pop ``n`` tokens from a ring buffer.

    Returns ``(toks[n, *elem], new_head, new_size)`` with the head/size
    update fused: ``new_head = (head + n) % cap``, ``new_size = size - n``.
    ``n`` is static (synthesis enforces static I/O rates).
    """
    impl = _resolve(impl)
    cap = buf.shape[0]
    elem = buf.shape[1:]
    n = int(n)
    new_head = (head + n) % cap
    new_size = size - n
    if n == 0:
        return buf[0:0], new_head, new_size
    if impl == "xla":
        idx = (head + jnp.arange(n, dtype=jnp.int32)) % cap
        return buf[idx], new_head, new_size
    flat, e = _flat(buf)
    kdt = _kernel_dtype(flat.dtype)
    flat = flat.astype(kdt)
    cap_p, n_p, e_p = _ceil(cap, _SUB), _ceil(n, _SUB), _ceil(e, _LANE)
    flat = jnp.pad(flat, ((0, cap_p - cap), (0, e_p - e)))
    scalars = jnp.asarray(head, jnp.int32).reshape(1)
    out = pl.pallas_call(
        partial(_pop_kernel, n, cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(1,),
            in_specs=[pl.BlockSpec((cap_p, e_p), lambda i, s: (0, 0))],
            out_specs=pl.BlockSpec((n_p, e_p), lambda i, s: (0, 0))),
        out_shape=jax.ShapeDtypeStruct((n_p, e_p), kdt),
        interpret=impl == "interpret",
    )(scalars, flat)
    toks = out[:n, :e].astype(buf.dtype).reshape((n,) + elem)
    return toks, new_head, new_size


# ---------------------------------------------------------------------------
# push
# ---------------------------------------------------------------------------

def _push_kernel(n: int, cap: int, s_ref, buf_ref, arr_ref, out_ref):
    out_ref[...] = buf_ref[...]
    start = s_ref[0]

    @pl.when(start + n <= cap)
    def _contig():
        out_ref[pl.ds(start, n), :] = arr_ref[pl.ds(0, n), :]

    @pl.when(start + n > cap)
    def _wrap():
        for i in range(n):
            idx = jax.lax.rem(start + jnp.int32(i), jnp.int32(cap))
            out_ref[pl.ds(idx, 1), :] = arr_ref[pl.ds(i, 1), :]


def ring_push(buf: jax.Array, head: jax.Array, size: jax.Array,
              arr: jax.Array, *, impl: Optional[str] = None):
    """Push ``arr[n, *elem]`` onto a ring buffer at the tail.

    Returns ``(new_buf, head, new_size)`` — the head is unchanged, the
    size update (``size + n``) is fused with the buffer write.
    """
    impl = _resolve(impl)
    cap = buf.shape[0]
    n = int(arr.shape[0])
    new_size = size + n
    if n == 0:
        return buf, head, new_size
    if impl == "xla":
        idx = (head + size + jnp.arange(n, dtype=jnp.int32)) % cap
        return buf.at[idx].set(arr), head, new_size
    flat, e = _flat(buf)
    aflat, _ = _flat(arr)
    kdt = _kernel_dtype(flat.dtype)
    flat = flat.astype(kdt)
    aflat = aflat.astype(kdt)
    cap_p, n_p, e_p = _ceil(cap, _SUB), _ceil(n, _SUB), _ceil(e, _LANE)
    flat = jnp.pad(flat, ((0, cap_p - cap), (0, e_p - e)))
    aflat = jnp.pad(aflat, ((0, n_p - n), (0, e_p - e)))
    start = jnp.asarray((head + size) % cap, jnp.int32).reshape(1)
    out = pl.pallas_call(
        partial(_push_kernel, n, cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(1,),
            in_specs=[pl.BlockSpec((cap_p, e_p), lambda i, s: (0, 0)),
                      pl.BlockSpec((n_p, e_p), lambda i, s: (0, 0))],
            out_specs=pl.BlockSpec((cap_p, e_p), lambda i, s: (0, 0))),
        out_shape=jax.ShapeDtypeStruct((cap_p, e_p), kdt),
        interpret=impl == "interpret",
    )(start, flat, aflat)
    new_buf = out[:cap, :e].astype(buf.dtype).reshape(buf.shape)
    return new_buf, head, new_size


# ---------------------------------------------------------------------------
# fused guard evaluation
# ---------------------------------------------------------------------------

def _guard_kernel(nr_ref, nw_ref, occ_ref, spc_ref, live_ref, out_ref):
    ok = (nr_ref[...] <= occ_ref[...]) & (nw_ref[...] <= spc_ref[...])
    allok = jnp.all(ok, axis=1, keepdims=True)            # [Tp, 1]
    out_ref[...] = jnp.where(allok & (live_ref[...] > 0), 1, 0)


def eval_guards(sizes: jax.Array, caps, need_r: jax.Array,
                need_w: jax.Array, live: jax.Array, *,
                impl: Optional[str] = None) -> jax.Array:
    """Fused firing predicates for every task in one op.

    ``sizes[C]`` is the current channel occupancy vector, ``caps[C]``
    the static capacities, ``need_r/need_w[T, C]`` each task's
    *current-phase* per-firing token needs, ``live[T]`` the
    still-has-firings mask.  Returns ``fire[T]`` bool:

        ``fire[t] = live[t] & all_c(need_r[t,c] <= sizes[c])
                            & all_c(need_w[t,c] <= caps[c] - sizes[c])``

    Pure integer comparisons — bit-identical across all impls.
    """
    impl = _resolve(impl)
    caps = jnp.asarray(caps, jnp.int32)
    t, c = need_r.shape
    if impl == "xla" or c == 0:
        if c == 0:
            return live
        space = caps - sizes
        ok_r = jnp.all(need_r <= sizes[None, :], axis=1)
        ok_w = jnp.all(need_w <= space[None, :], axis=1)
        return live & ok_r & ok_w
    t_p, c_p = _ceil(t, _SUB), _ceil(c, _LANE)
    pad2 = lambda a: jnp.pad(a.astype(jnp.int32),
                             ((0, t_p - t), (0, c_p - c)))
    row = lambda v: jnp.broadcast_to(
        jnp.pad(v.astype(jnp.int32), (0, c_p - c))[None, :], (t_p, c_p))
    live_m = jnp.broadcast_to(
        jnp.pad(live.astype(jnp.int32), (0, t_p - t))[:, None],
        (t_p, _LANE))
    out = pl.pallas_call(
        _guard_kernel,
        out_shape=jax.ShapeDtypeStruct((t_p, _LANE), jnp.int32),
        interpret=impl == "interpret",
    )(pad2(need_r), pad2(need_w), row(sizes), row(caps - sizes), live_m)
    return out[:t, 0] > 0
