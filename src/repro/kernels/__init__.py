"""Pallas TPU kernels for the compute hot-spots (pl.pallas_call + BlockSpec).

Three kernels, each with a pure-jnp oracle in ref.py and a jit'd public
wrapper in ops.py:

* flash_attention — tiled online-softmax attention (GQA / causal / window)
* decode_attention — flash-decode for one-token serving against a KV cache
* ssd_scan — Mamba-2 SSD chunked scan with VMEM-carried inter-chunk state

On non-TPU backends the kernels run under ``interpret=True`` (Python
execution of the kernel body — the correctness-validation mode).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
