"""Public jit-friendly wrappers around the Pallas kernels.

Layout conversion (model layout [B, S, heads, hd] <-> kernel head-major
layout), padding to block multiples, interpret-mode selection (the kernels
execute in Python on CPU via ``interpret=True``; on a TPU backend they
lower to Mosaic), and ``jax.custom_vjp`` definitions live here so the
kernels themselves stay pure forward passes.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_fwd
from .dispatch import resolve_impl
from .flash_attention import flash_attention_fwd
from .ssd_scan import ssd_scan_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q, k, v, causal: bool, window: Optional[int]):
    out, _ = _flash_fwd_res(q, k, v, causal, window)
    return out


def _flash_fwd_res(q, k, v, causal, window):
    # model layout [B, S, h, hd] -> head-major [B, h, S, hd]
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out, lse = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                   interpret=_interpret())
    return jnp.swapaxes(out, 1, 2), (q, k, v, jnp.swapaxes(out, 1, 2), lse)


def _flash_bwd(causal, window, res, dout):
    """Standard flash backward from saved (q, k, v, out, lse), pure jnp fp32.

    On real hardware this would be its own kernel; training defaults to the
    XLA path (use_kernel=False), so this keeps the custom_vjp law exact
    without a second Pallas kernel.
    """
    q, k, v, out, lse = res
    B, Sq, nh, hd = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B, Sq, nkv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = dout.reshape(B, Sq, nkv, g, hd).astype(jnp.float32)
    of = out.reshape(B, Sq, nkv, g, hd).astype(jnp.float32)
    lse_g = jnp.swapaxes(lse.reshape(B, nkv, g, Sq), 1, 3)  # [B,Sq,g,nkv]
    lse_g = jnp.swapaxes(lse_g, 2, 3)                       # [B,Sq,nkv,g]

    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    p = jnp.exp(s - jnp.moveaxis(lse_g, (1, 2, 3), (3, 1, 2))[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)

    Dv = jnp.sum(dof * of, axis=-1)                          # [B,Sq,nkv,g]
    dp = jnp.einsum("bqkgh,bskh->bkgqs", dof, vf)
    ds = p * (dp - jnp.moveaxis(Dv, (1, 2, 3), (3, 1, 2))[..., None])
    dq = jnp.einsum("bkgqs,bskh->bqkgh", ds, kf) * scale
    dk = jnp.einsum("bkgqs,bqkgh->bskh", ds, qf) * scale
    dv = jnp.einsum("bkgqs,bqkgh->bskh", p, dof)
    return (dq.reshape(B, Sq, nh, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


def _flash_fwd_rule(q, k, v, causal, window):
    out, res = _flash_fwd_res(q, k, v, causal, window)
    return out, res


_flash.defvjp(_flash_fwd_rule, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    """Flash attention, model layout.

    q: [B, Sq, nh, hd]; k/v: [B, Sk, nkv, hd].  Falls back to the jnp
    reference when shapes don't tile (non-128-multiple sequence lengths).
    """
    Sq, Sk = q.shape[1], k.shape[1]
    bq = min(128, Sq)
    bk = min(128, Sk)
    if Sq % bq or Sk % bk or (q.shape[-1] % 8):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal, window)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *,
                     block_k: int = 256,
                     impl: Optional[str] = None) -> jax.Array:
    """One-token GQA decode against a cache (no grad path — serving only).

    q: [B, nh, hd] or [B, 1, nh, hd]; k/v: [B, S_max, nkv, hd];
    kv_len: scalar or [B] int32 valid length.  Returns q-shaped output.

    Backend dispatch (``impl``, default from ``$REPRO_DECODE_ATTN``):

    * ``"pallas"``    — the Mosaic-lowered flash-decode kernel (TPU default);
    * ``"interpret"`` — the same kernel under the Pallas interpreter
      (bit-exact kernel semantics on any backend; used by parity tests);
    * ``"ref"``       — the vectorized jnp oracle (non-TPU default: on CPU
      the interpreter's sequential grid emulation costs ~3x the fused
      masked attention, and the serving decode loop is latency-critical).

    All three share the ragged-length contract: per-row valid lengths,
    ``kv_len == 0`` rows (dead serving slots) contribute no HBM traffic on
    the kernel paths.
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    B, nh, hd = q.shape
    Smax, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    impl = resolve_impl("decode_attention", "REPRO_DECODE_ATTN",
                        ("pallas", "interpret", "ref"), fallback="ref",
                        impl=impl)
    bk = min(block_k, Smax)
    if impl == "ref":
        out = ref.decode_attention_ref(q, k, v, lens)
        return out[:, None] if squeeze else out
    if Smax % bk:
        # explicit kernel request with a non-block-multiple cache: pad the
        # KV axis (positions >= kv_len are masked, so zeros are inert)
        # rather than silently answering from the oracle
        pad = bk - Smax % bk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qt = q.reshape(B, nkv, g, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = decode_attention_fwd(qt, kt, vt, lens, block_k=bk,
                               interpret=impl == "interpret")
    out = out.reshape(B, nh, hd)
    return out[:, None] if squeeze else out


# ---------------------------------------------------------------------------
# SSD scan (Mamba-2)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _ssd(x, dt, A, Bm, Cm, D, chunk, s0):
    return _ssd_call(x, dt, A, Bm, Cm, D, chunk, s0)


def _ssd_call(x, dt, A, Bm, Cm, D, chunk, s0):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    pad = (-S) % chunk
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # head-major kernel layout + precomputed elementwise terms
    xdt = jnp.moveaxis(xf * dtf[..., None], (1, 2), (2, 1))   # [B,H,S,P]
    dA = jnp.moveaxis(dtf * A.astype(jnp.float32), (1, 2),
                      (2, 1))[:, :, None, :]                  # [B,H,1,S]
    Bt = jnp.moveaxis(Bm.astype(jnp.float32), 1, 2)           # [B,G,S,N]
    Ct = jnp.moveaxis(Cm.astype(jnp.float32), 1, 2)
    y, sfinal = ssd_scan_fwd(xdt, dA, Bt, Ct, s0.astype(jnp.float32),
                             chunk=chunk, interpret=_interpret())
    y = jnp.moveaxis(y, 1, 2)[:, :S]                          # [B,S,H,P]
    y = y + xf[:, :S] * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), sfinal


def _ssd_fwd_rule(x, dt, A, Bm, Cm, D, chunk, s0):
    out = _ssd(x, dt, A, Bm, Cm, D, chunk, s0)
    return out, (x, dt, A, Bm, Cm, D, s0)


def _ssd_bwd(chunk, res, cts):
    """Recompute-through-reference backward (state cotangent included)."""
    x, dt, A, Bm, Cm, D, s0 = res

    def f(x, dt, A, Bm, Cm, D, s0):
        return ref.ssd_scan_ref(x, dt, A, Bm, Cm, D, init_state=s0)

    _, vjp = jax.vjp(f, x, dt, A, Bm, Cm, D, s0)
    return vjp(cts)


_ssd.defvjp(_ssd_fwd_rule, _ssd_bwd)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, D: jax.Array, *, chunk: int = 256,
             init_state: Optional[jax.Array] = None) -> tuple:
    """Chunked SSD sequence mixing (kernel-backed).

    Shapes as ref.ssd_scan_ref.  Returns (y, final_state fp32).
    """
    B, S, H, P = x.shape
    N = Bm.shape[3]
    s0 = init_state if init_state is not None else \
        jnp.zeros((B, H, P, N), jnp.float32)
    return _ssd(x, dt, A, Bm, Cm, D, chunk, s0)
