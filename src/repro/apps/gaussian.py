"""SODA-style iterative Gaussian-filter dataflow (paper Section 4.1).

A chain of ``iters`` stencil stages; each stage holds a line buffer (two
rows + two pixels of reuse) and applies the 3x3 Gaussian kernel as soon as
its window is full — the communication-optimal reuse-buffer
microarchitecture SODA generates.  Pixels stream through stage by stage,
one EoT-delimited transaction per image.

Interface migration: the image enters through a read ``mmap`` (Source
bursts it row by row) and the result leaves through a write ``mmap``
(Sink stores the reassembled frame) — no task body closure-captures an
array, so the frame traffic is visible to per-interface stats and the
graph IR.  Task definitions are module level: every build shares them.

Instance count scales with ``iters * width`` when vectorized; the paper's
build is 564 instances (16 lanes x 8 iterations + forks).  The default here
is one lane per stage (fast sim); the sim-time benchmark raises ``iters``
and ``lanes`` to probe scheduler scalability (Fig. 7's gaussian point).
"""

from __future__ import annotations

import numpy as np

from ..core import MMap, StepTask, channel, mmap, task
from .base import AppResult, simulate

K = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.float32) / 16.0


def _stencil_ref(img: np.ndarray) -> np.ndarray:
    h, w = img.shape
    out = img.copy()
    acc = np.zeros_like(img)
    for dy in range(3):
        for dx in range(3):
            acc[1:h-1, 1:w-1] += K[dy, dx] * img[dy:h-2+dy, dx:w-2+dx]
    out[1:h-1, 1:w-1] = acc[1:h-1, 1:w-1]
    return out


def Source(img: MMap, out, h: int, w: int):
    # one mmap burst loads the frame; rows then stream in bursts (the
    # line buffers downstream consume in row-sized chunks anyway)
    frame = img.read_burst(0, h)
    out.write_burst([float(px) for px in np.asarray(frame).reshape(-1)])
    out.close()


def Stencil(inp, out, h: int, w: int):
    """Line-buffered 3x3 stencil over a row-major pixel stream.

    A centre pixel's window completes when its south-east neighbour
    (linear index centre + w + 1) arrives, so the stage emits with a
    fixed latency of w+2 pixels — the SODA reuse-buffer schedule.
    Pixels move in row-sized bursts; emitted pixels are staged in a
    local list and flushed with one ``write_burst`` per input burst.
    """
    buf: list[float] = []
    pending: list[float] = []

    def emit(cy: int) -> None:
        y, x = divmod(cy, w)
        if 1 <= y < h - 1 and 1 <= x < w - 1:
            win = (K[0, 0] * buf[cy-w-1] + K[0, 1] * buf[cy-w] +
                   K[0, 2] * buf[cy-w+1] +
                   K[1, 0] * buf[cy-1] + K[1, 1] * buf[cy] +
                   K[1, 2] * buf[cy+1] +
                   K[2, 0] * buf[cy+w-1] + K[2, 1] * buf[cy+w] +
                   K[2, 2] * buf[cy+w+1])
            pending.append(float(win))
        else:
            pending.append(buf[cy])

    while True:
        chunk = inp.read_burst(w)
        for px in chunk:
            buf.append(px)
            cy = len(buf) - w - 2   # centre whose window just completed
            if cy >= 0:
                emit(cy)
        if pending:
            out.write_burst(pending)
            pending.clear()
        if len(chunk) < w:          # EoT reached
            break
    inp.open()
    for cy in range(max(len(buf) - w - 1, 0), len(buf)):
        emit(cy)                    # tail pixels (all boundary)
    if pending:
        out.write_burst(pending)
    out.close()


def Sink(inp, result: MMap, h: int, w: int):
    flat = inp.read_transaction()
    result.write_burst(0, np.asarray(flat, np.float32).reshape(h, w))


def build(h: int = 12, w: int = 12, iters: int = 4, lanes: int = 1,
          seed: int = 0):
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((h, w)).astype(np.float32)
    result = np.zeros_like(img)

    img_mm = mmap(img, "img")
    res_mm = mmap(result, "result")

    def Top(src: MMap, dst: MMap):
        chans = [channel(capacity=2 * w + 4, name=f"s{i}")
                 for i in range(iters + 1)]
        t = task().invoke(Source, src, chans[0], h, w)
        for i in range(iters):
            t = t.invoke(Stencil, chans[i], chans[i + 1], h, w,
                         name=f"Stencil{i}")
        t.invoke(Sink, chans[iters], dst, h, w)

    def check():
        ref = img
        for _ in range(iters):
            ref = _stencil_ref(ref)
        err = float(np.max(np.abs(result - ref)))
        return err < 1e-4, err

    return Top, (img_mm, res_mm), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("gaussian", top, args, engine, check)


# ---------------------------------------------------------------------------
# step-function form (whole-graph synthesis, docs/synthesis.md)
# ---------------------------------------------------------------------------

def build_step(h: int = 12, w: int = 12, iters: int = 4, seed: int = 0):
    """The stencil chain in traceable step-function form — the
    **burst-heavy** case: every firing moves a whole image row as one
    ``read_burst(w)``/``write_burst`` over a scalar-token channel, which
    synthesis lowers to a w-wide gather/scatter on the ring buffer.

    Each stencil stage keeps two rows of state (the SODA reuse buffer)
    across three phases: a 1-firing warmup fills the window, the h-1
    steady-state firings read row i and emit output row i-1, and a
    1-firing flush drains the final boundary row.  The frame enters
    through a read mmap and leaves through a write mmap, row by row.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    img = rng.standard_normal((h, w)).astype(np.float32)
    result = np.zeros_like(img)

    img_mm = mmap(img, "img")
    res_mm = mmap(result, "result")

    def source_step(k, img_m: MMap, out):
        row = jnp.asarray(img_m.read_burst(k, 1))[0]
        out.write_burst(row)
        return k + 1

    # bit-parity contract (docs/synthesis.md): the window math goes
    # through a jitted helper so the twin executes the same contracted
    # kernel the whole-graph program inlines
    @jax.jit
    def _out_row(i, pp, p, cur):
        win = (K[0, 0] * pp[:-2] + K[0, 1] * pp[1:-1] + K[0, 2] * pp[2:] +
               K[1, 0] * p[:-2] + K[1, 1] * p[1:-1] + K[1, 2] * p[2:] +
               K[2, 0] * cur[:-2] + K[2, 1] * cur[1:-1] + K[2, 2] * cur[2:])
        # row 0 is a boundary: emitted as-is (and so are the edge columns)
        mid = jnp.where(i - 1 == 0, p[1:-1], win)
        return jnp.concatenate([p[:1], mid, p[-1:]])

    def stencil_warmup(state, inp, out):
        i, pp, p = state
        row = inp.read_burst(w)
        return (i + 1, row, row)

    def stencil_step(state, inp, out):
        i, pp, p = state            # reading row i; emitting row i-1
        cur = inp.read_burst(w)
        out.write_burst(_out_row(i, pp, p, cur))
        return (i + 1, p, cur)

    def stencil_flush(state, inp, out):
        i, pp, p = state
        out.write_burst(p)          # last row: boundary copy
        return state

    def sink_step(k, inp, res: MMap):
        row = inp.read_burst(w)
        res.write_burst(k, row[None, :])
        return k + 1

    SourceS = StepTask(source_step, steps=h, init=jnp.int32(0),
                       name="Source")
    StencilS = StepTask(stencil_step, steps=h - 1, warmup=stencil_warmup,
                        flush=stencil_flush,
                        init=(jnp.int32(0), jnp.zeros(w, jnp.float32),
                              jnp.zeros(w, jnp.float32)), name="Stencil")
    SinkS = StepTask(sink_step, steps=h, init=jnp.int32(0), name="Sink")

    def Top(src: MMap, dst: MMap):
        chans = [channel(2 * w, f"s{i}", dtype=np.float32, shape=())
                 for i in range(iters + 1)]
        t = task().invoke(SourceS, src, chans[0])
        for i in range(iters):
            t = t.invoke(StencilS, chans[i], chans[i + 1],
                         name=f"Stencil{i}")
        t.invoke(SinkS, chans[iters], dst)

    def check():
        ref = img
        for _ in range(iters):
            ref = _stencil_ref(ref)
        err = float(np.max(np.abs(result - ref)))
        return err < 1e-4, err

    return Top, (img_mm, res_mm), check


def run_step(engine: str = "coroutine", **kw) -> AppResult:
    """Run the step-form graph — ``engine="compiled"`` synthesizes it."""
    top, args, check = build_step(**kw)
    return simulate("gaussian_step", top, args, engine, check)


# ---------------------------------------------------------------------------
# compiled (XLA) path — hierarchical codegen through the compile cache
# ---------------------------------------------------------------------------

def jax_stages(h: int = 12, w: int = 12, iters: int = 4):
    """The gaussian chain as JAX stage instances: ``iters`` instances of
    one stencil *definition* plus source/sink, wired as a feed-forward
    chain.  The stage closures are re-created on every call — exactly the
    case ``id(fn)`` keying mis-handled and the structural hash dedups."""
    import jax.numpy as jnp

    from ..core.hier_compile import StageInstance

    KJ = [[float(K[dy, dx]) for dx in range(3)] for dy in range(3)]

    def source(img):
        return img.astype(jnp.float32)

    def stencil(img):
        acc = sum(KJ[dy][dx] * img[dy:h - 2 + dy, dx:w - 2 + dx]
                  for dy in range(3) for dx in range(3))
        return img.at[1:-1, 1:-1].set(acc)

    def sink(img):
        return img

    spec = jnp.zeros((h, w), jnp.float32)
    insts = [StageInstance(fn=source, args=(spec,), name="Source")]
    insts += [StageInstance(fn=stencil, args=(spec,), name=f"Stencil{i}")
              for i in range(iters)]
    insts += [StageInstance(fn=sink, args=(spec,), name="Sink")]
    wiring = {i: [i - 1] for i in range(1, len(insts))}
    return insts, wiring


def compile_app(h: int = 12, w: int = 12, iters: int = 4, *,
                engine: str = "coroutine", cache=None, prev=None):
    """Elaborate the dataflow (correctness cycle) then hierarchically
    compile the per-stage XLA kernels through the compile cache.

    Returns ``(graph, report, program)``; a second call — even from a
    fresh process pointed at the same cache root — performs zero XLA
    compilations (``report.n_compiled == 0``).
    """
    from ..core.graph import elaborate
    from ..core.hier_compile import build_dataflow, compile_stages

    top, args, _ = build(h=h, w=w, iters=iters)
    graph = elaborate(top, *args, engine=engine)
    insts, wiring = jax_stages(h=h, w=w, iters=iters)
    report = compile_stages(insts, mode="hierarchical", cache=cache,
                            prev=prev)
    program = build_dataflow(insts, wiring)
    return graph, report, program
