"""Shared plumbing for the benchmark apps."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from ..core.engines import ENGINES, SimReport


@dataclasses.dataclass
class AppResult:
    name: str
    report: SimReport
    correct: Optional[bool]          # None when the sim itself failed
    max_err: float = 0.0

    @property
    def ok(self) -> bool:
        return self.report.ok and bool(self.correct)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<AppResult {self.name} sim={'ok' if self.report.ok else 'FAIL'}"
                f" correct={self.correct} err={self.max_err:.2e} "
                f"wall={self.report.wall_s*1e3:.1f}ms "
                f"insts={self.report.n_instances} "
                f"chans={self.report.n_channels}>")


def simulate(name: str, top: Callable, args: tuple, engine: str,
             check: Callable[[], tuple[bool, float]],
             engine_kwargs: Optional[dict] = None) -> AppResult:
    """``engine_kwargs`` go to the engine constructor — e.g.
    ``{"mesh": 4}`` runs the compiled engine partitioned over 4
    devices."""
    rep = ENGINES[engine](**(engine_kwargs or {})).run(top, *args)
    if not rep.ok:
        return AppResult(name=name, report=rep, correct=None)
    good, err = check()
    return AppResult(name=name, report=rep, correct=good, max_err=err)
