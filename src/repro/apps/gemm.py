"""PolySA-style systolic GEMM (paper Section 4.1).

Unlike Cannon, PolySA's array avoids feedback: A blocks stream left->right
through each row, B blocks stream top->bottom through each column, partial
C stays resident in the PE (output-stationary).  The graph is a DAG, so
even the sequential simulator handles it — the interesting axis here is
C3: one PE definition stamped out P^2 times (14 tasks / 207 instances in
the paper's build).

Interface migration: the matrices enter through declared ``mmap``
arguments (paper Table 2) instead of closure capture — feeders *load*
from ``a``/``b``, each row's collector *stores* into its own view of C
(one-writer rule), and the task definitions are module-level functions,
so every build shares the same definitions and the memory traffic shows
up in the graph IR and per-interface stats.
"""

from __future__ import annotations

import numpy as np

from ..core import MMap, OStream, StepTask, channel, mmap, task
from .base import AppResult, simulate


def AFeeder(a: MMap, out: OStream, i: int, n: int, K: int):
    # burst write: row i's K blocks move in capacity-sized batches, one
    # runtime interaction per batch instead of per block; the mmap load is
    # one burst-tracked block per k
    out.write_burst([a[i * n:(i + 1) * n, k * n:(k + 1) * n]
                     for k in range(K)])
    out.close()


def BFeeder(b: MMap, out: OStream, j: int, n: int, K: int):
    out.write_burst([b[k * n:(k + 1) * n, j * n:(j + 1) * n]
                     for k in range(K)])
    out.close()


def PE(a_in, b_in, a_out, b_out, c_out, burst: int = 2):
    acc = None
    while True:
        a_blks = a_in.read_burst(burst)
        if not a_blks:
            break
        # the B stream carries exactly as many blocks as the A stream,
        # so a same-sized burst keeps the pair in lockstep
        b_blks = b_in.read_burst(len(a_blks))
        for a, b in zip(a_blks, b_blks):
            acc = a @ b if acc is None else acc + a @ b
        if a_out is not None:
            a_out.write_burst(a_blks)
        if b_out is not None:
            b_out.write_burst(b_blks)
        if len(a_blks) < burst:
            break
    a_in.open()
    b_in.open()
    if a_out is not None:
        a_out.close()
    if b_out is not None:
        b_out.close()
    c_out.write(acc)


def Collector(c_row: MMap, c_ins, i: int, n: int):
    for j, ch in enumerate(c_ins):
        c_row[:, j * n:(j + 1) * n] = ch.read()


def build(P: int = 4, n: int = 8, K: int = 4, seed: int = 0):
    """(P*n x K*n) @ (K*n x P*n) on a PxP output-stationary array."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((P * n, K * n)).astype(np.float32)
    B = rng.standard_normal((K * n, P * n)).astype(np.float32)
    C = np.zeros((P * n, P * n), np.float32)

    a_mm = mmap(A, "A")
    b_mm = mmap(B, "B")
    # one writable view of C per collector: the one-writer rule holds per
    # mmap object, and numpy views write through to the same buffer
    c_rows = [mmap(C[i * n:(i + 1) * n, :], f"C{i}") for i in range(P)]

    def Top(a: MMap, b: MMap, c_views):
        a_ch = [[channel(2, f"a{i}_{j}") for j in range(P)] for i in range(P)]
        b_ch = [[channel(2, f"b{i}_{j}") for j in range(P)] for i in range(P)]
        c_ch = [[channel(1, f"c{i}_{j}") for j in range(P)] for i in range(P)]
        t = task()
        for i in range(P):
            t = t.invoke(AFeeder, a, a_ch[i][0], i, n, K, name=f"AFeeder{i}")
            t = t.invoke(BFeeder, b, b_ch[0][i], i, n, K, name=f"BFeeder{i}")
        for i in range(P):
            for j in range(P):
                t = t.invoke(
                    PE, a_ch[i][j], b_ch[i][j],
                    a_ch[i][j + 1] if j + 1 < P else None,
                    b_ch[i + 1][j] if i + 1 < P else None,
                    c_ch[i][j], name=f"PE{i}_{j}")
        for i in range(P):
            t = t.invoke(Collector, c_views[i], c_ch[i], i, n,
                         name=f"Collector{i}")

    def check():
        ref = A @ B
        err = float(np.max(np.abs(C - ref)))
        return err < 1e-3 * K * n, err

    return Top, (a_mm, b_mm, c_rows), check


def run(engine: str = "coroutine", P: int = 4, n: int = 8, K: int = 4,
        seed: int = 0) -> AppResult:
    top, args, check = build(P=P, n=n, K=K, seed=seed)
    return simulate("gemm", top, args, engine, check)


# ---------------------------------------------------------------------------
# step-function form (whole-graph synthesis, docs/synthesis.md)
# ---------------------------------------------------------------------------

def build_step(P: int = 4, n: int = 8, K: int = 4, seed: int = 0):
    """The same systolic array in traceable step-function form.

    Feeders fire K times emitting one (n, n) block per firing (the A
    column / B row selected by a dynamic slice on the firing counter),
    PEs fire K times (read a+b, forward, accumulate) then flush their
    resident C block once, and each row's collector fires once, draining
    its P result channels into its C-row mmap view.  Array tokens make
    the channels wide: the a/b rings hold (capacity, n, n) blocks.

    Runs identically under every simulation engine (the StepTask twin)
    and under ``CompiledEngine`` as one jitted program.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    A = rng.standard_normal((P * n, K * n)).astype(np.float32)
    B = rng.standard_normal((K * n, P * n)).astype(np.float32)
    C = np.zeros((P * n, P * n), np.float32)

    a_mm = mmap(A, "A")
    b_mm = mmap(B, "B")
    c_rows = [mmap(C[i * n:(i + 1) * n, :], f"C{i}") for i in range(P)]

    def afeeder_step(k, a: MMap, out, i: int):
        rows = jnp.asarray(a.read_burst(i * n, n))      # (n, K*n), static i
        out.write(jax.lax.dynamic_slice_in_dim(rows, k * n, n, axis=1))
        return k + 1

    def bfeeder_step(k, b: MMap, out, j: int):
        rows = jnp.asarray(b.read_burst(k * n, n))      # (n, P*n), dynamic k
        out.write(rows[:, j * n:(j + 1) * n])
        return k + 1

    # bit-parity contract (docs/synthesis.md): the MAC goes through a
    # jitted helper so the twin executes the same contracted kernel the
    # whole-graph program inlines
    _mac = jax.jit(lambda acc, a, b: acc + a @ b)

    def pe_step(acc, a_in, b_in, a_out, b_out, c_out):
        a = a_in.read()
        b = b_in.read()
        if a_out is not None:
            a_out.write(a)
        if b_out is not None:
            b_out.write(b)
        return _mac(acc, a, b)

    def pe_flush(acc, a_in, b_in, a_out, b_out, c_out):
        c_out.write(acc)
        return acc

    def collector_step(state, c_row: MMap, c_ins, i: int):
        for j, ch in enumerate(c_ins):
            c_row[:, j * n:(j + 1) * n] = ch.read()
        return state

    AFeederS = StepTask(afeeder_step, steps=K, init=jnp.int32(0),
                        name="AFeeder")
    BFeederS = StepTask(bfeeder_step, steps=K, init=jnp.int32(0),
                        name="BFeeder")
    PES = StepTask(pe_step, steps=K, flush=pe_flush,
                   init=jnp.zeros((n, n), jnp.float32), name="PE")
    CollectorS = StepTask(collector_step, steps=1, name="Collector")

    def Top(a: MMap, b: MMap, c_views):
        blk = dict(dtype=np.float32, shape=(n, n))
        a_ch = [[channel(2, f"a{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        b_ch = [[channel(2, f"b{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        c_ch = [[channel(1, f"c{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        t = task()
        for i in range(P):
            t = t.invoke(AFeederS, a, a_ch[i][0], i, name=f"AFeeder{i}")
            t = t.invoke(BFeederS, b, b_ch[0][i], i, name=f"BFeeder{i}")
        for i in range(P):
            for j in range(P):
                t = t.invoke(
                    PES, a_ch[i][j], b_ch[i][j],
                    a_ch[i][j + 1] if j + 1 < P else None,
                    b_ch[i + 1][j] if i + 1 < P else None,
                    c_ch[i][j], name=f"PE{i}_{j}")
        for i in range(P):
            t = t.invoke(CollectorS, c_views[i], c_ch[i], i,
                         name=f"Collector{i}")

    def check():
        ref = A @ B
        err = float(np.max(np.abs(C - ref)))
        return err < 1e-3 * K * n, err

    return Top, (a_mm, b_mm, c_rows), check


def run_step(engine: str = "coroutine", P: int = 4, n: int = 8, K: int = 4,
             seed: int = 0, engine_kwargs: dict = None) -> AppResult:
    """Run the step-form graph — ``engine="compiled"`` synthesizes it;
    ``engine_kwargs={"mesh": N}`` floorplans it over N devices."""
    top, args, check = build_step(P=P, n=n, K=K, seed=seed)
    return simulate("gemm_step", top, args, engine, check,
                    engine_kwargs=engine_kwargs)


def build_step_async(P: int = 4, n: int = 8, K: int = 4, seed: int = 0,
                     mem_latency: int = 4, depth: int = 4):
    """The systolic array with **async memory ports** on both ends: each
    row's A blocks arrive through an ``async_mmap`` read port (an AFetch
    task keeps up to ``depth`` block fetches in flight) and each row's C
    blocks leave through an ``async_mmap`` write port (a CStore task
    issues stores ahead of the returning write acks).  Synthesizable by
    ``CompiledEngine`` — the ports lower to latency queues in the
    whole-graph program (docs/synthesis.md, "kernel lowering").

    Because per-firing channel *selection* must be static, the row's P
    result channels are funneled through a RowMux task into one
    capacity-P channel that CStore drains block-by-block; B keeps its
    plain mmap feeders, so the graph mixes sync and async interfaces.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    A = rng.standard_normal((P * n, K * n)).astype(np.float32)
    B = rng.standard_normal((K * n, P * n)).astype(np.float32)

    from ..core import async_mmap

    # row i's K blocks, block-indexed: a_blocks[i][k] == A block (i, k)
    a_blocks = [np.ascontiguousarray(
        A[i * n:(i + 1) * n, :].reshape(n, K, n).swapaxes(0, 1))
        for i in range(P)]
    a_ports = [async_mmap(a_blocks[i], latency=mem_latency, depth=depth,
                          name=f"Ablk{i}") for i in range(P)]
    c_ports = [async_mmap(np.zeros((P, n, n), np.float32),
                          latency=mem_latency, depth=depth, name=f"Crow{i}")
               for i in range(P)]
    b_mm = mmap(B, "B")

    dA = min(depth, K)
    dC = min(depth, P)

    def afetch_warm(k, port, out):
        port.read_addr.write(k)
        return k + 1

    def afetch_step(k, port, out):
        out.write(port.read_data.read())
        port.read_addr.write(k)
        return k + 1

    def afetch_flush(k, port, out):
        out.write(port.read_data.read())
        return k + 1

    def bfeeder_step(k, b: MMap, out, j: int):
        rows = jnp.asarray(b.read_burst(k * n, n))      # (n, P*n), dynamic k
        out.write(rows[:, j * n:(j + 1) * n])
        return k + 1

    _mac = jax.jit(lambda acc, a, b: acc + a @ b)

    def pe_step(acc, a_in, b_in, a_out, b_out, c_out):
        a = a_in.read()
        b = b_in.read()
        if a_out is not None:
            a_out.write(a)
        if b_out is not None:
            b_out.write(b)
        return _mac(acc, a, b)

    def pe_flush(acc, a_in, b_in, a_out, b_out, c_out):
        c_out.write(acc)
        return acc

    def rowmux_step(state, c_ins, crow):
        crow.write_burst(jnp.stack([ch.read() for ch in c_ins]))
        return state

    def cstore_warm(k, port, crow):
        port.write_addr.write(k)
        port.write_data.write(crow.read())
        return k + 1

    def cstore_step(k, port, crow):
        port.write_resp.read()
        port.write_addr.write(k)
        port.write_data.write(crow.read())
        return k + 1

    def cstore_flush(k, port, crow):
        port.write_resp.read()
        return k + 1

    AFetchS = StepTask(afetch_step, steps=K - dA, init=jnp.int32(0),
                       warmup=afetch_warm, n_warmup=dA,
                       flush=afetch_flush, n_flush=dA, name="AFetch")
    BFeederS = StepTask(bfeeder_step, steps=K, init=jnp.int32(0),
                        name="BFeeder")
    PES = StepTask(pe_step, steps=K, flush=pe_flush,
                   init=jnp.zeros((n, n), jnp.float32), name="PE")
    RowMuxS = StepTask(rowmux_step, steps=1, name="RowMux")
    CStoreS = StepTask(cstore_step, steps=P - dC, init=jnp.int32(0),
                       warmup=cstore_warm, n_warmup=dC,
                       flush=cstore_flush, n_flush=dC, name="CStore")

    def Top(b: MMap, aports, cports):
        blk = dict(dtype=np.float32, shape=(n, n))
        a_ch = [[channel(2, f"a{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        b_ch = [[channel(2, f"b{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        c_ch = [[channel(1, f"c{i}_{j}", **blk) for j in range(P)]
                for i in range(P)]
        crow_ch = [channel(P, f"crow{i}", **blk) for i in range(P)]
        t = task()
        for i in range(P):
            t = t.invoke(AFetchS, aports[i], a_ch[i][0], name=f"AFetch{i}")
            t = t.invoke(BFeederS, b, b_ch[0][i], i, name=f"BFeeder{i}")
        for i in range(P):
            for j in range(P):
                t = t.invoke(
                    PES, a_ch[i][j], b_ch[i][j],
                    a_ch[i][j + 1] if j + 1 < P else None,
                    b_ch[i + 1][j] if i + 1 < P else None,
                    c_ch[i][j], name=f"PE{i}_{j}")
        for i in range(P):
            t = t.invoke(RowMuxS, c_ch[i], crow_ch[i], name=f"RowMux{i}")
            t = t.invoke(CStoreS, cports[i], crow_ch[i], name=f"CStore{i}")

    def check():
        ref = A @ B
        got = np.concatenate(
            [np.concatenate(list(np.asarray(c_ports[i].data)), axis=1)
             for i in range(P)], axis=0)
        err = float(np.max(np.abs(got - ref)))
        return err < 1e-3 * K * n, err

    return Top, (b_mm, a_ports, c_ports), check


def run_step_async(engine: str = "coroutine", P: int = 4, n: int = 8,
                   K: int = 4, seed: int = 0, mem_latency: int = 4,
                   depth: int = 4) -> AppResult:
    """Run the async-port step-form graph on any engine (incl. compiled)."""
    top, args, check = build_step_async(P=P, n=n, K=K, seed=seed,
                                        mem_latency=mem_latency, depth=depth)
    return simulate("gemm_step_async", top, args, engine, check)
