"""Cannon's algorithm on a PxP toroidal PE mesh (paper Section 4.1).

The defining property: every PE forwards its A block left and its B block
up on a *torus* — the wrap-around links make the dataflow graph cyclic,
which is exactly why the paper reports sequential simulators cannot handle
this benchmark (Fig. 7).  The coroutine and thread engines simulate it; the
sequential engine must fail with SequentialSimulationError.

Graph shape (paper Table 3: 5 task defs / 91 instances / 344 channels at
8x8): ADistrib/BDistrib feeders write each PE's initially-skewed resident
block on a dedicated init channel (one-producer rule); the rotation rings
run PE->PE with wrap-around, so the cycles are genuine.  At P=8 this build
has 88 instances and 320 channels — same shape, same task definitions.

Interface migration: A and B enter through read ``mmap`` arguments, each
collector row stores through its own writable view of C (one-writer per
mmap), and the definitions are module-level — no closure-captured arrays.

Burst note: cannon is the anti-burst benchmark.  Every rotation token is
data-dependent on the previous round (the block a PE forwards is the block
it just received), so the rings are inherently one-token-deep and the
burst channel API cannot batch them — unlike gemm/gaussian whose DAG
pipelines burst freely.  Cannon still benefits from the coroutine engine's
run-to-block fast path (rotation pushes/pops on non-full/non-empty rings
skip engine dispatch), which is exactly the per-token overhead the paper's
collaborative scheduling minimizes.
"""

from __future__ import annotations

import numpy as np

from ..core import MMap, channel, mmap, task
from .base import AppResult, simulate


def ADistrib(a: MMap, inits, i: int, n: int, P: int):
    # initial Cannon skew: PE(i,j) holds A(i, (i+j) mod P)
    for j, ch in enumerate(inits):
        k = (i + j) % P
        ch.write(a[i * n:(i + 1) * n, k * n:(k + 1) * n])


def BDistrib(b: MMap, inits, j: int, n: int, P: int):
    # initial Cannon skew: PE(i,j) holds B((i+j) mod P, j)
    for i, ch in enumerate(inits):
        k = (i + j) % P
        ch.write(b[k * n:(k + 1) * n, j * n:(j + 1) * n])


def PE(a_init, b_init, a_in, b_in, a_out, b_out, c_out, rounds: int):
    acc = None
    for r in range(rounds):
        a = a_init.read() if r == 0 else a_in.read()
        b = b_init.read() if r == 0 else b_in.read()
        acc = a @ b if acc is None else acc + a @ b
        if r < rounds - 1:            # rotate: A left, B up (torus)
            a_out.write(a)
            b_out.write(b)
    c_out.write(acc)


def Collector(c_row: MMap, c_ins, i: int, n: int):
    for j, ch in enumerate(c_ins):
        c_row[:, j * n:(j + 1) * n] = ch.read()


def build(P: int = 4, n: int = 8, seed: int = 0):
    """PxP PE mesh multiplying (P*n x P*n) matrices in n x n blocks."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((P * n, P * n)).astype(np.float32)
    B = rng.standard_normal((P * n, P * n)).astype(np.float32)
    C = np.zeros_like(A)

    a_mm = mmap(A, "A")
    b_mm = mmap(B, "B")
    c_rows = [mmap(C[i * n:(i + 1) * n, :], f"C{i}") for i in range(P)]

    def Top(a: MMap, b: MMap, c_views):
        ai = [[channel(2, f"ai{i}_{j}") for j in range(P)] for i in range(P)]
        bi = [[channel(2, f"bi{i}_{j}") for j in range(P)] for i in range(P)]
        a_ch = [[channel(2, f"a{i}_{j}") for j in range(P)] for i in range(P)]
        b_ch = [[channel(2, f"b{i}_{j}") for j in range(P)] for i in range(P)]
        c_ch = [[channel(1, f"c{i}_{j}") for j in range(P)] for i in range(P)]
        t = task()
        for i in range(P):
            t = t.invoke(ADistrib, a, ai[i], i, n, P, name=f"ADistrib{i}")
            t = t.invoke(BDistrib, b, [bi[r][i] for r in range(P)], i, n, P,
                         name=f"BDistrib{i}")
        for i in range(P):
            for j in range(P):
                t = t.invoke(
                    PE, ai[i][j], bi[i][j],
                    a_ch[i][j], b_ch[i][j],
                    a_ch[i][(j - 1) % P],      # forward A left
                    b_ch[(i - 1) % P][j],      # forward B up
                    c_ch[i][j], P, name=f"PE{i}_{j}")
        for i in range(P):
            t = t.invoke(Collector, c_views[i], c_ch[i], i, n,
                         name=f"Collector{i}")

    def check():
        ref = A @ B
        err = float(np.max(np.abs(C - ref)))
        return err < 1e-3 * P * n, err

    return Top, (a_mm, b_mm, c_rows), check


def run(engine: str = "coroutine", P: int = 4, n: int = 8,
        seed: int = 0) -> AppResult:
    top, args, check = build(P=P, n=n, seed=seed)
    return simulate("cannon", top, args, engine, check)
