"""The paper's benchmark suite (Table 3) as TAPA task graphs.

| app       | paper graph                      | feedback? | exercises      |
|-----------|----------------------------------|-----------|----------------|
| cannon    | 8x8 toroidal PE mesh             | YES       | C2 (seq fails) |
| cnn       | PolySA systolic conv layer       | no        | dedup (C3)     |
| gaussian  | SODA stencil dataflow pipeline   | no        | many instances |
| gcn       | edge-centric GCN layer           | no        | transactions   |
| gemm      | PolySA systolic matmul           | no        | dedup (C3)     |
| network   | 8x8 Omega switch                 | no        | peek (C1)      |
| page_rank | scatter/gather + control loop    | YES       | C2 (seq fails) |

Every app exposes ``run(engine=..., **size_overrides) -> AppResult`` which
simulates the graph and *numerically verifies* the result against a numpy
reference.  ``FEEDBACK_APPS`` lists the two the paper documents as failing
under sequential simulation.
"""

from . import cannon, cnn, gaussian, gcn, gemm, network, page_rank
from .base import AppResult

APPS = {
    "cannon": cannon,
    "cnn": cnn,
    "gaussian": gaussian,
    "gcn": gcn,
    "gemm": gemm,
    "network": network,
    "page_rank": page_rank,
}

FEEDBACK_APPS = ("cannon", "page_rank")

__all__ = ["APPS", "FEEDBACK_APPS", "AppResult", "cannon", "cnn", "gaussian",
           "gcn", "gemm", "network", "page_rank"]
