"""Systolic convolution layer (PolySA CNN, paper Section 4.1).

One VGG-style conv layer lowered to an output-stationary systolic matmul
over the im2col matrix: weight tiles stream down columns, input-patch
tiles stream across rows, each PE accumulates one (out-channel tile x
pixel tile) block of the output feature map.  Feed-forward DAG like gemm;
the knobs (i, o, h, w, p, q) default to a scaled-down VGG conv3 so the
simulation stays in milliseconds — paper-scale dims are a parameter, not a
code change.
"""

from __future__ import annotations

import numpy as np

from ..core import channel, task
from .base import AppResult, simulate


def build(ci: int = 8, co: int = 8, hw: int = 6, k: int = 3,
          P: int = 2, seed: int = 0):
    """conv(ci -> co, k x k, 'same') on a hw x hw image, PxP PE array."""
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((ci, hw, hw)).astype(np.float32)
    wgt = (rng.standard_normal((co, ci, k, k)) / np.sqrt(ci * k * k)) \
        .astype(np.float32)

    # im2col: X [ci*k*k, hw*hw]; W [co, ci*k*k]; out = W @ X
    pad = k // 2
    xpad = np.pad(img, ((0, 0), (pad, pad), (pad, pad)))
    cols = np.stack([
        xpad[:, dy:dy + hw, dx:dx + hw].reshape(ci, -1)
        for dy in range(k) for dx in range(k)], axis=1)
    X = cols.reshape(ci * k * k, hw * hw)
    W = wgt.reshape(co, ci * k * k)
    OUT = np.zeros((co, hw * hw), np.float32)

    ko = co // P                       # out-channel tile per PE row
    kp = (hw * hw) // P                # pixel tile per PE column
    red = ci * k * k                   # reduction length

    def WFeeder(out, i: int):
        out.write(W[i * ko:(i + 1) * ko].copy())
        out.close()

    def XFeeder(out, j: int):
        out.write(X[:, j * kp:(j + 1) * kp].copy())
        out.close()

    def PE(w_in, x_in, w_out, x_out, c_out):
        acc = None
        while not w_in.eot():
            wt = w_in.read()
            xt = x_in.read()
            acc = wt @ xt if acc is None else acc + wt @ xt
            if w_out is not None:
                w_out.write(wt)
            if x_out is not None:
                x_out.write(xt)
        w_in.open()
        x_in.open()
        if w_out is not None:
            w_out.close()
        if x_out is not None:
            x_out.close()
        c_out.write(acc)

    def Collector(c_ins, i: int):
        for j, ch in enumerate(c_ins):
            OUT[i * ko:(i + 1) * ko, j * kp:(j + 1) * kp] = ch.read()

    def Top():
        w_ch = [[channel(2, f"w{i}_{j}") for j in range(P)] for i in range(P)]
        x_ch = [[channel(2, f"x{i}_{j}") for j in range(P)] for i in range(P)]
        c_ch = [[channel(1, f"c{i}_{j}") for j in range(P)] for i in range(P)]
        t = task()
        for i in range(P):
            t = t.invoke(WFeeder, w_ch[i][0], i, name=f"WFeeder{i}")
            t = t.invoke(XFeeder, x_ch[0][i], i, name=f"XFeeder{i}")
        for i in range(P):
            for j in range(P):
                t = t.invoke(
                    PE, w_ch[i][j], x_ch[i][j],
                    w_ch[i][j + 1] if j + 1 < P else None,
                    x_ch[i + 1][j] if i + 1 < P else None,
                    c_ch[i][j], name=f"PE{i}_{j}")
        for i in range(P):
            t = t.invoke(Collector, c_ch[i], i, name=f"Collector{i}")

    def check():
        ref = W @ X
        err = float(np.max(np.abs(OUT - ref)))
        return err < 1e-3 * red, err

    return Top, (), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("cnn", top, args, engine, check)


def jax_stages(ci: int = 8, co: int = 8, hw: int = 6, k: int = 3,
               P: int = 2, seed: int = 0):
    """The systolic conv as JAX stages: P*P instances of one PE definition
    (tile matmul, weight/patch tiles bound per instance) feeding one
    assembler sink.  All stages are arg-bound — ``source_indices=[]`` —
    so the program is called with no graph inputs; hierarchical codegen
    compiles the PE definition once for all P*P instances."""
    import jax
    import jax.numpy as jnp

    from ..core.hier_compile import StageInstance

    rng = np.random.default_rng(seed)
    wgt = (rng.standard_normal((co, ci, k, k)) / np.sqrt(ci * k * k)) \
        .astype(np.float32)
    img = rng.standard_normal((ci, hw, hw)).astype(np.float32)
    pad = k // 2
    xpad = np.pad(img, ((0, 0), (pad, pad), (pad, pad)))
    cols = np.stack([
        xpad[:, dy:dy + hw, dx:dx + hw].reshape(ci, -1)
        for dy in range(k) for dx in range(k)], axis=1)
    X = cols.reshape(ci * k * k, hw * hw)
    W = wgt.reshape(co, ci * k * k)
    ko, kp = co // P, (hw * hw) // P

    def pe(w_tile, x_tile):
        return jnp.asarray(w_tile) @ jnp.asarray(x_tile)

    def assemble(*tiles):
        rows = [jnp.concatenate(tiles[i * P:(i + 1) * P], axis=1)
                for i in range(P)]
        return jnp.concatenate(rows, axis=0)

    insts = [StageInstance(
        fn=pe, args=(W[i * ko:(i + 1) * ko].copy(),
                     X[:, j * kp:(j + 1) * kp].copy()),
        name=f"PE{i}_{j}")
        for i in range(P) for j in range(P)]
    tile_aval = jax.ShapeDtypeStruct((ko, kp), jnp.float32)
    insts.append(StageInstance(fn=assemble, args=(tile_aval,) * (P * P),
                               name="Assemble"))
    wiring = {len(insts) - 1: list(range(P * P))}
    ref = W @ X
    return insts, wiring, ref


def compile_app(ci: int = 8, co: int = 8, hw: int = 6, k: int = 3,
                P: int = 2, *, cache=None, prev=None):
    """Hierarchically compile the systolic conv through the compile cache
    and return ``(report, program, ref)``."""
    from ..core.hier_compile import build_dataflow, compile_stages

    insts, wiring, ref = jax_stages(ci=ci, co=co, hw=hw, k=k, P=P)
    report = compile_stages(insts, mode="hierarchical", cache=cache,
                            prev=prev)
    program = build_dataflow(insts, wiring, source_indices=[])
    return report, program, ref
