"""8x8 Omega network switch (paper Section 4.1).

log2(8) = 3 stages of four 2x2 switch elements route 64-bit packets whose
top 3 bits are the destination port.  The 2x2 element is the paper's
showcase for **peek** (Section 2.3 / Listing 1's pattern): the element
looks at the head packet of each input to decide routing *without
consuming it*, because the packet can only be consumed once the chosen
output has space — peek + try_write replaces the manual claim/buffer state
machine the paper shows in red.
"""

from __future__ import annotations

import numpy as np

from ..core import OStream, StepTask, channel, select, task
from .base import AppResult, simulate

PORTS = 8
STAGES = 3


def _inv_shuffle(p: int) -> int:
    """Which wire feeds switch-input position ``p`` after the perfect
    shuffle (wire w lands on position rotate-left(w), so position p is fed
    by rotate-right(p))."""
    return ((p >> 1) | ((p & 1) << (STAGES - 1))) & (PORTS - 1)


def build(n_packets: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    # packet = (dst << 61) | payload  — modeled as a (dst, payload) tuple
    dsts = rng.integers(0, PORTS, n_packets)
    payloads = rng.integers(0, 1 << 32, n_packets)
    received: dict[int, list] = {p: [] for p in range(PORTS)}

    def Source(outs):
        for d, pl in zip(dsts, payloads):
            outs[int(rng.integers(0, PORTS))].write((int(d), int(pl)))
        for o in outs:
            o.close()

    def Switch2x2(in0, in1, out0, out1, stage: int):
        """Route by bit (STAGES-1-stage) of dst.  Peek first; consume only
        once the destination output accepts the packet — the paper's
        Listing-1 pattern (no manual claim/buffer state machine).  When
        neither input can progress, ``select`` parks the task until *any*
        watched port changes (hardware ready/valid polling)."""
        bit = STAGES - 1 - stage
        open_in = [False, False]
        ins = [in0, in1]
        outs = [out0, out1]
        while not all(open_in):
            progress = False
            blockers = []       # the ports whose state change can unblock us
            for s in (0, 1):
                if open_in[s]:
                    continue
                ok, is_eot = ins[s].try_eot()
                if ok and is_eot:
                    ins[s].open()
                    open_in[s] = True
                    progress = True
                    continue
                ok, head = ins[s].try_peek()
                if not ok:
                    blockers.append(ins[s])          # waiting for a packet
                    continue
                port = (head[0] >> bit) & 1
                if outs[port].try_write(head):       # output has space?
                    ins[s].read()                    # now consume
                    progress = True
                    # opportunistic burst drain: forward the run of
                    # consecutive same-destination packets in one batch
                    # (peek each, stop at the first routed elsewhere)
                    while True:
                        ok, nxt = ins[s].try_peek()
                        if not ok or ((nxt[0] >> bit) & 1) != port:
                            break
                        if not outs[port].try_write(nxt):
                            break
                        ins[s].read()
                else:
                    blockers.append(outs[port])      # waiting for space
            if not progress and blockers:
                select(*blockers)
        out0.close()
        out1.close()

    def Sink(inp, port: int):
        received[port].extend(inp.read_transaction())

    def Top():
        # stage wiring: lines[s][i] carries packets entering stage s on
        # wire i (after the perfect-shuffle permutation)
        lines = [[channel(4, f"l{s}_{i}") for i in range(PORTS)]
                 for s in range(STAGES + 1)]
        t = task().invoke(Source, lines[0])
        for s in range(STAGES):
            for e in range(PORTS // 2):      # four 2x2 elements
                i0 = _inv_shuffle(2 * e)
                i1 = _inv_shuffle(2 * e + 1)
                t = t.invoke(Switch2x2, lines[s][i0], lines[s][i1],
                             lines[s + 1][2 * e], lines[s + 1][2 * e + 1],
                             s, name=f"SW{s}_{e}")
        for p in range(PORTS):
            t = t.invoke(Sink, lines[STAGES][p], p, name=f"Sink{p}")

    def check():
        total = sum(len(v) for v in received.values())
        if total != n_packets:
            return False, float(n_packets - total)
        bad = sum(1 for p, v in received.items()
                  for (d, _) in v if d != p)
        return bad == 0, float(bad)

    return Top, (), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("network", top, args, engine, check)


# ---------------------------------------------------------------------------
# step-function form — the documented *refusal* case (docs/synthesis.md)
# ---------------------------------------------------------------------------

def build_step(n_packets: int = 64, seed: int = 0):
    """The Omega network with its injectors migrated to step-function
    form: each input line gets a LineSource with a build-time packet
    schedule (static firing count), closing its line after the last
    firing so the downstream free-form switches still see EoT.

    The 2x2 switch element itself **cannot** be a fixed-rate step task:
    it routes by peeking the head packet and forwards only when the
    *availability-chosen* output has space — the paper's beyond-KPN
    ``peek``/``select`` extension (Section 2.3).  Whole-graph synthesis
    therefore refuses this graph with a diagnostic naming the switch;
    it remains fully simulatable on every engine — exactly the
    sim-vs-synth boundary ``docs/synthesis.md`` documents.
    """
    rng = np.random.default_rng(seed)
    dsts = rng.integers(0, PORTS, n_packets)
    payloads = rng.integers(0, 1 << 32, n_packets)
    lines = rng.integers(0, PORTS, n_packets)
    per_line = [[(int(d), int(pl))
                 for d, pl, ln in zip(dsts, payloads, lines) if ln == p]
                for p in range(PORTS)]
    received: dict[int, list] = {p: [] for p in range(PORTS)}

    def make_line_source(p: int) -> StepTask:
        pkts = per_line[p]

        def line_source_step(k, out: OStream):
            out.write(pkts[int(k)])
            return k + 1

        return StepTask(line_source_step, steps=len(pkts), init=0,
                        close_outputs=True, name=f"LineSource{p}")

    line_sources = [make_line_source(p) for p in range(PORTS)]

    def Switch2x2(in0, in1, out0, out1, stage: int):
        bit = STAGES - 1 - stage
        open_in = [False, False]
        ins = [in0, in1]
        outs = [out0, out1]
        while not all(open_in):
            progress = False
            blockers = []
            for s in (0, 1):
                if open_in[s]:
                    continue
                ok, is_eot = ins[s].try_eot()
                if ok and is_eot:
                    ins[s].open()
                    open_in[s] = True
                    progress = True
                    continue
                ok, head = ins[s].try_peek()
                if not ok:
                    blockers.append(ins[s])
                    continue
                port = (head[0] >> bit) & 1
                if outs[port].try_write(head):
                    ins[s].read()
                    progress = True
                else:
                    blockers.append(outs[port])
            if not progress and blockers:
                select(*blockers)
        out0.close()
        out1.close()

    def Sink(inp, port: int):
        received[port].extend(inp.read_transaction())

    def Top():
        lines_ch = [[channel(4, f"l{s}_{i}") for i in range(PORTS)]
                    for s in range(STAGES + 1)]
        t = task()
        for p in range(PORTS):
            t = t.invoke(line_sources[p], lines_ch[0][p],
                         name=f"LineSource{p}")
        for s in range(STAGES):
            for e in range(PORTS // 2):
                i0 = _inv_shuffle(2 * e)
                i1 = _inv_shuffle(2 * e + 1)
                t = t.invoke(Switch2x2, lines_ch[s][i0], lines_ch[s][i1],
                             lines_ch[s + 1][2 * e],
                             lines_ch[s + 1][2 * e + 1],
                             s, name=f"SW{s}_{e}")
        for p in range(PORTS):
            t = t.invoke(Sink, lines_ch[STAGES][p], p, name=f"Sink{p}")

    def check():
        total = sum(len(v) for v in received.values())
        if total != n_packets:
            return False, float(n_packets - total)
        bad = sum(1 for p, v in received.items()
                  for (d, _) in v if d != p)
        return bad == 0, float(bad)

    return Top, (), check


def run_step(engine: str = "coroutine", **kw) -> AppResult:
    """Run the step-form graph; ``engine="compiled"`` refuses it with a
    diagnostic naming the availability-routed switch."""
    top, args, check = build_step(**kw)
    return simulate("network_step", top, args, engine, check)
