"""Edge-centric GCN forward layer (paper Section 4.1).

One graph-convolution layer  H' = ReLU(Ã H W)  on a synthetic Cora-like
graph, decomposed the way the paper's accelerator is: an EdgeStream task
reads the (src, dst) list, a Gather task accumulates degree-normalized
neighbour features per destination vertex, a Dense task applies the weight
matrix, and a Sink collects rows.  Vertex feature vectors cross channels as
whole tokens; the per-partition update streams are EoT-delimited
transactions (the UpdateHandler pattern from the paper's Listing 2).
"""

from __future__ import annotations

import numpy as np

from ..core import channel, task
from .base import AppResult, simulate


def build(n_vertices: int = 64, n_edges: int = 256, fin: int = 16,
          fout: int = 8, n_parts: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    H = rng.standard_normal((n_vertices, fin)).astype(np.float32)
    W = (rng.standard_normal((fin, fout)) / np.sqrt(fin)).astype(np.float32)
    # symmetric-normalized adjacency with self loops (GCN, Kipf&Welling)
    deg = np.bincount(dst, minlength=n_vertices) + 1.0
    OUT = np.zeros((n_vertices, fout), np.float32)

    part = n_vertices // n_parts

    def EdgeStream(outs):
        """Scatter phase: route each edge's message to its dst partition;
        one transaction per partition round."""
        for e in range(n_edges):
            p = int(dst[e]) // part
            outs[min(p, n_parts - 1)].write((int(dst[e]), int(src[e])))
        for o in outs:
            o.close()

    def Gather(inp, out, p: int):
        """Gather phase: accumulate normalized neighbour features for this
        partition's vertices, then stream the aggregate rows."""
        lo = p * part
        hi = n_vertices if p == n_parts - 1 else lo + part
        acc = H[lo:hi].copy()                      # self loop
        for (d, s) in inp:
            acc[d - lo] += H[s]
        acc /= deg[lo:hi, None]
        for i in range(hi - lo):
            out.write((lo + i, acc[i]))
        out.close()

    def Dense(inp, out):
        for (v, row) in inp:
            out.write((v, np.maximum(row @ W, 0.0)))
        out.close()

    def Sink(ins):
        for ch in ins:
            for (v, row) in ch:
                OUT[v] = row

    def Top():
        e_ch = [channel(8, f"edges{p}") for p in range(n_parts)]
        g_ch = [channel(8, f"agg{p}") for p in range(n_parts)]
        d_ch = [channel(8, f"dense{p}") for p in range(n_parts)]
        t = task().invoke(EdgeStream, e_ch)
        for p in range(n_parts):
            t = t.invoke(Gather, e_ch[p], g_ch[p], p, name=f"Gather{p}")
            t = t.invoke(Dense, g_ch[p], d_ch[p], name=f"Dense{p}")
        t.invoke(Sink, d_ch)

    def check():
        A = np.zeros((n_vertices, n_vertices), np.float32)
        A[dst, src] = 0.0                      # build unnormalized adj
        for s, d in zip(src, dst):
            A[d, s] += 1.0
        A += np.eye(n_vertices, dtype=np.float32)
        ref = np.maximum((A / deg[:, None]) @ H @ W, 0.0)
        err = float(np.max(np.abs(OUT - ref)))
        return err < 1e-3, err

    return Top, (), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("gcn", top, args, engine, check)


def jax_stages(n_vertices: int = 64, n_edges: int = 256, fin: int = 16,
               fout: int = 8, n_parts: int = 4, seed: int = 0):
    """The GCN layer as JAX stages: per-partition Gather and Dense
    instances (one definition each) plus a concatenating sink — the same
    decomposition the streaming version simulates, lowered to XLA with the
    adjacency slice bound per Gather instance."""
    import jax
    import jax.numpy as jnp

    from ..core.hier_compile import StageInstance

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    H = rng.standard_normal((n_vertices, fin)).astype(np.float32)
    W = (rng.standard_normal((fin, fout)) / np.sqrt(fin)).astype(np.float32)
    deg = np.bincount(dst, minlength=n_vertices) + 1.0
    A = np.zeros((n_vertices, n_vertices), np.float32)
    for s, d in zip(src, dst):
        A[d, s] += 1.0
    A += np.eye(n_vertices, dtype=np.float32)
    A /= deg[:, None].astype(np.float32)
    part = n_vertices // n_parts

    def gather(a_rows, feats):
        return jnp.asarray(a_rows) @ jnp.asarray(feats)

    def dense(agg, w):
        return jnp.maximum(jnp.asarray(agg) @ jnp.asarray(w), 0.0)

    def concat(*rows):
        return jnp.concatenate(rows, axis=0)

    bounds = [(p * part,
               n_vertices if p == n_parts - 1 else (p + 1) * part)
              for p in range(n_parts)]
    insts = [StageInstance(fn=gather, args=(A[lo:hi].copy(), H),
                           name=f"Gather{p}")
             for p, (lo, hi) in enumerate(bounds)]
    agg_avals = [jax.ShapeDtypeStruct((hi - lo, fin), jnp.float32)
                 for lo, hi in bounds]
    insts += [StageInstance(fn=dense, args=(agg_avals[p], W),
                            name=f"Dense{p}")
              for p in range(n_parts)]
    out_avals = [jax.ShapeDtypeStruct((hi - lo, fout), jnp.float32)
                 for lo, hi in bounds]
    insts.append(StageInstance(fn=concat, args=tuple(out_avals),
                               name="Concat"))
    wiring = {n_parts + p: [p] for p in range(n_parts)}
    wiring[2 * n_parts] = [n_parts + p for p in range(n_parts)]
    ref = np.maximum(A @ H @ W, 0.0)
    return insts, wiring, ref


def compile_app(n_vertices: int = 64, n_parts: int = 4, *, cache=None,
                prev=None, **kw):
    """Hierarchically compile the GCN layer through the compile cache and
    return ``(report, program, ref)``."""
    from ..core.hier_compile import build_dataflow, compile_stages

    insts, wiring, ref = jax_stages(n_vertices=n_vertices,
                                    n_parts=n_parts, **kw)
    report = compile_stages(insts, mode="hierarchical", cache=cache,
                            prev=prev)
    program = build_dataflow(insts, wiring, source_indices=[])
    return report, program, ref
