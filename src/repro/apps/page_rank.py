"""PageRank accelerator (the paper's motivating example, Sections 2.3/4.1).

Edge-centric scatter/gather with the exact task roles of the paper's
Figure 3: a Ctrl task coordinates iterations, a VertexHandler serves
vertex-rank requests (detached, infinite-loop — the ``tapa::detach``
use-case), ComputeUnits scatter weighted rank updates along edges, and
UpdateHandlers accumulate them per destination partition using the
*peek-to-detect-partition-conflict* idiom of Listing 1 and EoT-delimited
update transactions of Listing 2.

Interface migration: the rank and out-degree vectors live behind ``mmap``
arguments served by the VertexHandlers (only the handler on Ctrl's
channel ever stores — the runtime-observed one-writer rule), and each
ComputeUnit fetches its edge list through an ``async_mmap`` port: edge
addresses are issued ahead of the returning data (``read_pipelined``), so
with outstanding depth > 1 the fetch round-trips overlap — visible as
``max_outstanding_reads`` in the per-interface sim stats.

The Ctrl <-> VertexHandler request/response pair is a feedback loop in the
dataflow graph, so — like cannon — the sequential engine must fail on this
benchmark (Fig. 7), while thread/coroutine engines converge to the same
ranks as the numpy power iteration.
"""

from __future__ import annotations

import numpy as np

from ..core import (AsyncMMap, MMap, StepTask, async_mmap, channel, mmap,
                    task)
from .base import AppResult, simulate

DAMPING = 0.85


def VertexHandler(ranks: MMap, out_deg: MMap, req, resp):
    """Serve rank reads and apply rank writes; never terminates
    (invoked with detach=True, paper Listing 5)."""
    while True:
        kind, payload = req.read()
        if kind == "read":
            resp.write(ranks[payload] / out_deg[payload])
        else:                       # ("write", (vertex, value))
            v, val = payload
            ranks[v] = val


def ComputeUnit(edges: AsyncMMap, ctrl_in, upd_out, vreq, vresp):
    """Scatter phase for one partition: one update transaction per
    iteration.  Edge fetches go through the async memory port with the
    addresses pipelined ahead of the data (request/response overlap);
    vertex lookups are pipelined in bursts bounded by the response
    channel's capacity, so the handler round-trip cost is amortized
    across each batch."""
    n_edges = len(edges)
    burst = vresp.channel.capacity
    while True:
        go = ctrl_in.read()
        if go is None:              # shutdown
            break
        for base in range(0, n_edges, burst):
            hi = min(base + burst, n_edges)
            chunk = edges.read_pipelined(range(base, hi))
            vreq.write_burst([("read", int(s)) for s, _ in chunk])
            ws = vresp.read_burst(len(chunk))
            upd_out.write_burst([(int(d), w)
                                 for (_, d), w in zip(chunk, ws)])
        upd_out.close()             # end of this iteration's transaction


def UpdateHandler(upd_in, commit_out, p: int, part: int, n_vertices: int):
    """Gather phase: accumulate one iteration's update transaction
    (EoT-delimited, Listing 2) in a local register file, then report
    the partition's aggregate to Ctrl for commit."""
    lo = p * part
    hi = min(lo + part, n_vertices)
    while True:
        acc = np.zeros(hi - lo, np.float64)
        for d, w in upd_in.read_transaction():
            acc[d - lo] += w        # register accumulate (Listing 1)
        commit_out.write((p, acc))


def Ctrl(cu_outs, commit_ins, vreq, vresp, n_iters: int, part: int,
         n_vertices: int):
    for it in range(n_iters):
        for o in cu_outs:
            o.write(True)           # start scatter on every PE
        # barrier: collect EVERY partition's commit before writing any
        # rank back — scatter must see a consistent iteration-i view
        commits = [ci.read() for ci in commit_ins]
        for p, acc in commits:
            lo = p * part
            # rank write-back is fire-and-forget: a single burst moves
            # the whole partition (chunked by channel capacity)
            vreq.write_burst(
                [("write",
                  (lo + i, (1 - DAMPING) / n_vertices + DAMPING * val))
                 for i, val in enumerate(acc)])
        # read-as-fence: the handler serves FIFO, so a round-trip read
        # proves every prior write of this iteration has been applied
        # before the next iteration's scatter starts
        vreq.write(("read", 0))
        vresp.read()
    for o in cu_outs:
        o.write(None)               # shutdown compute units


def build(n_vertices: int = 32, n_edges: int = 128, n_pe: int = 2,
          n_iters: int = 5, seed: int = 0, edge_latency: int = 4,
          edge_depth: int = 4):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int64)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int64)
    out_deg = np.maximum(np.bincount(src, minlength=n_vertices), 1)

    ranks = np.full(n_vertices, 1.0 / n_vertices, np.float64)
    part = (n_vertices + n_pe - 1) // n_pe
    # edges assigned to PEs by destination partition (gather locality),
    # each partition's (src, dst) rows behind its own async memory port
    pe_edges = [np.array([(int(s), int(d)) for s, d in zip(src, dst)
                          if d // part == p], np.int64).reshape(-1, 2)
                for p in range(n_pe)]

    ranks_mm = mmap(ranks, "ranks")
    deg_mm = mmap(out_deg, "out_deg")
    edge_ports = [async_mmap(pe_edges[p], latency=edge_latency,
                             depth=edge_depth, name=f"edges{p}")
                  for p in range(n_pe)]

    def Top(rk: MMap, deg: MMap, eports):
        vreq = channel(8, "vertex_req")
        vresp = channel(8, "vertex_resp")
        cu_go = [channel(2, f"go{p}") for p in range(n_pe)]
        upd = [channel(16, f"updates{p}") for p in range(n_pe)]
        commit = [channel(2, f"commit{p}") for p in range(n_pe)]
        # per-CU private request channels would shard the handler; the
        # paper's design muxes through one handler — we serialize CU reads
        # through per-CU req/resp pairs served by dedicated handlers to
        # honor one-producer/one-consumer.
        cu_vreq = [channel(8, f"cu_vreq{p}") for p in range(n_pe)]
        cu_vresp = [channel(8, f"cu_vresp{p}") for p in range(n_pe)]

        t = task()
        t = t.invoke(VertexHandler, rk, deg, vreq, vresp, detach=True)
        for p in range(n_pe):
            t = t.invoke(VertexHandler, rk, deg, cu_vreq[p], cu_vresp[p],
                         detach=True, name=f"VertexHandler{p}")
            t = t.invoke(ComputeUnit, eports[p], cu_go[p], upd[p],
                         cu_vreq[p], cu_vresp[p], name=f"ComputeUnit{p}")
            t = t.invoke(UpdateHandler, upd[p], commit[p], p, part,
                         n_vertices, name=f"UpdateHandler{p}", detach=True)
        t.invoke(Ctrl, cu_go, commit, vreq, vresp, n_iters, part,
                 n_vertices)

    def check():
        ref = np.full(n_vertices, 1.0 / n_vertices, np.float64)
        for _ in range(n_iters):
            contrib = np.zeros(n_vertices, np.float64)
            np.add.at(contrib, dst, ref[src] / out_deg[src])
            ref = (1 - DAMPING) / n_vertices + DAMPING * contrib
        err = float(np.max(np.abs(ranks - ref)))
        return err < 1e-9, err

    return Top, (ranks_mm, deg_mm, edge_ports), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("page_rank", top, args, engine, check)


# ---------------------------------------------------------------------------
# step-function form (whole-graph synthesis, docs/synthesis.md)
# ---------------------------------------------------------------------------

def build_step(n_vertices: int = 32, n_edges: int = 128, n_pe: int = 2,
               n_iters: int = 5, seed: int = 0):
    """PageRank in traceable step-function form — the mmap-fed **feedback
    loop** case: Ctrl broadcasts the rank vector to the scatter PEs each
    iteration and reads their contributions back, so the dataflow graph
    has a cycle (which the sequential engine must fail on, paper Fig. 7)
    that the whole-graph ``lax.while_loop`` executes natively.

    Each PE's edge list and the shared out-degree vector live behind
    read-only mmaps; the initial ranks enter through an mmap and the
    converged ranks leave through one (arrays stay float32: jax's
    canonical dtype, so the twin and the compiled program agree bit for
    bit).  Tokens are whole rank/contribution vectors — one token per PE
    per iteration.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    out_deg = np.maximum(np.bincount(src, minlength=n_vertices), 1)

    part = (n_vertices + n_pe - 1) // n_pe
    pe_edges = [np.array([(int(s), int(d)) for s, d in zip(src, dst)
                          if d // part == p], np.int32).reshape(-1, 2)
                for p in range(n_pe)]

    # Build-time gather plan per PE: pad each vertex's incoming-edge list
    # to the partition's max in-degree; slot (v, k) holds the edge index
    # whose weight feeds vertex v (or the one-past-the-end sentinel, a
    # zero weight).  The per-firing accumulation is then an *unrolled
    # fixed-order* chain of elementwise adds — bit-stable under any XLA
    # fusion, unlike scatter-add, whose duplicate-index order is
    # compilation-dependent (and would break sim-vs-synth bit parity).
    def _gather_plan(e):
        by_v: dict[int, list] = {}
        for k, (_, d) in enumerate(e):
            by_v.setdefault(int(d), []).append(k)
        width = max((len(v) for v in by_v.values()), default=1)
        idx = np.full((n_vertices, width), len(e), np.int32)   # sentinel
        for v, ks in by_v.items():
            idx[v, :len(ks)] = ks
        return idx

    gather_plans = [_gather_plan(pe_edges[p]) for p in range(n_pe)]

    r0 = np.full(n_vertices, 1.0 / n_vertices, np.float32)
    ranks = np.zeros(n_vertices, np.float32)

    r0_mm = mmap(r0, "ranks0")
    out_mm = mmap(ranks, "ranks")
    deg_mm = mmap(out_deg.astype(np.float32), "out_deg")
    edge_mms = [mmap(pe_edges[p], f"edges{p}") for p in range(n_pe)]
    plan_mms = [mmap(gather_plans[p], f"gather{p}") for p in range(n_pe)]

    def scatter_step(state, edges: MMap, plan: MMap, deg: MMap, ranks_in,
                     upd_out):
        r = ranks_in.read()
        e = jnp.asarray(edges.read_burst(0, len(edges)))
        idx = jnp.asarray(plan.read_burst(0, n_vertices))
        degv = jnp.asarray(deg.read_burst(0, n_vertices))
        w = r[e[:, 0]] / degv[e[:, 0]]
        wext = jnp.concatenate([w, jnp.zeros(1, jnp.float32)])
        contrib = wext[idx[:, 0]]
        for k in range(1, idx.shape[1]):        # static, fixed-order sum
            contrib = contrib + wext[idx[:, k]]
        upd_out.write(contrib)
        return state

    # bit-parity contract (docs/synthesis.md): firing math that XLA may
    # FMA-contract goes through a jitted helper, so the twin executes the
    # same contracted kernel the whole-graph program inlines
    _mix = jax.jit(lambda total: ((1 - DAMPING) / n_vertices +
                                  DAMPING * total).astype(jnp.float32))

    def _combine(upd_ins):
        total = upd_ins[0].read()
        for ci in upd_ins[1:]:
            total = total + ci.read()
        return _mix(total)

    def ctrl_warmup(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = jnp.asarray(ranks0.read_burst(0, n_vertices))
        for o in rank_outs:
            o.write(r)
        return r

    def ctrl_step(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = _combine(upd_ins)
        for o in rank_outs:
            o.write(r)
        return r

    def ctrl_flush(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = _combine(upd_ins)
        out.write_burst(0, r)
        return r

    ScatterS = StepTask(scatter_step, steps=n_iters, name="Scatter")
    CtrlS = StepTask(ctrl_step, steps=n_iters - 1, warmup=ctrl_warmup,
                     flush=ctrl_flush,
                     init=jnp.zeros(n_vertices, jnp.float32), name="Ctrl")

    def Top(r0m: MMap, outm: MMap, degm: MMap, eports, plans):
        vec = dict(dtype=np.float32, shape=(n_vertices,))
        rank_ch = [channel(1, f"rank{p}", **vec) for p in range(n_pe)]
        upd_ch = [channel(1, f"upd{p}", **vec) for p in range(n_pe)]
        t = task()
        for p in range(n_pe):
            t = t.invoke(ScatterS, eports[p], plans[p], degm, rank_ch[p],
                         upd_ch[p], name=f"Scatter{p}")
        t.invoke(CtrlS, r0m, outm, rank_ch, upd_ch)

    def check():
        ref = np.full(n_vertices, 1.0 / n_vertices, np.float64)
        for _ in range(n_iters):
            contrib = np.zeros(n_vertices, np.float64)
            np.add.at(contrib, dst, ref[src] / out_deg[src])
            ref = (1 - DAMPING) / n_vertices + DAMPING * contrib
        err = float(np.max(np.abs(ranks - ref)))
        return err < 1e-5, err

    return Top, (r0_mm, out_mm, deg_mm, edge_mms, plan_mms), check


def run_step(engine: str = "coroutine", engine_kwargs: dict = None,
             **kw) -> AppResult:
    """Run the step-form graph — ``engine="compiled"`` synthesizes it;
    ``engine_kwargs={"mesh": N}`` floorplans it over N devices."""
    top, args, check = build_step(**kw)
    return simulate("page_rank_step", top, args, engine, check,
                    engine_kwargs=engine_kwargs)


def build_step_async(n_vertices: int = 32, n_edges: int = 128, n_pe: int = 2,
                     n_iters: int = 5, seed: int = 0, edge_latency: int = 4,
                     edge_depth: int = 4):
    """The step-form feedback loop with **async-fed edges**: each PE's edge
    list sits behind an ``async_mmap`` port and a per-PE EdgeFetcher task
    streams the rows in through the port's latency queue, issuing addresses
    up to ``edge_depth`` ahead of the returning data — the step-function
    twin of ``build``'s ``read_pipelined`` idiom, synthesizable by
    ``CompiledEngine`` (docs/synthesis.md, "kernel lowering").

    The fetcher is the canonical issue-ahead shape: a warmup phase primes
    ``depth`` requests, the steady phase retires one row and issues the
    next address per firing, and a flush phase drains the in-flight
    window.  Scatter then bursts the whole row batch out of an ordinary
    channel, so the rank feedback cycle of ``build_step`` is unchanged —
    one graph exercises both the cycle and the latency queue.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int32)
    out_deg = np.maximum(np.bincount(src, minlength=n_vertices), 1)

    part = (n_vertices + n_pe - 1) // n_pe
    pe_edges = [np.array([(int(s), int(d)) for s, d in zip(src, dst)
                          if d // part == p], np.int32).reshape(-1, 2)
                for p in range(n_pe)]
    for p, e in enumerate(pe_edges):
        assert len(e) > 0, \
            f"partition {p} has no edges; pick a denser graph or fewer PEs"

    def _gather_plan(e):
        by_v: dict[int, list] = {}
        for k, (_, d) in enumerate(e):
            by_v.setdefault(int(d), []).append(k)
        width = max((len(v) for v in by_v.values()), default=1)
        idx = np.full((n_vertices, width), len(e), np.int32)   # sentinel
        for v, ks in by_v.items():
            idx[v, :len(ks)] = ks
        return idx

    gather_plans = [_gather_plan(pe_edges[p]) for p in range(n_pe)]

    r0 = np.full(n_vertices, 1.0 / n_vertices, np.float32)
    ranks = np.zeros(n_vertices, np.float32)

    r0_mm = mmap(r0, "ranks0")
    out_mm = mmap(ranks, "ranks")
    deg_mm = mmap(out_deg.astype(np.float32), "out_deg")
    edge_ports = [async_mmap(pe_edges[p], latency=edge_latency,
                             depth=edge_depth, name=f"edges{p}")
                 for p in range(n_pe)]
    plan_mms = [mmap(gather_plans[p], f"gather{p}") for p in range(n_pe)]

    def _mk_fetcher(p: int, n_e: int):
        """Issue-ahead row fetcher: addresses cycle 0..n_e-1, n_iters
        sweeps of the table, with ``d`` requests in flight."""
        d = min(edge_depth, n_e)
        total = n_iters * n_e

        def warm(k, port, erows):
            port.read_addr.write(jnp.mod(k, n_e))
            return k + 1

        def step(k, port, erows):
            erows.write(port.read_data.read())
            port.read_addr.write(jnp.mod(k, n_e))
            return k + 1

        def flush(k, port, erows):
            erows.write(port.read_data.read())
            return k + 1

        return StepTask(step, steps=total - d, init=jnp.int32(0),
                        warmup=warm, n_warmup=d, flush=flush, n_flush=d,
                        name=f"EdgeFetch{p}")

    def scatter_step(state, plan: MMap, deg: MMap, erows, ranks_in,
                     upd_out, n_e: int):
        r = ranks_in.read()
        e = jnp.asarray(erows.read_burst(n_e))
        idx = jnp.asarray(plan.read_burst(0, n_vertices))
        degv = jnp.asarray(deg.read_burst(0, n_vertices))
        w = r[e[:, 0]] / degv[e[:, 0]]
        wext = jnp.concatenate([w, jnp.zeros(1, jnp.float32)])
        contrib = wext[idx[:, 0]]
        for k in range(1, idx.shape[1]):        # static, fixed-order sum
            contrib = contrib + wext[idx[:, k]]
        upd_out.write(contrib)
        return state

    _mix = jax.jit(lambda total: ((1 - DAMPING) / n_vertices +
                                  DAMPING * total).astype(jnp.float32))

    def _combine(upd_ins):
        total = upd_ins[0].read()
        for ci in upd_ins[1:]:
            total = total + ci.read()
        return _mix(total)

    def ctrl_warmup(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = jnp.asarray(ranks0.read_burst(0, n_vertices))
        for o in rank_outs:
            o.write(r)
        return r

    def ctrl_step(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = _combine(upd_ins)
        for o in rank_outs:
            o.write(r)
        return r

    def ctrl_flush(r, ranks0: MMap, out: MMap, rank_outs, upd_ins):
        r = _combine(upd_ins)
        out.write_burst(0, r)
        return r

    fetchers = [_mk_fetcher(p, len(pe_edges[p])) for p in range(n_pe)]
    ScatterS = StepTask(scatter_step, steps=n_iters, name="Scatter")
    CtrlS = StepTask(ctrl_step, steps=n_iters - 1, warmup=ctrl_warmup,
                     flush=ctrl_flush,
                     init=jnp.zeros(n_vertices, jnp.float32), name="Ctrl")

    def Top(r0m: MMap, outm: MMap, degm: MMap, eports, plans):
        vec = dict(dtype=np.float32, shape=(n_vertices,))
        rank_ch = [channel(1, f"rank{p}", **vec) for p in range(n_pe)]
        upd_ch = [channel(1, f"upd{p}", **vec) for p in range(n_pe)]
        t = task()
        for p in range(n_pe):
            n_e = len(pe_edges[p])
            erow = channel(n_e, f"erow{p}", dtype=np.int32, shape=(2,))
            t = t.invoke(fetchers[p], eports[p], erow)
            t = t.invoke(ScatterS, plans[p], degm, erow, rank_ch[p],
                         upd_ch[p], n_e, name=f"Scatter{p}")
        t.invoke(CtrlS, r0m, outm, rank_ch, upd_ch)

    def check():
        ref = np.full(n_vertices, 1.0 / n_vertices, np.float64)
        for _ in range(n_iters):
            contrib = np.zeros(n_vertices, np.float64)
            np.add.at(contrib, dst, ref[src] / out_deg[src])
            ref = (1 - DAMPING) / n_vertices + DAMPING * contrib
        err = float(np.max(np.abs(ranks - ref)))
        return err < 1e-5, err

    return Top, (r0_mm, out_mm, deg_mm, edge_ports, plan_mms), check


def run_step_async(engine: str = "coroutine", **kw) -> AppResult:
    """Run the async-fed step-form graph on any engine (incl. compiled)."""
    top, args, check = build_step_async(**kw)
    return simulate("page_rank_step_async", top, args, engine, check)
