"""PageRank accelerator (the paper's motivating example, Sections 2.3/4.1).

Edge-centric scatter/gather with the exact task roles of the paper's
Figure 3: a Ctrl task coordinates iterations, a VertexHandler serves
vertex-rank requests (detached, infinite-loop — the ``tapa::detach``
use-case), ComputeUnits scatter weighted rank updates along edges, and
UpdateHandlers accumulate them per destination partition using the
*peek-to-detect-partition-conflict* idiom of Listing 1 and EoT-delimited
update transactions of Listing 2.

The Ctrl <-> VertexHandler request/response pair is a feedback loop in the
dataflow graph, so — like cannon — the sequential engine must fail on this
benchmark (Fig. 7), while thread/coroutine engines converge to the same
ranks as the numpy power iteration.
"""

from __future__ import annotations

import numpy as np

from ..core import EOT, channel, task
from .base import AppResult, simulate

DAMPING = 0.85


def build(n_vertices: int = 32, n_edges: int = 128, n_pe: int = 2,
          n_iters: int = 5, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges).astype(np.int64)
    dst = rng.integers(0, n_vertices, n_edges).astype(np.int64)
    out_deg = np.maximum(np.bincount(src, minlength=n_vertices), 1)

    ranks = np.full(n_vertices, 1.0 / n_vertices, np.float64)
    part = (n_vertices + n_pe - 1) // n_pe
    # edges assigned to PEs by destination partition (gather locality)
    pe_edges = [[(int(s), int(d)) for s, d in zip(src, dst)
                 if d // part == p] for p in range(n_pe)]

    def VertexHandler(req, resp):
        """Serve rank reads and apply rank writes; never terminates
        (invoked with detach=True, paper Listing 5)."""
        while True:
            kind, payload = req.read()
            if kind == "read":
                resp.write(ranks[payload] / out_deg[payload])
            else:                       # ("write", (vertex, value))
                v, val = payload
                ranks[v] = val

    def ComputeUnit(ctrl_in, upd_out, vreq, vresp, p: int):
        """Scatter phase for partition p: one update transaction per
        iteration.  Vertex lookups are pipelined in bursts: up to
        ``resp-capacity`` read requests go out per batch, so the in-flight
        responses can never exceed the response channel and the handler
        round-trip cost is amortized across the batch."""
        edges = pe_edges[p]
        burst = vresp.channel.capacity
        while True:
            go = ctrl_in.read()
            if go is None:              # shutdown
                break
            for base in range(0, len(edges), burst):
                chunk = edges[base:base + burst]
                vreq.write_burst([("read", s) for s, _ in chunk])
                ws = vresp.read_burst(len(chunk))
                upd_out.write_burst([(d, w)
                                     for (_, d), w in zip(chunk, ws)])
            upd_out.close()             # end of this iteration's transaction

    def UpdateHandler(upd_in, commit_out, p: int):
        """Gather phase: accumulate one iteration's update transaction
        (EoT-delimited, Listing 2) in a local register file, then report
        the partition's aggregate to Ctrl for commit."""
        lo = p * part
        hi = min(lo + part, n_vertices)
        while True:
            acc = np.zeros(hi - lo, np.float64)
            for d, w in upd_in.read_transaction():
                acc[d - lo] += w        # register accumulate (Listing 1)
            commit_out.write((p, acc))

    def Ctrl(cu_outs, commit_ins, vreq, vresp):
        for it in range(n_iters):
            for o in cu_outs:
                o.write(True)           # start scatter on every PE
            # barrier: collect EVERY partition's commit before writing any
            # rank back — scatter must see a consistent iteration-i view
            commits = [ci.read() for ci in commit_ins]
            for p, acc in commits:
                lo = p * part
                # rank write-back is fire-and-forget: a single burst moves
                # the whole partition (chunked by channel capacity)
                vreq.write_burst(
                    [("write",
                      (lo + i, (1 - DAMPING) / n_vertices + DAMPING * val))
                     for i, val in enumerate(acc)])
            # read-as-fence: the handler serves FIFO, so a round-trip read
            # proves every prior write of this iteration has been applied
            # before the next iteration's scatter starts
            vreq.write(("read", 0))
            vresp.read()
        for o in cu_outs:
            o.write(None)               # shutdown compute units

    def Top():
        vreq = channel(8, "vertex_req")
        vresp = channel(8, "vertex_resp")
        cu_go = [channel(2, f"go{p}") for p in range(n_pe)]
        upd = [channel(16, f"updates{p}") for p in range(n_pe)]
        commit = [channel(2, f"commit{p}") for p in range(n_pe)]
        # per-CU private request channels would shard the handler; the
        # paper's design muxes through one handler — we serialize CU reads
        # through per-CU req/resp pairs served by dedicated handlers to
        # honor one-producer/one-consumer.
        cu_vreq = [channel(8, f"cu_vreq{p}") for p in range(n_pe)]
        cu_vresp = [channel(8, f"cu_vresp{p}") for p in range(n_pe)]

        t = task()
        t = t.invoke(VertexHandler, vreq, vresp, detach=True)
        for p in range(n_pe):
            t = t.invoke(VertexHandler, cu_vreq[p], cu_vresp[p],
                         detach=True, name=f"VertexHandler{p}")
            t = t.invoke(ComputeUnit, cu_go[p], upd[p], cu_vreq[p],
                         cu_vresp[p], p, name=f"ComputeUnit{p}")
            t = t.invoke(UpdateHandler, upd[p], commit[p], p,
                         name=f"UpdateHandler{p}", detach=True)
        t.invoke(Ctrl, cu_go, commit, vreq, vresp)

    def check():
        ref = np.full(n_vertices, 1.0 / n_vertices, np.float64)
        for _ in range(n_iters):
            contrib = np.zeros(n_vertices, np.float64)
            np.add.at(contrib, dst, ref[src] / out_deg[src])
            ref = (1 - DAMPING) / n_vertices + DAMPING * contrib
        err = float(np.max(np.abs(ranks - ref)))
        return err < 1e-9, err

    return Top, (), check


def run(engine: str = "coroutine", **kw) -> AppResult:
    top, args, check = build(**kw)
    return simulate("page_rank", top, args, engine, check)
