"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: each gradient leaf is quantized to int8
with a per-leaf fp32 scale before crossing the data-parallel axis, cutting
DP collective bytes 4x (bf16 grads) at the cost of quantization noise that
*error feedback* (Seide et al., 1-bit SGD; Karimireddy et al. EF-SGD)
re-injects on the next step, preserving convergence.

Inside pjit the quantize -> psum(int32) -> dequantize sequence makes the
all-reduce payload int8-width; XLA keeps the reduction in int32 to avoid
overflow (512 chips x 127 < 2^31 safe).  The local error accumulator is
sharded exactly like the gradient leaf, so the state adds no replicated
memory.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Params) -> Params:
    """Residual accumulator, one per gradient leaf (sharded like it)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Params, err: Params) -> tuple[Params, Params]:
    """Apply error feedback + int8 quantization locally.

    Returns (quantized_pairs, new_err).  The caller psums the int32 view of
    each quantized leaf across DP (XLA emits an int8-payload all-reduce when
    the dtype allows) and divides by the DP size.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = jax.tree.leaves(err)
    q_leaves, ne_leaves = [], []
    for g, e in zip(g_leaves, e_leaves):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        q_leaves.append((q, scale))
        ne_leaves.append(corrected - dequantize_int8(q, scale))
    # (q, scale) pairs ride as opaque leaves: consumers unpack them via the
    # is_leaf=(tuple of length 2) convention used by compressed_psum
    qs = _unflatten_pairs(treedef, q_leaves)
    ne = jax.tree.unflatten(treedef, ne_leaves)
    return qs, ne


def _unflatten_pairs(treedef, pairs: list) -> Params:
    """Unflatten with (q, scale) tuples kept as leaves (a plain unflatten
    would splice them in as subtrees)."""
    wrapped = treedef.unflatten(list(range(len(pairs))))
    return jax.tree.map(lambda i: pairs[i], wrapped)


def ef_compressed_mean(grads: Params, err: Params, axis: str) -> tuple:
    """Error-feedback int8 gradient mean across a mapped axis (shard_map).

    The quantization scale is shared across the group (a scalar pmax per
    leaf — negligible traffic), so the int8 payloads sum EXACTLY: the only
    error is each worker's own rounding, which error feedback re-injects
    next step.  Returns (mean_grads fp32, new_err).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-30), axis) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127)
        mean = jax.lax.psum(q.astype(jnp.int32), axis) \
            .astype(jnp.float32) * scale / n
        new_e = corrected - q * scale
        return mean, new_e

    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(g_leaves, e_leaves)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compressed_psum(qs: Params, axis: str) -> Params:
    """Mean-reduce quantized gradients across a mapped axis (shard_map
    context).  q is widened to int32 for the reduction; scales are averaged
    — equivalent to averaging the dequantized values when scales are equal
    and a bounded approximation otherwise (the error lands in the feedback
    accumulator either way)."""
    n = jax.lax.psum(1, axis)

    def one(pair):
        q, scale = pair
        tot = jax.lax.psum(q.astype(jnp.int32), axis)
        s = jax.lax.psum(scale, axis) / n
        return tot.astype(jnp.float32) * s / n

    return jax.tree.map(one, qs,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 2)


def compression_error(g: jax.Array) -> float:
    """Relative L2 error of one quantize/dequantize round trip (no EF)."""
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    gf = g.astype(jnp.float32)
    return float(jnp.linalg.norm(gf - back) /
                 jnp.maximum(jnp.linalg.norm(gf), 1e-30))
