"""Sharding rules: parameters, batches and decode caches onto the mesh.

Axes
----
``model``  tensor parallelism (Megatron-style: attention heads / FFN width /
           vocab; expert dim for MoE when it divides).
``data``   data parallelism; also hosts FSDP-style parameter sharding for
           very large models and sequence sharding for B=1 long-context.
``pod``    (multi-pod only) an outer data-parallel axis by default; the
           pipeline schedule may claim it instead (distributed/pipeline.py).

Rules are *path-pattern based* over the parameter pytree so the same table
covers every architecture family.  Divisibility is checked against the real
mesh axis sizes; a rule that does not divide falls back to the next
candidate (or replication), so e.g. grok-1's 8 experts simply don't shard
over a 16-way model axis — its FFN width does instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import lm
from ..models.config import ModelConfig

Params = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    dp_axes: tuple = ("data",)            # ("pod","data") for multi-pod
    fsdp: bool = False                    # shard big params over dp too
    fsdp_min_elems: int = 4_000_000
    seq_axis: Optional[str] = None        # SP for B=1 long-context caches
    two_d: bool = False                   # weights sharded over dp+tp and
                                          # kept RESIDENT (serving: no
                                          # per-step weight all-gather, the
                                          # anti-FSDP for decode)
    batch_axes: Optional[tuple] = None    # override activation batch axes
                                          # (two_d serving replicates the
                                          # small decode batch instead of
                                          # fighting the weights for 'data')

    @property
    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def batch_spec_axes(self):
        ax = self.batch_axes if self.batch_axes is not None else self.dp_axes
        if not ax:
            return None
        return ax if len(ax) > 1 else ax[0]

    @property
    def wide_axis(self):
        """The dp+tp combined axis used by two_d weight sharding."""
        return tuple(self.dp_axes) + (self.tp_axis,)


def for_mesh(mesh: Mesh, fsdp: bool = False) -> ShardingPolicy:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardingPolicy(dp_axes=dp_axes, fsdp=fsdp)


def device_mesh(n_devices: Optional[int] = None, axis: str = "dev",
                devices: Optional[list] = None) -> Mesh:
    """A 1-D mesh over the first ``n_devices`` visible devices — the
    floorplanner's device axis (``CompiledEngine(mesh=N)`` resolves
    through here).  ``n_devices=None`` takes every visible device."""
    devs = list(devices) if devices is not None else list(jax.devices())
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1 or n > len(devs):
        raise ValueError(
            f"requested a {n}-device mesh but {len(devs)} device(s) are "
            f"visible (platform {jax.default_backend()!r}); on CPU, "
            f"simulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.asarray(devs[:n]), (axis,))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _divides(dim: int, mesh: Mesh, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, shape: tuple, mesh: Mesh,
               pol: ShardingPolicy, stacked: bool) -> P:
    """Base spec for a parameter leaf; `stacked` marks a leading layer dim."""
    tp = pol.wide_axis if pol.two_d else pol.tp_axis
    dims = list(shape[1:]) if stacked else list(shape)

    def spec(*entries):
        entries = list(entries) + [None] * (len(dims) - len(entries))
        # drop shardings that do not divide
        ent = [a if (a is not None and _divides(dims[i], mesh, a)) else None
               for i, a in enumerate(entries)]
        return ent

    if path.endswith("embed"):
        ent = spec(tp, None)
    elif path.endswith("lm_head") or path.endswith("patch_proj"):
        ent = spec(None, tp)
    elif any(path.endswith(s) for s in ("wq", "wk", "wv", "w1")):
        ent = spec(None, tp)
    elif any(path.endswith(s) for s in ("wo", "w2")):
        ent = spec(tp, None)
    elif path.endswith("b1"):
        ent = spec(tp)
    elif "moe" in path and path[-2:] in ("wg", "wu"):
        # [E, d, ff]: prefer expert parallelism; else shard ff
        if _divides(dims[0], mesh, tp):
            ent = spec(tp, None, None)
        else:
            ent = spec(None, None, tp)
    elif "moe" in path and path.endswith("wd"):
        if _divides(dims[0], mesh, tp):
            ent = spec(tp, None, None)
        else:
            ent = spec(None, tp, None)
    elif path.endswith("wg") or path.endswith("wu"):
        ent = spec(None, tp)
    elif path.endswith("wd"):
        ent = spec(tp, None)
    elif path.endswith("in_proj"):
        ent = spec(None, tp)
    elif path.endswith("out_proj"):
        ent = spec(tp, None)
    else:
        # norms, biases, router, conv, A_log, D, dt_bias, enc_pos, ...
        ent = [None] * len(dims)

    # FSDP: put the dp axis on the largest still-unsharded dim of big leaves
    if pol.fsdp and int(np.prod(shape)) >= pol.fsdp_min_elems:
        dp = pol.dp_spec
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if ent[i] is None and _divides(dims[i], mesh, dp):
                ent[i] = dp
                break

    if stacked:
        ent = [None] + ent
    return P(*ent)


def param_specs(cfg: ModelConfig, mesh: Mesh,
                pol: Optional[ShardingPolicy] = None) -> Params:
    """PartitionSpec pytree mirroring ``lm.init_params``."""
    pol = pol or for_mesh(mesh)
    abstract = lm.abstract_params(cfg)

    def one(path_tuple, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path_tuple]
        path = "/".join(str(k) for k in keys)
        stacked = keys and keys[0] in ("layers", "enc_layers")
        return _leaf_spec(path, leaf.shape, mesh, pol, stacked)

    return jax.tree_util.tree_map_with_path(one, abstract)


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    pol: Optional[ShardingPolicy] = None) -> Params:
    specs = param_specs(cfg, mesh, pol)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, mesh: Mesh, batch_size: int,
               pol: Optional[ShardingPolicy] = None) -> dict:
    """Specs for a training/prefill batch dict."""
    pol = pol or for_mesh(mesh)
    dp = pol.batch_spec_axes
    bdim = dp if _divides(batch_size, mesh, dp) else (
        "data" if _divides(batch_size, mesh, "data") else None)
    d = {"tokens": P(bdim, None), "labels": P(bdim, None)}
    if cfg.vlm is not None:
        d["patches"] = P(bdim, None, None)
    if cfg.encdec is not None:
        d["frames"] = P(bdim, None, None)
    return d


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch_size: int,
                pol: Optional[ShardingPolicy] = None) -> dict:
    """Specs for the decode cache pytree (mirrors lm.init_decode_cache).

    B >= dp: shard batch over dp; B == 1 (long-context): shard the cache
    *sequence* dim over the data axis (sequence parallelism) and heads over
    the model axis.
    """
    pol = pol or for_mesh(mesh)
    tp = pol.tp_axis
    dp = pol.batch_spec_axes
    bdim = dp if _divides(batch_size, mesh, dp) else (
        "data" if _divides(batch_size, mesh, "data") else None)
    seq_axis = pol.dp_spec if bdim is None else None   # SP fallback for B=1
    if pol.two_d:
        # resident-weight serving: batch replicated, cache SEQUENCE sharded
        # over every axis — each chip owns a contiguous KV window and the
        # softmax statistics are combined with tiny all-reduces
        bdim, seq_axis = None, pol.wide_axis

    c: dict = {"len": P()}
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        nkv = cfg.n_kv_heads if cfg.family != "audio" else cfg.n_heads
        kvdim = tp if (not pol.two_d and _divides(nkv, mesh, tp)) else None
        c["k"] = P(None, bdim, seq_axis, kvdim, None)
        c["v"] = P(None, bdim, seq_axis, kvdim, None)
        if cfg.kv_quant and cfg.family != "audio":
            c["k_scale"] = P(None, bdim, seq_axis, kvdim)
            c["v_scale"] = P(None, bdim, seq_axis, kvdim)
        if cfg.family == "audio":
            c["xk"] = P(None, bdim, None, kvdim, None)
            c["xv"] = P(None, bdim, None, kvdim, None)
    elif cfg.family in ("ssm", "hybrid"):
        nh = cfg.ssm.n_heads(cfg.d_model)
        hdim = tp if _divides(nh, mesh, tp) else None
        c["ssm"] = P(None, bdim, hdim, None, None)
        c["conv"] = P(None, bdim, None, None)
        if cfg.family == "hybrid":
            kvdim = tp if _divides(cfg.n_kv_heads, mesh, tp) else None
            c["k"] = P(None, bdim, seq_axis, kvdim, None)
            c["v"] = P(None, bdim, seq_axis, kvdim, None)
    return c


def logical_axis_rules() -> list[tuple]:
    """Documented axis mapping (for DESIGN.md / debugging)."""
    return [
        ("batch", ("pod", "data")),
        ("vocab", ("model",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("mlp", ("model",)),
        ("experts", ("model",)),
        ("cache_seq", ("data",)),
    ]
