"""Distributed runtime: sharding rules, pipeline parallelism over TAPA
channels, ZeRO optimizer-state sharding, gradient compression."""

from .sharding import (batch_spec, cache_specs, logical_axis_rules,
                       param_specs, ShardingPolicy)

__all__ = ["param_specs", "batch_spec", "cache_specs",
           "logical_axis_rules", "ShardingPolicy"]
