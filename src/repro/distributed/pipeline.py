"""Pipeline parallelism as a TAPA task graph, lowered to shard_map+ppermute.

This is where the paper's programming model becomes a first-class feature
of the LM framework:

1. The pipeline schedule *is* a task graph — each stage is a task, each
   microbatch hand-off is a bounded channel (capacity = in-flight
   microbatches).  ``schedule_task_graph`` builds it with the Table-2 API
   and the coroutine engine *verifies* it (deadlock-freedom, occupancy
   bounds, schedule length) in milliseconds — the paper's
   fast-correctness-cycle applied to a distributed schedule instead of an
   RTL design (Fig. 2).

2. The verified schedule is then lowered to the TPU: one mesh axis hosts
   the stages, activations move between neighbouring stages with
   ``lax.ppermute`` (the ICI is the channel), and the GPipe time loop is a
   differentiable ``lax.scan`` so ``jax.grad`` runs the *reverse* pipeline
   automatically — backward microbatches flow through the same channels in
   the opposite direction, which is exactly the 1F1B dataflow without
   hand-scheduling it.

The TAPA channel *capacity* maps to the number of microbatches in flight;
the simulation reports ``max_occupancy`` per channel, which must not exceed
what the compiled buffer (one ppermute slot per step) provides — the
property test in tests/test_pipeline.py checks both sides.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # moved to jax.shard_map in 0.5+
    _shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)

from ..core import channel, task
from ..core.engines import ENGINES, SimReport


# ---------------------------------------------------------------------------
# 1. the schedule as a TAPA task graph (simulation / verification side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    channel_capacity: int = 2        # in-flight microbatches per hand-off

    @property
    def bubble_fraction(self) -> float:
        """GPipe bubble: (S-1) / (M + S - 1)."""
        S, M = self.n_stages, self.n_microbatches
        return (S - 1) / (M + S - 1)


def schedule_task_graph(pcfg: PipelineConfig,
                        engine: str = "coroutine",
                        payloads: Optional[list] = None) -> SimReport:
    """Run the pipeline schedule as a task-parallel program.

    Feeder -> Stage_0 -> ... -> Stage_{S-1} -> Collector, every hand-off a
    bounded channel.  Returns the SimReport; ``report.result`` holds the
    microbatch ids in arrival order (must be FIFO) and per-channel
    occupancy statistics ride on the report's channel list.
    """
    S, M = pcfg.n_stages, pcfg.n_microbatches
    payloads = payloads if payloads is not None else list(range(M))

    def Feeder(out):
        for p in payloads:
            out.write(p)
        out.close()

    def Stage(inp, out):
        for p in inp:                 # drain one transaction
            out.write(p)              # unit of work per microbatch
        out.close()

    def Collector(inp, sink: list):
        for p in inp:
            sink.append(p)

    def Top(sink):
        chans = [channel(capacity=pcfg.channel_capacity, name=f"mb{i}")
                 for i in range(S + 1)]
        t = task().invoke(Feeder, chans[0])
        for i in range(S):
            t = t.invoke(Stage, chans[i], chans[i + 1], name=f"stage{i}")
        t.invoke(Collector, chans[S], sink)

    sink: list = []
    # stats on: the whole point of this simulation is verifying channel
    # occupancy against the ppermute buffer bound (max_occupancy below)
    rep = ENGINES[engine](track_stats=True).run(Top, sink)
    rep.result = sink
    return rep


# ---------------------------------------------------------------------------
# 2. the compiled GPipe schedule (shard_map + ppermute)
# ---------------------------------------------------------------------------

def spmd_pipeline(stage_fn: Callable, n_stages: int, n_microbatches: int,
                  axis: str = "stage"):
    """Build the per-device pipeline body (to run inside shard_map).

    ``stage_fn(stage_params, x) -> y`` is one stage's compute; the returned
    function has signature ``(stage_params_local, microbatches) -> outputs``
    where ``microbatches`` is ``[M, mb, ...]`` (replicated across stages)
    and ``outputs`` is ``[M, mb, ...]`` (valid on every stage after the
    final psum-broadcast).

    The time loop is ``lax.scan`` over T = M + S - 1 steps; each step does
    compute then a neighbour ``ppermute`` — exactly one channel slot per
    edge per step, matching the verified task-graph schedule.
    """
    S, M = n_stages, n_microbatches
    T = M + S - 1

    def pipe(stage_params, xs):
        stage = jax.lax.axis_index(axis)
        x0 = jnp.zeros_like(xs[0])

        def step(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (clamped; garbage beyond M is
            # never written to outputs)
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            inp = jnp.where(stage == 0, feed, state)
            out = stage_fn(stage_params, inp)
            # hand off to the next stage over the ICI "channel"
            nxt = jax.lax.ppermute(
                out, axis, [(i, i + 1) for i in range(S - 1)])
            # the last stage retires microbatch t-(S-1)
            widx = t - (S - 1)
            valid = (stage == S - 1) & (widx >= 0)
            cw = jnp.clip(widx, 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, cw, 0,
                                               keepdims=False)
            new = jnp.where(valid, out, cur)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, new, cw, 0)
            return (nxt, outputs), None

        outputs0 = jnp.zeros((M,) + jax.eval_shape(
            stage_fn, stage_params, x0).shape, x0.dtype)
        (_, outputs), _ = jax.lax.scan(step, (x0, outputs0),
                                       jnp.arange(T))
        # broadcast the last stage's outputs to every stage
        outputs = jax.lax.psum(
            jnp.where(stage == S - 1, outputs, jnp.zeros_like(outputs)),
            axis)
        return outputs

    return pipe


def compile_pipeline(mesh: Mesh, stage_fn: Callable, stacked_params: Any,
                     microbatches: jax.Array, *, axis: str = "stage",
                     cache=None):
    """AOT-compile the shard_mapped GPipe body through the compile cache.

    The cache key is the *user's stage definition* (structural hash — the
    shard_map wrapper's internals would only add noise) plus the digest of
    the schedule builder itself (editing ``spmd_pipeline``'s
    ppermute/rotation logic must dirty cached pipelines), the schedule
    geometry, and the mesh topology.  An unchanged pipeline loads from the
    content-addressed store instead of re-lowering; editing the stage body
    or the schedule dirties exactly this entry.  Returns
    ``(executable, source)``.
    """
    from ..core.compile_cache import default_cache, structural_digest
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    pipe = spmd_pipeline(stage_fn, S, M, axis)
    shmapped = _shard_map(
        pipe, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False)
    cc = cache if cache is not None else default_cache()
    return cc.compile_cached(
        shmapped, (stacked_params, microbatches),
        hash_fn=stage_fn,
        extra=("spmd_pipeline", structural_digest(spmd_pipeline),
               axis, int(S), int(M),
               tuple(sorted((k, int(v)) for k, v in mesh.shape.items())),
               tuple(str(d) for d in mesh.devices.flat)))


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stacked_params: Any,
                   microbatches: jax.Array, *, axis: str = "stage",
                   verify: bool = True, cache=False) -> jax.Array:
    """High-level entry: verify the schedule in simulation (C2), then run
    the compiled pipeline on the mesh.

    ``stacked_params``: pytree with a leading [S, ...] stage axis.
    ``microbatches``: [M, mb, ...].  ``cache``: ``False`` traces eagerly
    (the seed behaviour); ``None`` routes the compile through the
    process-default :class:`~repro.core.compile_cache.CompileCache`; a
    cache instance uses that store.
    """
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    if verify:
        rep = schedule_task_graph(PipelineConfig(S, M))
        if not rep.ok:
            raise RuntimeError(f"pipeline schedule failed simulation: "
                               f"{rep.error}")
        assert rep.result == list(range(M)), "schedule is not FIFO"

    if cache is not False:
        exe, _ = compile_pipeline(mesh, stage_fn, stacked_params,
                                  microbatches, axis=axis, cache=cache)
        return exe(stacked_params, microbatches)

    pipe = spmd_pipeline(stage_fn, S, M, axis)
    shmapped = _shard_map(
        pipe, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False)
    return shmapped(stacked_params, microbatches)


def pipeline_loss_fn(mesh: Mesh, stage_fn: Callable, loss_tail: Callable,
                     *, axis: str = "stage"):
    """Differentiable pipeline loss: mean over microbatches of
    ``loss_tail(last_stage_out, labels_mb)``.  ``jax.grad`` of this runs
    the reverse pipeline (backward microbatches traverse the same
    ppermute channels in reverse)."""
    def fn(stacked_params, microbatches, labels):
        S = mesh.shape[axis]
        M = microbatches.shape[0]
        pipe = spmd_pipeline(stage_fn, S, M, axis)

        def body(params, xs, ys):
            outs = pipe(params, xs)                    # [M, mb, ...]
            return loss_tail(outs, ys)

        shmapped = _shard_map(
            body, mesh=mesh, in_specs=(P(axis), P(), P()),
            out_specs=P(), check_vma=False)
        return shmapped(stacked_params, microbatches, labels)
    return fn


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def stack_stage_params(per_stage: list) -> Any:
    """Stack per-stage parameter pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage)


def shard_stage_params(mesh: Mesh, stacked: Any, axis: str = "stage") -> Any:
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), stacked)
