"""Admission control, load shedding, fair queuing, and the circuit breaker.

This is the overload layer of the serving stack: everything that decides
whether a request gets compute *before* any compute is spent on it.

* :class:`AdmissionController` sits between the traffic frontend and the
  bounded request channel.  Arrivals are queued per tenant; dispatch
  order is **priority classes first, weighted deficit-round-robin within
  a class** (the DRR quantum is in estimated tokens, so a tenant with
  weight 2 gets twice the token budget per round, not twice the request
  count).  Three shedding mechanisms bound the backlog:

  - ``reject-new`` — an arrival past ``queue_limit`` is shed on the spot;
  - ``drop-oldest`` — the arrival is queued and the oldest request of the
    *lowest-priority* backlogged tenant is shed instead (protects
    interactive tenants from a flooder);
  - **deadline-infeasible shed** — at offer *and* at dispatch, a request
    whose estimated completion (queued work ahead x measured per-token
    latency + its own service estimate) cannot meet its ``deadline_s``
    is shed immediately rather than wasting queue time and compute.

  Every shed produces a structured
  ``RequestError("overloaded", retry_after_s=...)`` — never a blocked
  producer — and is journaled through the PR-7 :class:`~repro.serve.
  journal.ServeJournal` (record type ``shed``) before the verdict is
  visible, so a crash-restart replays shed verdicts exactly-once and
  never re-admits a shed rid.

* :class:`CircuitBreaker` wraps the serving step calls: ``closed`` →
  ``open`` after ``fail_threshold`` consecutive step failures (the PR-6
  fault kinds: exhausted transients, injected step exceptions),
  fast-fail with :class:`BreakerOpen` while open, then a half-open probe
  after ``cooldown_s`` — one real call is let through; success closes
  the breaker, failure re-opens it.  This extends the degradation ladder
  between "retry" and "fail everything" (docs/robustness.md).

* :class:`ServeMetrics` tracks per-tenant streaming TTFT and per-token
  latency (p50/p95/p99), goodput vs throughput, and the shed accounting
  invariant ``offered == admitted + shed``; ``benchmarks/serve_time.py``
  persists its summary as the overload section of
  ``BENCH_serve_time.json``.

Determinism: nothing here reads a wall clock directly — the controller,
breaker and metrics all take a ``clock`` callable (``time.perf_counter``
for production, :class:`~repro.serve.traffic.VirtualClock` for
simulated time), so an overload run under the coroutine engine is a
pure function of (traffic seed, fault seed, config).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

from .engine import Request, RequestError

__all__ = ["AdmissionConfig", "AdmissionController", "BreakerOpen",
           "CircuitBreaker", "ServeMetrics", "percentile"]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def percentile(xs: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on empty input."""
    if not xs:
        return None
    xs = sorted(xs)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


class ServeMetrics:
    """Per-tenant streaming latency and goodput accounting.

    The engine funnels every request outcome through here: ``shed`` at
    admission, ``done``/``failed`` at retirement, with first-token and
    completion stamps taken from the shared serving clock.  ``summary()``
    folds the stream into the shape the benchmark persists.
    """

    def __init__(self, clock=None):
        self.clock = clock or time.perf_counter
        self.offered: Dict[str, int] = {}
        self.admitted: Dict[str, int] = {}
        self.shed: Dict[str, int] = {}
        self.shed_reasons: Dict[str, int] = {}
        self.done_rows: List[dict] = []     # completed requests
        self.failed: Dict[str, int] = {}    # structured non-shed errors
        self.deadline_violations = 0
        self.t_start: Optional[float] = None

    def _bump(self, table: Dict[str, int], tenant: str) -> None:
        table[tenant] = table.get(tenant, 0) + 1

    def note_offered(self, tenant: str) -> None:
        if self.t_start is None:
            self.t_start = self.clock()
        self._bump(self.offered, tenant)

    def note_admitted(self, tenant: str) -> None:
        self._bump(self.admitted, tenant)

    def note_shed(self, tenant: str, reason: str) -> None:
        self._bump(self.shed, tenant)
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def note_done(self, tenant: str, t_arr: Optional[float],
                  t_first: Optional[float], n_tokens: int) -> None:
        now = self.clock()
        self.done_rows.append({
            "tenant": tenant, "n": n_tokens,
            "ttft": None if (t_arr is None or t_first is None)
            else t_first - t_arr,
            "tok_s": None if (t_first is None or n_tokens <= 1)
            else (now - t_first) / (n_tokens - 1),
            "t_done": now,
        })

    def note_failed(self, tenant: str, status: str) -> None:
        self._bump(self.failed, tenant)
        if status == "deadline":
            self.deadline_violations += 1

    # -- folding -----------------------------------------------------------

    def tenants(self) -> List[str]:
        names = set(self.offered) | set(self.admitted) | set(self.shed)
        names |= {r["tenant"] for r in self.done_rows}
        return sorted(names)

    def check_accounting(self) -> None:
        """The shed invariant: every offered request was either admitted
        or shed, per tenant.  Raises AssertionError on violation."""
        for t in self.tenants():
            off = self.offered.get(t, 0)
            adm = self.admitted.get(t, 0)
            shd = self.shed.get(t, 0)
            assert off == adm + shd, \
                f"tenant {t!r}: offered {off} != admitted {adm} + shed {shd}"

    def summary(self, wall_s: Optional[float] = None) -> dict:
        good_tokens = sum(r["n"] for r in self.done_rows)
        if wall_s is None:
            t0 = self.t_start
            t1 = max((r["t_done"] for r in self.done_rows), default=None)
            wall_s = (t1 - t0) if (t0 is not None and t1 is not None
                                   and t1 > t0) else None
        per_tenant = {}
        for t in self.tenants():
            rows = [r for r in self.done_rows if r["tenant"] == t]
            ttft = [r["ttft"] for r in rows if r["ttft"] is not None]
            toks = [r["tok_s"] for r in rows if r["tok_s"] is not None]
            per_tenant[t] = {
                "offered": self.offered.get(t, 0),
                "admitted": self.admitted.get(t, 0),
                "shed": self.shed.get(t, 0),
                "completed": len(rows),
                "failed": self.failed.get(t, 0),
                "ttft_p50_s": percentile(ttft, 50),
                "ttft_p95_s": percentile(ttft, 95),
                "ttft_p99_s": percentile(ttft, 99),
                "tok_latency_p50_s": percentile(toks, 50),
                "tok_latency_p99_s": percentile(toks, 99),
            }
        all_ttft = [r["ttft"] for r in self.done_rows
                    if r["ttft"] is not None]
        return {
            "offered": sum(self.offered.values()),
            "admitted": sum(self.admitted.values()),
            "shed": sum(self.shed.values()),
            "shed_reasons": dict(self.shed_reasons),
            "completed": len(self.done_rows),
            "deadline_violations": self.deadline_violations,
            "good_tokens": good_tokens,
            "goodput_tok_s": None if not wall_s
            else round(good_tokens / wall_s, 2),
            "wall_s": None if wall_s is None else round(wall_s, 4),
            "ttft_p50_s": percentile(all_ttft, 50),
            "ttft_p95_s": percentile(all_ttft, 95),
            "ttft_p99_s": percentile(all_ttft, 99),
            "tenants": per_tenant,
        }


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdmissionConfig:
    """Knobs for the admission controller (all static, journal-friendly).

    ``shed_policy``: ``"reject-new"`` | ``"drop-oldest"``.
    ``queue_limit``: max queued requests across all tenants (the DRR
    backlog bound; the request channel's ``queue_cap`` bounds the
    dispatched segment separately).
    ``est_token_s``: initial per-token latency estimate for the
    deadline-infeasible shed; refined online by an EWMA over measured
    decode-step latency (``observe_token_latency``).  ``0`` disables
    infeasibility shedding until a measurement arrives.
    ``quantum_tokens``: DRR quantum per round per unit weight, in
    estimated tokens.
    ``retry_after_s``: hint returned with every shed verdict.
    """

    shed_policy: str = "reject-new"
    queue_limit: int = 64
    deadline_shed: bool = True
    est_token_s: float = 0.0
    ewma: float = 0.25
    quantum_tokens: float = 32.0
    retry_after_s: float = 0.5

    def __post_init__(self):
        if self.shed_policy not in ("reject-new", "drop-oldest"):
            raise ValueError(
                f"unknown shed_policy {self.shed_policy!r}; "
                f"expected 'reject-new' or 'drop-oldest'")


class _TenantQ:
    __slots__ = ("q", "deficit", "weight", "priority")

    def __init__(self, weight: float, priority: int):
        self.q: deque = deque()
        self.deficit = 0.0
        self.weight = weight
        self.priority = priority


def _cost(r: Request) -> float:
    """Estimated service cost in tokens (prefill amortized per token is
    cheap next to decode, so max_new dominates; the prompt still counts
    at a discount for long-context requests)."""
    return r.max_new + 0.25 * len(r.prompt)


class AdmissionController:
    """Per-tenant fair queuing + cost-aware load shedding.

    ``offer(request)`` returns ``None`` (queued), a
    :class:`RequestError` (shed verdict — the caller delivers it), or
    ``("replayed", result)`` when the journal already holds the rid's
    outcome (crash-restart exactly-once).  ``pop()`` returns the next
    request in fair-queue order, shedding any queued request that became
    deadline-infeasible while it waited (those verdicts accumulate in
    ``pending_errors`` for the caller to drain).
    """

    def __init__(self, cfg: AdmissionConfig = None, tenants=None,
                 journal=None, metrics: ServeMetrics = None, clock=None):
        self.cfg = cfg or AdmissionConfig()
        self.journal = journal
        self.metrics = metrics
        self.clock = clock or time.perf_counter
        self.token_s = self.cfg.est_token_s
        self._tq: Dict[str, _TenantQ] = {}
        self._rotation: List[str] = []       # tenant visit order (stable)
        self.pending_errors: List[RequestError] = []
        self.offered = 0
        self.admitted = 0                    # dispatched via pop()
        self.shed_total = 0

    # -- tenant registry ---------------------------------------------------

    def register(self, name: str, weight: float = 1.0,
                 priority: int = 0) -> None:
        if name not in self._tq:
            self._tq[name] = _TenantQ(weight, priority)
            self._rotation.append(name)
            # stable sort: priority classes first, registration/rotation
            # order within a class
            self._rotation.sort(key=lambda n: self._tq[n].priority)

    def register_tenants(self, specs) -> None:
        for s in specs:
            self.register(s.name, weight=s.weight, priority=s.priority)

    def _queue_for(self, tenant: str) -> _TenantQ:
        if tenant not in self._tq:
            self.register(tenant)
        return self._tq[tenant]

    # -- latency model -----------------------------------------------------

    def observe_token_latency(self, dt: float) -> None:
        """EWMA over measured per-token (decode step) latency."""
        if dt <= 0:
            return
        a = self.cfg.ewma
        self.token_s = dt if self.token_s <= 0 \
            else (1 - a) * self.token_s + a * dt

    def backlog(self) -> int:
        return sum(len(t.q) for t in self._tq.values())

    def backlog_cost(self) -> float:
        return sum(_cost(r) for t in self._tq.values() for r in t.q)

    def _backlog_cost_ahead(self, r: Request) -> float:
        """Estimated queued tokens dispatched *before* ``r`` would be:
        strictly-higher-priority classes in full plus ``r``'s own class
        (DRR interleaves within a class — counting peers is the
        conservative bound).  Lower-priority backlog does not make a
        high-priority arrival infeasible."""
        pr = self._queue_for(r.tenant).priority
        return sum(_cost(q) for t in self._tq.values()
                   if t.priority <= pr for q in t.q)

    def _infeasible(self, r: Request, now: float, queued_cost: float) -> bool:
        if not self.cfg.deadline_shed or r.deadline_s is None \
                or self.token_s <= 0:
            return False
        waited = 0.0 if r.t_arrival is None else max(0.0, now - r.t_arrival)
        est = waited + (queued_cost + _cost(r)) * self.token_s
        return est > r.deadline_s

    # -- verdicts ----------------------------------------------------------

    def _shed(self, r: Request, reason: str, detail: str) -> RequestError:
        self.shed_total += 1
        if self.metrics is not None:
            self.metrics.note_shed(r.tenant, reason)
        if self.journal is not None:
            # write-ahead: the verdict is durable before it is visible,
            # so a crash-restart replays it instead of re-admitting
            self.journal.shed(r.rid, detail=detail)
        return RequestError(r.rid, "overloaded", detail,
                            retry_after_s=self.cfg.retry_after_s)

    def offer(self, r: Request):
        """Admission verdict for one arrival (see class docstring)."""
        now = self.clock()
        self.offered += 1
        if self.metrics is not None:
            self.metrics.note_offered(r.tenant)
        if self.journal is not None:
            done = self.journal.completed.get(r.rid)
            if done is not None:
                # exactly-once across restart: shed and retired rids
                # answer straight from the journal, never recomputed.
                # note_offered above still counts it so accounting holds.
                if self.metrics is not None:
                    if isinstance(done, tuple) and done[0] == "overloaded":
                        self.metrics.note_shed(r.tenant, "replayed")
                    else:
                        self.metrics.note_admitted(r.tenant)
                return ("replayed", done)
        if self._infeasible(r, now, self._backlog_cost_ahead(r)):
            return self._shed(
                r, "deadline-infeasible",
                f"cannot meet deadline {r.deadline_s}s: "
                f"{self.backlog()} queued ahead at "
                f"~{self.token_s:.4f}s/token")
        if self.backlog() >= self.cfg.queue_limit:
            if self.cfg.shed_policy == "reject-new":
                return self._shed(
                    r, "reject-new",
                    f"queue full ({self.cfg.queue_limit} backlogged)")
            # drop-oldest: evict from the lowest-priority backlogged
            # tenant (ties: latest in rotation) so a flood sheds itself
            victim_name = max(
                (n for n, t in self._tq.items() if t.q),
                key=lambda n: (self._tq[n].priority,
                               self._rotation.index(n)))
            victim = self._tq[victim_name].q.popleft()
            err = self._shed(victim, "drop-oldest",
                             f"dropped for newer arrival {r.rid}")
            self.pending_errors.append(err)
        self._queue_for(r.tenant).q.append(r)
        return None

    def pop(self) -> Optional[Request]:
        """Next request in priority + weighted-DRR order, or None.

        Dispatch-time staleness check: a queued request that can no
        longer meet its deadline is shed here (verdict appended to
        ``pending_errors``) and the scan continues.
        """
        now = self.clock()
        while True:
            r = self._pop_drr()
            if r is None:
                return None
            # at dispatch the request is next in line: only its own
            # service time remains in the estimate
            if self._infeasible(r, now, 0.0):
                self.pending_errors.append(self._shed(
                    r, "deadline-infeasible",
                    f"deadline {r.deadline_s}s unreachable after queuing"))
                continue
            self.admitted += 1
            if self.metrics is not None:
                self.metrics.note_admitted(r.tenant)
            return r

    def _pop_drr(self) -> Optional[Request]:
        active = [n for n in self._rotation if self._tq[n].q]
        if not active:
            return None
        top = min(self._tq[n].priority for n in active)
        incls = {n for n in active if self._tq[n].priority == top}
        # classic DRR over the top priority class: the rotation head
        # keeps serving while its deficit covers its head-of-line cost;
        # when it cannot, it is topped up ONCE and sent to the back of
        # its class (its turn ends).  Topping up per-turn — not per-visit
        # — is what makes weight 2 worth twice the token share; a head
        # costlier than quantum*weight banks deficit across rounds.
        for _ in range(100000):
            name = next(n for n in self._rotation
                        if n in incls and self._tq[n].q)
            t = self._tq[name]
            if t.deficit >= _cost(t.q[0]):
                r = t.q.popleft()
                t.deficit -= _cost(r)
                if not t.q:
                    t.deficit = 0.0           # no banking while idle
                    self._to_back(name)
                return r
            t.deficit += self.cfg.quantum_tokens * t.weight
            self._to_back(name)
        raise RuntimeError("DRR dispatch failed to converge")

    def _to_back(self, name: str) -> None:
        """End a tenant's turn: move it behind its priority class (the
        sort is stable, so cross-class order is untouched)."""
        self._rotation.remove(name)
        self._rotation.append(name)
        self._rotation.sort(key=lambda n: self._tq[n].priority)

    def drain_errors(self) -> List[RequestError]:
        out, self.pending_errors = self.pending_errors, []
        return out

    def stats(self) -> dict:
        return {"offered": self.offered, "admitted": self.admitted,
                "shed": self.shed_total, "backlog": self.backlog(),
                "est_token_s": round(self.token_s, 6)}


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class BreakerOpen(RuntimeError):
    """Fast-fail raised instead of a step call while the breaker is open."""

    def __init__(self, msg: str, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class CircuitBreaker:
    """closed -> open -> half-open circuit around the serving step calls.

    ``failure()`` counts *consecutive* final step failures (a retried
    transient that eventually succeeds never reaches it); at
    ``fail_threshold`` the breaker opens and ``check()`` raises
    :class:`BreakerOpen` without touching the backend.  After
    ``cooldown_s`` (on the injected ``clock``) one probe call is let
    through half-open: success closes, failure re-opens and restarts the
    cooldown.  All transitions append to ``log`` as
    ``(t, from_state, to_state, detail)``.
    """

    def __init__(self, fail_threshold: int = 3, cooldown_s: float = 1.0,
                 clock=None):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        self.fail_threshold = fail_threshold
        self.cooldown_s = cooldown_s
        self.clock = clock or time.perf_counter
        self.state = "closed"
        self.consecutive = 0
        self.opened_at: Optional[float] = None
        self.log: List[tuple] = []

    def _move(self, to: str, detail: str = "") -> None:
        self.log.append((self.clock(), self.state, to, detail))
        self.state = to

    def retry_after(self) -> float:
        if self.opened_at is None:
            return 0.0
        return max(0.0, self.cooldown_s - (self.clock() - self.opened_at))

    def check(self) -> None:
        """Gate one step call: no-op when closed; raises when open;
        transitions open -> half-open (admitting this call as the probe)
        once the cooldown has elapsed."""
        if self.state == "closed" or self.state == "half-open":
            return
        left = self.retry_after()
        if left > 0:
            raise BreakerOpen(
                f"circuit open ({self.consecutive} consecutive failures); "
                f"retry in {left:.3f}s", retry_after_s=left)
        self._move("half-open", "cooldown elapsed; probing")

    def success(self) -> None:
        if self.state == "half-open":
            self._move("closed", "probe succeeded")
        self.consecutive = 0
        self.opened_at = None

    def failure(self, detail: str = "") -> None:
        self.consecutive += 1
        if self.state == "half-open":
            self.opened_at = self.clock()
            self._move("open", f"probe failed: {detail}"[:120])
        elif self.state == "closed" and \
                self.consecutive >= self.fail_threshold:
            self.opened_at = self.clock()
            self._move("open",
                       f"{self.consecutive} consecutive failures: "
                       f"{detail}"[:120])
