"""Write-ahead serving journal: exactly-once results across crashes.

One JSONL file, appended synchronously (``fsync`` per record) by the
serving scheduler:

* ``{"t": "admit", "rid", "prompt", "max_new", "deadline"}`` — a request
  entered a decode slot.  Written *before* any compute for that request.
* ``{"t": "shed", "rid", "detail", "retry_after"}`` — the admission
  controller rejected the request (overload).  Written *before* the
  structured ``RequestError("overloaded")`` verdict is delivered, so a
  crash between shedding and delivery re-delivers the verdict on restart
  instead of silently re-admitting a request the client was already told
  to back off from.
* ``{"t": "tok", "rid", "tok"}`` — one emitted token.  Written as each
  token is appended to the slot, so the journal always knows the request's
  last position.
* ``{"t": "retire", "rid", "toks"}`` (success) or
  ``{"t": "retire", "rid", "status", "detail"}`` (structured error) —
  the request's final result.  Written *before* the result transaction is
  emitted to the collector (write-ahead), so a crash between journaling
  and delivery re-delivers from the journal on restart.

Replay folds the log into two maps:

* ``completed``: rid -> token list (or ``(status, detail)``) — requests
  whose result is durable.  Shed records fold to
  ``("overloaded", detail)`` here: a shed verdict is a final answer.  A re-submitted completed rid is answered
  straight from the journal, never recomputed: with the rid-keyed result
  store this is exactly-once delivery (a crash after retire-journal but
  before delivery re-emits the identical result; a duplicate submission
  reproduces it byte-for-byte).
* ``inflight``: rid -> {prompt, max_new, deadline, toks} — admitted but
  not retired.  The restarted scheduler re-admits these at their last
  journaled position: it re-prefills over ``prompt + toks`` and continues
  decoding, which for greedy (argmax) decoding of a causal model produces
  exactly the continuation the crashed process would have produced.

A record torn by the crash itself (partial last line) is dropped at
replay — every *complete* record was fsync'd before the corresponding
effect was externally visible, so dropping the torn tail loses nothing
that was promised.
"""

from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Optional


class ServeJournal:
    """Append-only request journal; replays existing content at open."""

    def __init__(self, path):
        self.path = Path(path)
        self.completed, self.inflight = self.replay(self.path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._truncate_torn_tail()
        self._f = open(self.path, "a", encoding="utf-8")
        # under the thread engine the frontend (shed records) and the
        # scheduler (admit/tok/retire) append concurrently
        self._lock = threading.Lock()

    def _truncate_torn_tail(self) -> None:
        """Cut the file back to its last complete record before appending.

        A crash mid-append leaves a partial line at the tail; appending
        after it would concatenate the next record onto the fragment,
        making one unreadable line in the *middle* of the file — which
        replay (correctly) refuses to read past.  The torn record never
        had external effects, so dropping it is safe."""
        if not self.path.exists():
            return
        data = self.path.read_bytes()
        good = 0
        for line in data.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break
            try:
                json.loads(line)
            except ValueError:
                break
            good += len(line)
        if good < len(data):
            with open(self.path, "r+b") as f:
                f.truncate(good)

    # -- append (write-ahead: callers journal BEFORE acting) ---------------

    def _append(self, rec: dict) -> None:
        with self._lock:
            self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
            self._f.flush()
            os.fsync(self._f.fileno())

    def admit(self, rid: int, prompt: list, max_new: int,
              deadline: Optional[float]) -> None:
        self._append({"t": "admit", "rid": int(rid),
                      "prompt": [int(t) for t in prompt],
                      "max_new": int(max_new), "deadline": deadline})

    def shed(self, rid: int, detail: str = "",
             retry_after: float = 0.0) -> None:
        """Durable overload verdict (write-ahead, before delivery)."""
        self._append({"t": "shed", "rid": int(rid), "detail": detail,
                      "retry_after": retry_after})
        self.completed[int(rid)] = ("overloaded", detail)

    def tok(self, rid: int, tok: int) -> None:
        self._append({"t": "tok", "rid": int(rid), "tok": int(tok)})

    def retire(self, rid: int, toks: Optional[list] = None,
               status: Optional[str] = None, detail: str = "") -> None:
        rec: dict = {"t": "retire", "rid": int(rid)}
        if toks is not None:
            rec["toks"] = [int(t) for t in toks]
        else:
            rec["status"] = status or "error"
            rec["detail"] = detail
        self._append(rec)

    def close(self) -> None:
        self._f.close()

    # -- replay ------------------------------------------------------------

    @staticmethod
    def replay(path) -> tuple[dict, dict]:
        """Fold a journal file into ``(completed, inflight)`` maps.

        Stops at the first undecodable line — only the crash-torn tail
        record can be malformed, and it never had external effects.
        """
        completed: dict = {}
        inflight: dict = {}
        path = Path(path)
        if not path.exists():
            return completed, inflight
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    break                     # torn tail record
                t, rid = rec.get("t"), rec.get("rid")
                if t == "admit":
                    inflight[rid] = {"prompt": rec.get("prompt", []),
                                     "max_new": rec.get("max_new", 0),
                                     "deadline": rec.get("deadline"),
                                     "toks": []}
                elif t == "tok":
                    if rid in inflight:
                        inflight[rid]["toks"].append(rec["tok"])
                elif t == "shed":
                    inflight.pop(rid, None)
                    completed[rid] = ("overloaded", rec.get("detail", ""))
                elif t == "retire":
                    inflight.pop(rid, None)
                    if "toks" in rec:
                        completed[rid] = list(rec["toks"])
                    else:
                        completed[rid] = (rec.get("status", "error"),
                                          rec.get("detail", ""))
        return completed, inflight
