"""Deterministic open-loop traffic generation for the serving stack.

The ROADMAP's serving arc asks for *production traffic shapes*: Poisson
arrivals, bursty on/off sources, multi-tenant mixes with a noisy
neighbor.  This module generates them as **seeded, replayable traces** —
the same ``(seed, tenants, duration)`` triple yields the identical
request list byte-for-byte, across processes and engines — by reusing
the FaultInjector's draw discipline (``repro.core.faults._draw``): every
random decision is a pure blake2b hash of ``(seed, kind, site, counter)``,
never a stateful RNG.  That is what makes overload behavior something we
can regression-gate (``BENCH_serve_time.json``) and replay exactly
(the admit/shed/retire journal determinism test).

A trace is a list of :class:`~repro.serve.engine.Request` objects with
``t_arrival`` (seconds from trace start) and ``tenant`` filled in,
sorted by arrival time.  Arrival processes per tenant:

* **Poisson** — exponential inter-arrivals at ``TenantSpec.rate``
  requests/sec.
* **Bursty (on/off MMPP)** — a two-phase Markov-modulated Poisson
  process: exponential on/off phase durations (``phases={"on_s", "off_s",
  "on_scale"}``), arrivals only during on-phases at ``rate * on_scale``.

The chaos harness composes with traffic: a :class:`~repro.core.faults.
FaultPlan` with ``arrival_burst`` / ``tenant_flood`` entries overlays
extra arrivals (a rate spike in a window / a whole flooding tenant) onto
the trace, drawn from the *fault* seed so traffic shape and fault shape
vary independently.  See docs/serving.md (Overload section).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import List, Optional

from ..core.faults import _draw
from .engine import Request

__all__ = ["TenantSpec", "VirtualClock", "make_trace", "trace_digest",
           "noisy_neighbor_mix", "uniform_mix"]


@dataclasses.dataclass
class TenantSpec:
    """One traffic source: arrival process + request-shape distributions.

    ``rate`` is the mean arrival rate in requests/sec; ``weight`` and
    ``priority`` are consumed by the admission controller's fair queuing
    (weight scales the DRR quantum; lower ``priority`` value = served
    first).  ``prompt_len`` / ``max_new`` are inclusive uniform integer
    ranges.  ``phases`` switches the source from Poisson to on/off MMPP:
    ``{"on_s": mean_on, "off_s": mean_off, "on_scale": rate_multiplier}``
    — arrivals fire only during on-phases, at ``rate * on_scale``.
    """

    name: str
    rate: float = 4.0
    weight: float = 1.0
    priority: int = 0
    prompt_len: tuple = (4, 12)
    max_new: tuple = (4, 12)
    deadline_s: Optional[float] = None
    phases: Optional[dict] = None


class VirtualClock:
    """Monotone logical clock for deterministic (simulated-time) serving.

    The serving engine accepts any zero-arg callable as its clock; this
    one is advanced explicitly — by the traffic frontend to each arrival
    time and by the scheduler per decode step (``ServingEngine.step_dt``)
    — so a whole overload run is a deterministic function of (traffic
    seed, fault seed, config), never of host timing.  ``next_event`` is
    the frontend's declared next arrival; an idle scheduler fast-forwards
    to it instead of deadlocking on an empty queue.
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)
        self.next_event: Optional[float] = None

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt > 0:
            self.t += dt

    def advance_to(self, t: float) -> None:
        if t > self.t:
            self.t = t


def _uniform_int(u: float, lo: int, hi: int) -> int:
    """Map a [0,1) draw onto the inclusive integer range [lo, hi]."""
    return lo + min(int(u * (hi - lo + 1)), hi - lo)


def _arrival_times(seed: int, site: str, rate: float, t0: float,
                   t1: float, phases: Optional[dict]) -> List[float]:
    """Arrival instants in [t0, t1) for one source, purely hash-drawn.

    Poisson when ``phases`` is None; on/off MMPP otherwise.  Every draw
    is keyed by (seed, kind, site, counter) so the schedule is identical
    across processes.
    """
    if rate <= 0 or t1 <= t0:
        return []
    out: List[float] = []
    if phases is None:
        t, k = t0, 0
        while True:
            u = _draw(seed, "arr", site, k)
            t += -math.log(1.0 - u) / rate
            k += 1
            if t >= t1:
                return out
            out.append(t)
    on_s = float(phases.get("on_s", 0.5))
    off_s = float(phases.get("off_s", 0.5))
    on_rate = rate * float(phases.get("on_scale", 4.0))
    t, j, k = t0, 0, 0
    on = True                      # phase 0 is an on-phase
    while t < t1:
        mean = on_s if on else off_s
        dur = -math.log(1.0 - _draw(seed, "phase", site, j)) * mean
        j += 1
        end = min(t + dur, t1)
        if on:
            a = t
            while True:
                u = _draw(seed, "arr", site, k)
                a += -math.log(1.0 - u) / on_rate
                k += 1
                if a >= end:
                    break
                out.append(a)
        t = end
        on = not on
    return out


def _requests_for(seed: int, spec: TenantSpec, times: List[float],
                  vocab: int, site: Optional[str] = None) -> List[Request]:
    site = site or spec.name
    reqs = []
    for k, t in enumerate(times):
        plen = _uniform_int(_draw(seed, "plen", site, k), *spec.prompt_len)
        prompt = [_uniform_int(_draw(seed, "tok", site, k, i), 0, vocab - 1)
                  for i in range(plen)]
        max_new = _uniform_int(_draw(seed, "mn", site, k), *spec.max_new)
        reqs.append(Request(rid=-1, prompt=prompt, max_new=max_new,
                            deadline_s=spec.deadline_s, tenant=spec.name,
                            t_arrival=t))
    return reqs


def make_trace(tenants: List[TenantSpec], duration_s: float, *,
               seed: int = 0, vocab: int = 256, scale: float = 1.0,
               faults=None) -> List[Request]:
    """Generate one deterministic open-loop trace.

    ``scale`` multiplies every tenant's arrival rate (the 1x-vs-2x
    offered-load knob: the *same* seed at two scales keeps each tenant's
    request shapes aligned while the arrival schedule densifies).

    ``faults`` (a FaultPlan or FaultInjector) overlays chaos traffic:

    * ``arrival_burst = {tenant|"*": {"at_s", "dur_s", "rate"}}`` — extra
      Poisson arrivals for matching tenants inside the window;
    * ``tenant_flood = {name: {"rate", "start_s", "dur_s", ...}}`` — an
      entire extra flooding tenant (default: low priority, weight 1).

    Overlay draws are keyed by the *fault* seed, so (traffic seed, fault
    seed) vary independently; fired overlays land in ``injector.log``.

    Returns requests sorted by ``t_arrival`` with ``rid`` assigned in
    arrival order — replayable byte-for-byte (see :func:`trace_digest`).
    """
    if faults is not None and not hasattr(faults, "traffic_floods"):
        faults = faults.injector()
    reqs: List[Request] = []
    for spec in tenants:
        rate = spec.rate * scale
        times = _arrival_times(seed, spec.name, rate, 0.0, duration_s,
                               spec.phases)
        reqs.extend(_requests_for(seed, spec, times, vocab))
    if faults is not None:
        fseed = faults.plan.seed
        for spec in tenants:
            for burst in faults.traffic_bursts(spec.name):
                t0 = float(burst.get("at_s", 0.0))
                t1 = min(t0 + float(burst.get("dur_s", duration_s)),
                         duration_s)
                site = f"burst:{spec.name}"
                times = _arrival_times(fseed, site,
                                       float(burst.get("rate", spec.rate)),
                                       t0, t1, None)
                if times:
                    faults.record("arrival_burst", spec.name, len(times))
                reqs.extend(_requests_for(fseed, spec, times, vocab,
                                          site=site))
        for name, flood in faults.traffic_floods().items():
            spec = TenantSpec(
                name=name,
                rate=float(flood.get("rate", 50.0)),
                weight=float(flood.get("weight", 1.0)),
                priority=int(flood.get("priority", 9)),
                prompt_len=tuple(flood.get("prompt_len", (4, 8))),
                max_new=tuple(flood.get("max_new", (4, 8))),
                deadline_s=flood.get("deadline_s"))
            t0 = float(flood.get("start_s", 0.0))
            t1 = min(t0 + float(flood.get("dur_s", duration_s)), duration_s)
            times = _arrival_times(fseed, f"flood:{name}", spec.rate,
                                   t0, t1, None)
            if times:
                faults.record("tenant_flood", name, len(times))
            reqs.extend(_requests_for(fseed, spec, times, vocab,
                                      site=f"flood:{name}"))
    # arrival order with a deterministic tie-break; rids in arrival order
    reqs.sort(key=lambda r: (r.t_arrival, r.tenant))
    for rid, r in enumerate(reqs):
        r.rid = rid
    return reqs


def trace_digest(trace: List[Request]) -> str:
    """Content hash of a trace — the byte-for-byte replay check."""
    payload = [[r.rid, r.tenant, round(r.t_arrival, 9), r.prompt,
                r.max_new, r.deadline_s] for r in trace]
    blob = json.dumps(payload, separators=(",", ":")).encode()
    return hashlib.sha256(blob).hexdigest()


# -- preset mixes ----------------------------------------------------------

def uniform_mix(n: int = 2, rate: float = 4.0,
                deadline_s: Optional[float] = None, **kw) -> List[TenantSpec]:
    """``n`` equal-weight Poisson tenants."""
    return [TenantSpec(name=f"t{i}", rate=rate, deadline_s=deadline_s, **kw)
            for i in range(n)]


def noisy_neighbor_mix(victim_rate: float = 4.0, flood_rate: float = 40.0,
                       deadline_s: Optional[float] = None) -> List[TenantSpec]:
    """A well-behaved interactive tenant next to a bursty flooder.

    The victim gets priority class 0; the flooder sits in class 1 with
    the same DRR weight — fair queuing must keep the victim's latency
    flat while the flooder absorbs the shedding.
    """
    return [
        TenantSpec(name="victim", rate=victim_rate, priority=0,
                   deadline_s=deadline_s),
        TenantSpec(name="flood", rate=flood_rate, priority=1,
                   prompt_len=(4, 8), max_new=(4, 8),
                   deadline_s=deadline_s,
                   phases={"on_s": 0.3, "off_s": 0.3, "on_scale": 2.0}),
    ]
