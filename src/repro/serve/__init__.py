from .engine import (Request, RequestError, ServeConfig, ServingEngine,
                     serve_requests)
from .journal import ServeJournal

__all__ = ["Request", "RequestError", "ServeConfig", "ServingEngine",
           "ServeJournal", "serve_requests"]
