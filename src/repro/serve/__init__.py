from .admission import (AdmissionConfig, AdmissionController, BreakerOpen,
                        CircuitBreaker, ServeMetrics)
from .engine import (Request, RequestError, ServeConfig, ServingEngine,
                     serve_requests)
from .journal import ServeJournal
from .traffic import (TenantSpec, VirtualClock, make_trace,
                      noisy_neighbor_mix, trace_digest, uniform_mix)

__all__ = ["AdmissionConfig", "AdmissionController", "BreakerOpen",
           "CircuitBreaker", "Request", "RequestError", "ServeConfig",
           "ServeJournal", "ServeMetrics", "ServingEngine", "TenantSpec",
           "VirtualClock", "make_trace", "noisy_neighbor_mix",
           "serve_requests", "trace_digest", "uniform_mix"]
