from .engine import (Request, RequestError, ServeConfig, ServingEngine,
                     serve_requests)

__all__ = ["Request", "RequestError", "ServeConfig", "ServingEngine",
           "serve_requests"]
