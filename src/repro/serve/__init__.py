from .engine import Request, ServeConfig, ServingEngine, serve_requests

__all__ = ["Request", "ServeConfig", "ServingEngine", "serve_requests"]
