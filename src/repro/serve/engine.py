"""Serving engine: continuous batching built on TAPA channels.

This subsystem uses the paper's two motivating APIs *as motivated*:

* **Transactions (EoT)** — one request's prompt tokens form one transaction
  on the request channel: the frontend writes the tokens then ``close()``s;
  the scheduler drains ``for tok in stream`` until EoT.  Variable-length
  prompts need no length header and no sentinel values inside the token
  domain (paper Listing 2's exact argument).

* **Peek** — the admission scheduler ``peek``s the request channel to see
  the *next* request's id without consuming it, admitting it only if a
  batch slot is free — the network-switch pattern from the paper's
  introduction (forward based on content *and* availability, no manual
  buffer-and-state-machine).

The decode loop itself is a jit'd ``decode_step`` over a fixed batch of
slots (continuous batching: finished slots are refilled without draining
the batch).  The whole engine runs under the coroutine simulator for tests
and examples; on a pod the same task graph drives the compiled step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import channel, task
from ..core.engines import ENGINES


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_new: int = 8


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4          # concurrent decode slots
    max_seq: int = 128
    eos_token: int = -1           # -1: only stop on max_new


class ServingEngine:
    """Continuous-batching engine over a (prefill_fn, decode_fn) pair.

    ``prefill_fn(tokens[B,S]) -> (logits[B,V], cache)`` and
    ``decode_fn(token[B], cache) -> (logits[B,V], cache)`` — typically the
    jit'd model steps; tests may pass toy closures.
    """

    def __init__(self, scfg: ServeConfig, prefill_fn: Callable,
                 decode_fn: Callable, pad_token: int = 0):
        self.scfg = scfg
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.pad = pad_token
        self._aot_prefill: dict = {}       # (B, S) -> executable
        self._aot_decode: Optional[tuple] = None   # (aval sig, executable)

    # -- warmup through the persistent compile cache --------------------------

    def warmup(self, prompt_len: int = 8, cache=None) -> dict:
        """AOT-compile prefill/decode through the compile cache.

        The first request a serving process sees should not pay an XLA
        compile: warmup resolves both steps from the content-addressed
        store (populated by any previous process running the same model
        and shapes) and pins the executables for the decode loop.  Toy
        engines whose step functions are not jittable fall back to eager
        with ``{"ok": False}`` — warmup never breaks serving.
        """
        from ..core.compile_cache import aval_signature, default_cache
        cc = cache if cache is not None else default_cache()
        toks = np.zeros((1, prompt_len), np.int32)
        try:
            pre, src_p = cc.compile_cached(self.prefill_fn, (toks,))
            _, kv = pre(toks)
            tok = np.zeros((1,), np.int32)
            dec, src_d = cc.compile_cached(self.decode_fn, (tok, kv))
        except Exception as e:  # noqa: BLE001 - non-jittable step fns
            return {"ok": False, "reason": repr(e)[:200]}
        self._aot_prefill[(1, prompt_len)] = pre
        self._aot_decode = (aval_signature((tok, kv), {}), dec)
        return {"ok": True, "prefill": src_p, "decode": src_d}

    # -- task bodies ---------------------------------------------------------

    def frontend(self, requests: list, req_out) -> None:
        """Write each request as one EoT-delimited transaction:
        [rid, max_new, tok0, tok1, ...] <EoT>."""
        for r in requests:
            req_out.write(("hdr", r.rid, r.max_new))
            for t in r.prompt:
                req_out.write(("tok", t))
            req_out.close()
        # final empty transaction marks shutdown
        req_out.close()

    def scheduler(self, req_in, out_chan) -> None:
        """Admission + continuous batch decode."""
        scfg = self.scfg
        slots: list[Optional[dict]] = [None] * scfg.batch_slots
        shutdown = False

        while True:
            # Admit: peek the head of the request stream; only consume when
            # a slot is actually free (paper's switch pattern).
            while not shutdown:
                free = next((i for i, s in enumerate(slots) if s is None),
                            None)
                if free is None:
                    break
                ok, is_eot = req_in.try_eot()
                if ok and is_eot:          # empty transaction = shutdown
                    req_in.open()
                    shutdown = True
                    break
                ok, head = req_in.try_peek()
                if not ok:
                    if any(s is not None for s in slots):
                        break              # keep decoding while we wait
                    # idle: block until something arrives
                    if req_in.eot():
                        req_in.open()
                        shutdown = True
                        break
                    head = req_in.peek()
                # consume one whole transaction
                kind, rid, max_new = req_in.read()
                assert kind == "hdr"
                prompt = [t for (_, t) in iter(req_in)]
                slots[free] = {"rid": rid, "prompt": prompt,
                               "max_new": max_new, "new": []}

            live = [s for s in slots if s is not None]
            if not live:
                if shutdown:
                    break
                continue

            self._step_batch(slots)

            # retire finished slots (emit one transaction per request)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                done = len(s["new"]) >= s["max_new"] or (
                    self.scfg.eos_token >= 0 and s["new"]
                    and s["new"][-1] == self.scfg.eos_token)
                if done:
                    out_chan.write(("hdr", s["rid"]))
                    for t in s["new"]:
                        out_chan.write(("tok", int(t)))
                    out_chan.close()
                    slots[i] = None
        out_chan.close()                   # shutdown transaction

    def _step_batch(self, slots: list) -> None:
        """One prefill-or-decode step over the packed batch."""
        # prefill any slot that has no cache yet (one at a time keeps the
        # toy engine simple; batched prefill is a straightforward extension)
        for s in slots:
            if s is not None and "cache" not in s:
                toks = np.asarray(s["prompt"], np.int32)[None, :]
                prefill = self._aot_prefill.get(toks.shape,
                                                self.prefill_fn)
                logits, cache = prefill(toks)
                s["cache"] = cache
                s["next"] = int(np.argmax(np.asarray(logits)[0]))
                s["new"].append(s["next"])
                # decide the AOT-vs-eager decode path once per slot, not
                # per token (the kv signature is fixed after prefill)
                if self._aot_decode is not None:
                    from ..core.compile_cache import aval_signature
                    sig, exe = self._aot_decode
                    tok0 = np.zeros((1,), np.int32)
                    s["aot_decode"] = exe if aval_signature(
                        (tok0, cache), {}) == sig else None
        # decode all live slots (packed batch; a production engine packs
        # caches — here each slot decodes its own cache)
        for s in slots:
            if s is None or len(s["new"]) >= s["max_new"]:
                continue
            tok = np.asarray([s["next"]], np.int32)
            decode = s.get("aot_decode") or self.decode_fn
            try:
                logits, s["cache"] = decode(tok, s["cache"])
            except (TypeError, ValueError):
                # a decode_fn that reshapes its cache mid-stream falls off
                # the AOT fast path instead of erroring
                if decode is self.decode_fn:
                    raise
                s["aot_decode"] = None
                logits, s["cache"] = self.decode_fn(tok, s["cache"])
            s["next"] = int(np.argmax(np.asarray(logits)[0]))
            s["new"].append(s["next"])

    def collector(self, out_in, results: dict) -> None:
        while True:
            if out_in.eot():               # shutdown transaction
                out_in.open()
                break
            kind, rid = out_in.read()
            assert kind == "hdr"
            results[rid] = [t for (_, t) in iter(out_in)]

    # -- top ------------------------------------------------------------------

    def top(self, requests: list, results: dict) -> None:
        req = channel(capacity=16, name="requests")
        out = channel(capacity=16, name="outputs")
        task() \
            .invoke(self.frontend, requests, req) \
            .invoke(self.scheduler, req, out) \
            .invoke(self.collector, out, results)


def serve_requests(engine: ServingEngine, requests: list,
                   sim_engine: str = "coroutine") -> dict:
    """One-call host API for serving (paper Section 3.1.4)."""
    results: dict = {}
    rep = ENGINES[sim_engine]().run(engine.top, requests, results)
    if not rep.ok:
        raise RuntimeError(f"serving failed: {rep.error}")
    return results
