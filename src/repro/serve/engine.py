"""Serving engine: continuous batching built on TAPA channels.

This subsystem uses the paper's two motivating APIs *as motivated*:

* **Transactions (EoT)** — one request's prompt tokens form one transaction
  on the request channel: the frontend writes the tokens then ``close()``s;
  the scheduler drains the stream until EoT.  Variable-length prompts need
  no length header and no sentinel values inside the token domain (paper
  Listing 2's exact argument).

* **Peek** — the admission scheduler ``peek``s the request channel to see
  the *next* request's header without consuming it, admitting it only if a
  batch slot is free — the network-switch pattern from the paper's
  introduction (forward based on content *and* availability, no manual
  buffer-and-state-machine).

Two decode paths share the scheduler:

* **Batched fast path** (``batched=`` a :class:`~repro.models.lm.
  ServingAdapter`): ONE jitted decode step per iteration regardless of
  live slot count.  All slots live in one packed KV cache ``[.., slots,
  ..]`` with a per-slot ``len`` vector; admission runs bucketed batched
  prefill and writes rows into slots (donated buffers, in-place under
  XLA); retirement zeroes ``len``; sampling happens on device so the host
  fetches one ``[slots]`` int32 array per step.  Every shape resolves
  through the persistent compile cache, so a warm process pays zero XLA
  compiles (see ``warmup``).

* **Per-slot fallback** (``prefill_fn``/``decode_fn`` closures): the seed
  path — one call per live slot per token, host argmax.  Kept for toy
  engines, recurrent families (whose prefill cannot pad), and as the
  baseline that ``benchmarks/serve_time.py`` measures the fast path
  against.

See docs/serving.md for the packed-cache layout and bucket policy, and
docs/robustness.md for the failure model.

Robustness (chaos-harness contract)
-----------------------------------

A serving process must degrade, not crash.  The failure surface and the
response to each, from least to most severe:

* **Transient step failure** — :class:`~repro.core.errors.TransientFault`
  (injected by the chaos harness before the step executes): retried with
  exponential backoff up to ``ServeConfig.max_retries`` times; retries are
  recorded in :attr:`ServingEngine.retry_log`.
* **Poisoned request** — :class:`~repro.core.errors.PoisonError` raised
  *before* the step function runs (donated buffers untouched): only the
  poisoned request is quarantined — it gets a :class:`RequestError` result
  and its slot is retired; everything else keeps decoding.
* **Per-slot deadline / cancellation** — a request past its
  ``deadline_s`` or cancelled by the fault plan is retired with a
  structured :class:`RequestError`; its partial output is dropped, its
  slot freed.
* **Unattributable batched failure** — the one jitted step covers every
  slot and donates the packed cache, so a real exception from inside it
  cannot be pinned on one request: every live request gets a
  :class:`RequestError` and the packed cache is rebuilt from scratch.
* **Batched path unavailable** — warmup or the pre-flight step
  resolution fails: the scheduler degrades to the per-slot path when the
  closures exist (the ladder is batched -> per-slot -> refuse).
* **Preemption** — a ``stop_flag`` (wired to
  :class:`~repro.ft.PreemptionGuard` by ``launch/serve.py``) makes the
  scheduler reject all queued/future admissions with ``"preempted"``
  errors, finish the in-flight slots, flush results and exit clean.

Overload (PR 8)
---------------

Sustained offered load above capacity is handled *before* compute is
spent on it (docs/serving.md, Overload section):

* an :class:`~repro.serve.admission.AdmissionController` (``admission=``)
  fronts the request channel with per-tenant fair queuing and cost-aware
  load shedding; every shed is a journaled, structured
  ``RequestError("overloaded", retry_after_s=...)`` deposited straight
  into ``results`` — the frontend never blocks indefinitely;
* without a controller, ``ServeConfig.admit_timeout_s`` bounds how long
  the direct frontend waits on a full request channel before failing
  fast the same way (thread engine; cooperative engines hand off);
* a :class:`~repro.serve.admission.CircuitBreaker` (``breaker=``) gates
  every ``_call_step``: consecutive step failures open it, further calls
  fast-fail with ``"overloaded"`` results while open, a half-open probe
  closes it again;
* traffic-paced runs (requests carrying ``t_arrival``, from
  ``serve/traffic.py``) run in one of two pacing modes: ``pace="wall"``
  sleeps to real arrival times under the thread engine, while
  ``pace="virtual"`` couples a :class:`~repro.serve.traffic.VirtualClock`
  to the decode loop through a capacity-1 tick channel — the scheduler
  advances time by ``step_dt`` per step and the frontend blocks on ticks
  until the next arrival is due, so the whole overload run (arrivals,
  queue dynamics, sheds, deadline violations) is a deterministic
  function of (traffic seed, fault seed, config).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import channel, task
from ..core.engines import ENGINES
from ..core.errors import PoisonError, TransientFault


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_new: int = 8
    deadline_s: Optional[float] = None   # latency budget (see t_arrival)
    tenant: str = "default"              # fair-queuing / metrics key
    # arrival timestamp (trace-relative seconds) set by serve/traffic.py;
    # when present, deadlines anchor at arrival (queueing time counts),
    # otherwise at slot admission (the pre-PR8 behaviour)
    t_arrival: Optional[float] = None


@dataclasses.dataclass
class RequestError:
    """Structured failure result for one request (collector value).

    ``status`` is one of ``"poisoned"``, ``"deadline"``, ``"cancelled"``,
    ``"preempted"``, ``"overloaded"``, ``"error"``; ``detail`` is
    human-readable context.  ``retry_after_s`` is set on overload sheds
    and breaker fast-fails: the client's backoff hint.  A request either
    yields a token list or a RequestError — never a silent absence from
    ``results``.
    """
    rid: int
    status: str
    detail: str = ""
    retry_after_s: Optional[float] = None


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4          # concurrent decode slots
    max_seq: int = 128
    eos_token: int = -1           # -1: only stop on max_new
    prefill_buckets: tuple = ()   # () = powers of two from 8 to max_seq
    queue_cap: int = 16           # bounded admission queue (channel capacity)
    max_retries: int = 2          # per step-call retry budget (transients)
    retry_base_s: float = 0.0     # exponential-backoff base (0: no sleep)
    retry_max_s: float = 1.0      # cap on TOTAL backoff per step call
    # direct-frontend bound on waiting for a full request channel before
    # failing fast with "overloaded" (None: block, the seed behaviour).
    # Honoured under the preemptive thread engine; cooperative engines
    # hand off on the blocking write instead.
    admit_timeout_s: Optional[float] = None


def _default_buckets(max_seq: int) -> tuple:
    out, b = [], 8
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


def _pow2_at_least(n: int, cap: int) -> int:
    b = 1
    while b < n and b < cap:
        b *= 2
    return min(b, cap)


class ServingEngine:
    """Continuous-batching engine over a model's serving step functions.

    Per-slot mode: ``prefill_fn(tokens[B,S]) -> (logits[B,V], cache)`` and
    ``decode_fn(token[B], cache) -> (logits[B,V], cache)`` — typically the
    jit'd model steps; tests may pass toy closures.

    Batched mode: pass ``batched=lm.serving_adapter(...)`` instead; the
    step functions are compiled through the persistent compile cache and
    the decode loop runs one jitted call per step for all slots.
    """

    def __init__(self, scfg: ServeConfig, prefill_fn: Callable = None,
                 decode_fn: Callable = None, pad_token: int = 0,
                 batched: Any = None, faults: Any = None,
                 stop_flag: Callable = None, journal: Any = None,
                 admission: Any = None, metrics: Any = None,
                 breaker: Any = None, clock: Callable = None,
                 pace: Optional[str] = None, step_dt: float = 0.0):
        self.scfg = scfg
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.pad = pad_token
        self.batched = batched
        if batched is None and (prefill_fn is None or decode_fn is None):
            raise ValueError("need prefill_fn/decode_fn or batched=adapter")
        # chaos harness (repro.core.faults): poisoned/cancelled requests and
        # transient step failures; None in normal operation
        if faults is not None and not hasattr(faults, "serving_check"):
            faults = faults.injector()
        self.faults = faults
        # preemption: callable polled once per scheduler iteration; True ->
        # reject queued admissions, finish live slots, exit clean
        self.stop_flag = stop_flag
        # write-ahead serving journal (repro.serve.journal.ServeJournal or
        # a path): admission/token/retire records, fsync'd before the
        # corresponding effect is externally visible.  A restarted process
        # answers already-retired rids straight from the journal and
        # re-admits in-flight rids at their last journaled position —
        # exactly-once results across SIGKILL (docs/robustness.md).
        if journal is not None and not hasattr(journal, "retire"):
            from .journal import ServeJournal
            journal = ServeJournal(journal)
        self.journal = journal
        # -- overload layer (PR 8) ----------------------------------------
        # one clock for the whole stack: time.perf_counter in production,
        # a traffic.VirtualClock for deterministic simulated-time runs
        self.clock = clock or time.perf_counter
        # pacing for traffic-timed runs: None (legacy frontend), "wall"
        # (sleep to real arrival times, thread engine), or "virtual"
        # (tick-channel coupling, cooperative engines)
        if pace not in (None, "wall", "virtual"):
            raise ValueError(f"unknown pace {pace!r}")
        self.pace = pace
        self.step_dt = step_dt             # simulated seconds per decode step
        self.metrics = metrics             # admission.ServeMetrics or None
        if metrics is not None:
            metrics.clock = self.clock
        self.admission = admission         # admission.AdmissionController
        if admission is not None:
            if admission.journal is None:
                admission.journal = self.journal
            if admission.metrics is None:
                admission.metrics = self.metrics
            admission.clock = self.clock
        self.breaker = breaker             # admission.CircuitBreaker
        self.retry_log: list = []          # (site, attempt, error) tuples
        self.degraded: Optional[tuple] = None   # ("per-slot", reason) or None
        self._aot_prefill: dict = {}       # (B, S) -> executable
        self._aot_decode: Optional[tuple] = None   # (aval sig, executable)
        # batched mode: executables by shape key + where each came from
        self._exe: dict = {}
        self._cc = None
        self.compile_log: list = []        # (kind, shape, source) tuples

    def buckets(self) -> tuple:
        return self.scfg.prefill_buckets or _default_buckets(
            self.scfg.max_seq)

    # -- warmup through the persistent compile cache --------------------------

    def warmup(self, prompt_len: int = 8, cache=None,
               batch_sizes: tuple = (1,)) -> dict:
        """AOT-compile the serving steps through the compile cache.

        The first request a serving process sees should not pay an XLA
        compile: warmup resolves the steps from the content-addressed
        store (populated by any previous process running the same model
        and shapes) and pins the executables for the decode loop.

        Batched mode resolves one prefill executable per (batch-size,
        bucket) — ``batch_sizes`` x ``buckets()`` — plus the packed decode
        step, and reports the source of each (``compiled`` vs ``memory``/
        ``disk``).  Per-slot mode keeps the seed behaviour: a single
        ``(1, prompt_len)`` prefill plus the decode signature probe, and
        toy engines whose step functions are not jittable fall back to
        eager with ``{"ok": False}`` — warmup never breaks per-slot
        serving.  A batched adapter has no eager path: ``{"ok": False}``
        there means serving itself would fail the same way, so the caller
        should fall back to a per-slot engine (``launch/serve.py`` does).
        """
        from ..core.compile_cache import aval_signature, default_cache
        cc = cache if cache is not None else default_cache()
        if self.batched is not None:
            rep = self._warmup_batched(cc, batch_sizes)
            if rep.get("ok") or self.prefill_fn is None \
                    or self.decode_fn is None:
                return rep
            # degradation ladder: batched -> per-slot.  An engine built
            # with BOTH the adapter and the closures degrades here instead
            # of making the caller rebuild it (launch/serve.py still
            # handles the adapter-only {"ok": False} by rebuilding).
            self.degraded = ("per-slot", rep.get("reason", ""))
            self.batched = None
        toks = np.zeros((1, prompt_len), np.int32)
        try:
            pre, src_p = cc.compile_cached(self.prefill_fn, (toks,),
                                           extra=self._key_salt())
            _, kv = pre(toks)
            tok = np.zeros((1,), np.int32)
            dec, src_d = cc.compile_cached(self.decode_fn, (tok, kv),
                                           extra=self._key_salt())
        except Exception as e:  # noqa: BLE001 - non-jittable step fns
            return {"ok": False, "reason": repr(e)[:200]}
        self._aot_prefill[(1, prompt_len)] = pre
        self._aot_decode = (aval_signature((tok, kv), {}), dec)
        return {"ok": True, "prefill": src_p, "decode": src_d}

    def _warmup_batched(self, cc, batch_sizes: tuple) -> dict:
        self._cc = cc
        report: dict = {"ok": True, "buckets": {}, "decode": None}
        try:
            for L in self.buckets():
                for bk in batch_sizes:
                    _, src = self._resolve_prefill(bk, L)
                    report["buckets"][f"{bk}x{L}"] = src
            _, src = self._resolve_step()
            report["decode"] = src
            # the small slot-maintenance executables, so the first
            # admission wave pays zero compiles of any size
            for bk in batch_sizes:
                self._resolve_write(bk)
            self._resolve_retire()
        except Exception as e:  # noqa: BLE001 - keep serving alive
            return {"ok": False, "reason": repr(e)[:200]}
        return report

    # -- batched-mode executable resolution -----------------------------------

    def _cache(self):
        if self._cc is None:
            from ..core.compile_cache import default_cache
            self._cc = default_cache()
        return self._cc

    @staticmethod
    def _key_salt():
        """Env-selected kernel dispatch is baked into the traced decode
        program (kernels/ops.decode_attention), so it must be part of the
        cache key for every serving executable — and only for those, so
        flipping it never invalidates unrelated cache entries."""
        import os
        return ("decode-attn", os.environ.get("REPRO_DECODE_ATTN", ""))

    def _resolve_prefill(self, bk: int, L: int):
        """Executable for the (bk, L) prefill bucket, via the compile
        cache (disk hit in a warm process, one XLA compile otherwise)."""
        key = ("prefill", bk, L)
        if key in self._exe:
            return self._exe[key], "pinned"
        sds = jax.ShapeDtypeStruct
        args = (sds((bk, L), jnp.int32), sds((bk,), jnp.int32),
                sds((), jnp.int32))
        exe, src = self._cache().compile_cached(self.batched.prefill_fn,
                                                args,
                                                extra=self._key_salt())
        self._exe[key] = exe
        self.compile_log.append(("prefill", (bk, L), src))
        return exe, src

    def _resolve_step(self):
        key = ("step",)
        if key in self._exe:
            return self._exe[key], "pinned"
        sds = jax.ShapeDtypeStruct
        slots = self.scfg.batch_slots
        packed = self.batched.init_slots(slots, abstract=True)
        args = (sds((slots,), jnp.int32), packed, sds((), jnp.int32))
        exe, src = self._cache().compile_cached(
            self.batched.step_fn, args, extra=self._key_salt(),
            jit_kwargs={"donate_argnums": (1,)})
        self._exe[key] = exe
        self.compile_log.append(("decode_step", (slots,), src))
        return exe, src

    def _resolve_write(self, bk: int):
        key = ("write", bk)
        if key in self._exe:
            return self._exe[key]
        sds = jax.ShapeDtypeStruct
        slots = self.scfg.batch_slots
        packed = self.batched.init_slots(slots, abstract=True)
        cache = jax.eval_shape(
            lambda t, n: self.batched.prefill_fn(t, n, jnp.int32(0))[1],
            sds((bk, self.scfg.max_seq), jnp.int32), sds((bk,), jnp.int32))
        args = (packed, cache, sds((), jnp.int32), sds((), jnp.int32))
        exe, src = self._cache().compile_cached(
            self.batched.write_slot_fn, args,
            jit_kwargs={"donate_argnums": (0,)})
        self._exe[key] = exe
        self.compile_log.append(("write_slot", (bk,), src))
        return exe

    def _resolve_retire(self):
        key = ("retire",)
        if key in self._exe:
            return self._exe[key]
        sds = jax.ShapeDtypeStruct
        packed = self.batched.init_slots(self.scfg.batch_slots,
                                         abstract=True)
        exe, src = self._cache().compile_cached(
            self.batched.retire_fn, (packed, sds((), jnp.int32)),
            jit_kwargs={"donate_argnums": (0,)})
        self._exe[key] = exe
        self.compile_log.append(("retire", (), src))
        return exe

    # -- task bodies ---------------------------------------------------------

    def _write_req(self, req_out, r) -> None:
        """One request as one EoT-delimited transaction:
        [hdr(rid, max_new, deadline, tenant, t_arr), tok0, ...] <EoT>."""
        req_out.write(("hdr", r.rid, r.max_new,
                       getattr(r, "deadline_s", None),
                       getattr(r, "tenant", "default"),
                       getattr(r, "t_arrival", None)))
        req_out.write_burst([("tok", t) for t in r.prompt])
        req_out.close()

    def _offer_direct(self, req_out, r, results) -> bool:
        """Write one request transaction, failing fast on a full channel.

        With ``admit_timeout_s`` unset this is the seed behaviour: the
        write blocks until the scheduler drains (a cooperative hand-off
        under run-to-block engines).  With it set and the channel full,
        the frontend waits at most that long, then sheds the request with
        a journaled ``RequestError("overloaded")`` instead of blocking
        the producer indefinitely.  Returns True iff the request was
        written."""
        tmo = self.scfg.admit_timeout_s
        if tmo is not None and results is not None and req_out.full():
            give_up = time.monotonic() + tmo
            while req_out.full() and time.monotonic() < give_up:
                time.sleep(min(0.002, max(tmo * 0.25, 1e-4)))
            if req_out.full():
                detail = (f"request queue full "
                          f"(cap {self.scfg.queue_cap}) for {tmo}s")
                if self.journal is not None:
                    self.journal.shed(r.rid, detail=detail)
                if self.metrics is not None:
                    self.metrics.note_shed(
                        getattr(r, "tenant", "default"), "queue-full")
                results[r.rid] = RequestError(r.rid, "overloaded", detail,
                                              retry_after_s=tmo)
                return False
        self._write_req(req_out, r)
        return True

    def frontend(self, requests: list, req_out, results: dict = None) -> None:
        """Direct (un-paced) frontend: requests are offered back-to-back."""
        for r in requests:
            if self.metrics is not None:
                self.metrics.note_offered(getattr(r, "tenant", "default"))
            if self._offer_direct(req_out, r, results) \
                    and self.metrics is not None:
                self.metrics.note_admitted(getattr(r, "tenant", "default"))
        # final empty transaction marks shutdown
        req_out.close()

    # -- traffic-paced frontend (overload path) --------------------------------

    def _deliver(self, results: dict, rid: int, done) -> None:
        """Deposit a journal-replayed result (exactly-once, no recompute)."""
        if isinstance(done, tuple):
            results[rid] = RequestError(rid, done[0], done[1])
        else:
            results[rid] = list(done)

    @staticmethod
    def _drain_ticks(tick_in) -> None:
        """Consume pending ticks before a potentially-blocking request
        write.  This is the virtual-pacing deadlock guard: it guarantees
        an idle scheduler's blocking tick write (:meth:`_timed_idle`) has
        space to complete, so the scheduler is always runnable to consume
        whatever the frontend is about to write."""
        if tick_in is not None:
            while tick_in.try_read()[0]:
                pass

    def _pump(self, req_out, results: dict, tick_in=None,
              drain: bool = False) -> None:
        """Move dispatchable requests from the admission controller into
        the request channel, in fair-queue order.  Normally stops at a
        full channel (the backlog stays in the controller where it can
        still be shed); ``drain=True`` pushes everything through with
        blocking writes (end of trace — the scheduler is consuming)."""
        ctrl = self.admission
        if ctrl is None:
            return
        while True:
            for e in ctrl.drain_errors():      # dispatch-time sheds
                results[e.rid] = e
            if not drain and req_out.full():
                return
            r = ctrl.pop()
            if r is None:
                break
            self._drain_ticks(tick_in)
            self._write_req(req_out, r)
        for e in ctrl.drain_errors():
            results[e.rid] = e

    def _offer_timed(self, r, req_out, results: dict, tick_in=None) -> None:
        ctrl = self.admission
        if ctrl is None:
            if self.metrics is not None:
                self.metrics.note_offered(r.tenant)
            self._drain_ticks(tick_in)
            if self._offer_direct(req_out, r, results) \
                    and self.metrics is not None:
                self.metrics.note_admitted(r.tenant)
            return
        verdict = ctrl.offer(r)
        if verdict is None:
            return                             # queued; _pump dispatches
        if isinstance(verdict, RequestError):
            results[verdict.rid] = verdict     # shed at offer
        else:                                  # ("replayed", done)
            self._deliver(results, r.rid, verdict[1])

    def traffic_frontend(self, trace: list, req_out, tick_in,
                         results: dict) -> None:
        """Open-loop frontend: release each request at its ``t_arrival``.

        Wall pacing sleeps to real arrival times (thread engine).
        Virtual pacing blocks on the tick channel until the scheduler —
        which advances the shared VirtualClock by ``step_dt`` per decode
        step, or fast-forwards to ``clock.next_event`` when idle — has
        moved simulated time past the next arrival.  Arrival timestamps
        are rebased onto the engine clock (``t_start``), so deadlines and
        TTFT anchor at *arrival*, queueing time included.
        """
        virtual = self.pace == "virtual" and tick_in is not None
        t_start = self.clock()
        for r in trace:
            t_abs = t_start + (r.t_arrival or 0.0)
            if virtual:
                self.clock.next_event = t_abs
                while self.clock() < t_abs:
                    tick_in.read()             # cooperative hand-off
                self.clock.next_event = None
            else:
                wait = t_abs - self.clock()
                if wait > 0:
                    time.sleep(wait)
            self._offer_timed(dataclasses.replace(r, t_arrival=t_abs),
                              req_out, results, tick_in)
            self._pump(req_out, results, tick_in)
        self._pump(req_out, results, tick_in, drain=True)
        self._drain_ticks(tick_in)             # unblock a mid-write scheduler
        req_out.close()                        # shutdown transaction
        self._drain_ticks(tick_in)

    # -- admission (shared by both paths) -------------------------------------

    def _admit_one(self, req_in, can_wait: bool):
        """Try to consume one whole request transaction.

        The caller guarantees a free slot, so admission is the paper's
        switch pattern: ``peek`` the header to inspect the pending request,
        then consume it — the peeked value IS the header (no double read).
        Returns ``("req", rid, max_new, prompt)``, ``("shutdown",)``, or
        ``("none",)`` when nothing is pending and ``can_wait`` is False.

        With ``can_wait=True`` (no live slot, nothing else to do) this
        *blocks* on the channel — a cooperative engine hand-off, not a
        busy poll of ``try_*`` in a spin loop.
        """
        avail, is_eot = req_in.try_eot()
        if not avail:
            if not can_wait:
                return ("none",)
            is_eot = req_in.eot()          # block until the next transaction
        if is_eot:                          # empty transaction = shutdown
            req_in.open()
            return ("shutdown",)
        kind, rid, max_new, deadline, tenant, t_arr = req_in.peek()
        assert kind == "hdr", kind
        req_in.read()                       # consume the peeked header
        prompt = [t for (_, t) in req_in.read_transaction()]
        # normalize: empty prompts decode from a single pad token; overlong
        # prompts keep their most recent max_seq-1 tokens so one decode
        # position remains
        prompt = (prompt or [self.pad])[-(self.scfg.max_seq - 1):]
        return ("req", rid, max_new, prompt, deadline, tenant, t_arr)

    def _emit(self, out_chan, rid: int, new: list, slot: dict = None) -> None:
        if self.metrics is not None and slot is not None:
            self.metrics.note_done(slot.get("tenant", "default"),
                                   slot.get("t_arr"), slot.get("t_first"),
                                   len(new))
        if self.journal is not None:
            # write-ahead: the retire record hits disk before the result
            # transaction exists, so a crash in between re-delivers from
            # the journal instead of losing the finished request
            self.journal.retire(rid, toks=[int(t) for t in new])
        out_chan.write(("hdr", rid))
        out_chan.write_burst([("tok", int(t)) for t in new])
        out_chan.close()

    def _emit_err(self, out_chan, rid: int, status: str,
                  detail: str = "", slot: dict = None,
                  retry_after: Optional[float] = None) -> None:
        """One error transaction; the collector turns it into a
        :class:`RequestError` result."""
        if retry_after is None and status == "overloaded" \
                and self.breaker is not None:
            retry_after = self.breaker.retry_after()   # client backoff hint
        if self.metrics is not None and slot is not None:
            self.metrics.note_failed(slot.get("tenant", "default"), status)
        if self.journal is not None:
            self.journal.retire(rid, status=status, detail=detail)
        out_chan.write(("err", rid, status, detail, retry_after))
        out_chan.close()

    def _note_tok(self, s: dict, t: int) -> None:
        """Append one emitted token to a slot, journaling it first — the
        single funnel for every token either decode path produces."""
        if "t_first" not in s:
            s["t_first"] = self.clock()    # TTFT stamp (first real token)
        if self.journal is not None:
            self.journal.tok(s["rid"], t)
        s["new"].append(t)

    # -- hardening helpers -----------------------------------------------------

    def _backoff(self, attempt: int, slept: float, slots) -> float:
        """One retry backoff sleep; returns the seconds actually slept.

        The exponential term is clamped two ways: ``retry_max_s`` caps
        the *total* backoff for one step call (the seed's uncapped
        ``base * 2**attempt`` could stall the whole batched decode loop),
        and no sleep ever extends past the earliest remaining deadline
        among the live slots — backing off for one slot's transient must
        not blow every neighbour's budget."""
        dt = self.scfg.retry_base_s * 2 ** attempt
        dt = min(dt, max(0.0, self.scfg.retry_max_s - slept))
        if slots:
            now = self.clock()
            for s in slots:
                if s is None or s.get("deadline") is None:
                    continue
                anchor = s["t_arr"] if s.get("t_arr") is not None else s["t0"]
                dt = min(dt, max(0.0, s["deadline"] - (now - anchor)))
        if dt > 0:
            time.sleep(dt)
        return dt

    def _call_step(self, site: str, rids: list, fn, *args, slots=None):
        """Run one step function under the serving fault contract.

        Consults the circuit breaker and the fault injector *before*
        ``fn`` executes, so :class:`~repro.serve.admission.BreakerOpen`
        (fast-fail while the backend is suspect), :class:`PoisonError`
        (re-raised for the caller to quarantine) and
        :class:`TransientFault` (retried here with capped,
        deadline-aware backoff) all fire while any donated buffers in
        ``args`` are still valid.  Only *final* step outcomes reach the
        breaker: a retried transient that eventually succeeds counts as
        success.
        """
        if self.breaker is not None:
            self.breaker.check()           # may raise BreakerOpen
        slept = 0.0
        for attempt in range(self.scfg.max_retries + 1):
            try:
                if self.faults is not None:
                    self.faults.serving_check(site, rids)
                out = fn(*args)
            except PoisonError:
                raise                      # per-request, not a backend fault
            except TransientFault as e:
                self.retry_log.append((site, attempt, repr(e)))
                if attempt >= self.scfg.max_retries:
                    if self.breaker is not None:
                        self.breaker.failure(repr(e))
                    raise
                if self.scfg.retry_base_s > 0:
                    slept += self._backoff(attempt, slept, slots)
                continue
            except Exception as e:  # noqa: BLE001 - real backend failure
                if self.breaker is not None:
                    self.breaker.failure(repr(e))
                raise
            if self.breaker is not None:
                self.breaker.success()
            return out

    def _abnormal(self, s: dict) -> Optional[tuple]:
        """(status, detail) if the slot must be retired abnormally."""
        err = s.get("error")
        if err is not None:
            return err
        dl = s.get("deadline")
        # arrival-anchored when the request carries t_arrival (queueing
        # time counts against the budget), slot-admission-anchored (t0)
        # for legacy requests — the pre-PR8 contract
        anchor = s["t_arr"] if s.get("t_arr") is not None else s["t0"]
        if dl is not None and self.clock() - anchor > dl:
            return ("deadline", f"deadline {dl}s exceeded after "
                                f"{len(s['new'])} tokens")
        if self.faults is not None and \
                self.faults.cancelled(s["rid"], len(s["new"])):
            return ("cancelled", f"cancelled after {len(s['new'])} tokens")
        return None

    def _stop_requested(self) -> bool:
        return self.stop_flag is not None and bool(self.stop_flag())

    def _drain_reject(self, req_in, out_chan) -> None:
        """Preemption path: consume every queued/future request transaction
        up to the frontend's shutdown marker, answering each with a
        ``"preempted"`` error — the frontend never blocks on a full channel
        and the collector still sees one result per request."""
        while True:
            r = self._admit_one(req_in, can_wait=True)
            if r[0] == "shutdown":
                return
            if r[0] == "none":      # unreachable with can_wait=True
                continue
            self._emit_err(out_chan, r[1], "preempted",
                           "serving preempted; request rejected")

    def _finished(self, s: dict) -> bool:
        if len(s["new"]) >= s["max_new"]:
            return True
        eos = self.scfg.eos_token
        if eos >= 0 and s["new"] and s["new"][-1] == eos:
            return True
        # cache-capacity stop: the next decode would scatter at
        # prompt_len + len(new) - 1; retire one step early.  Journal-seeded
        # tokens are counted once — they are part of the re-prefilled
        # prompt AND of ``new`` — so subtract the overlap.
        return s["plen"] + len(s["new"]) - s.get("seeded", 0) \
            >= self.scfg.max_seq

    # -- scheduler -------------------------------------------------------------

    def scheduler(self, req_in, out_chan, tick_out=None) -> None:
        """Admission + continuous batch decode."""
        batched = self.batched is not None
        if batched:
            # pre-flight: resolving the packed decode step is the batched
            # path's single point of no return; if it fails and the
            # per-slot closures exist, degrade instead of dying with the
            # whole request queue unanswered
            try:
                self._resolve_step()
            except Exception as e:  # noqa: BLE001 - degrade, don't crash
                if self.prefill_fn is None or self.decode_fn is None:
                    raise
                self.degraded = ("per-slot", repr(e)[:200])
                batched = False
        if batched:
            self._scheduler_batched(req_in, out_chan, tick_out)
        else:
            self._scheduler_per_slot(req_in, out_chan, tick_out)
        out_chan.close()                   # shutdown transaction

    def _timed_idle(self, tick_out) -> None:
        """Idle under virtual pacing: hand simulated time to the frontend.

        Nothing is decoding, so the only pending event is the frontend's
        next arrival (``clock.next_event``): fast-forward to it and tick.
        The second, *blocking* tick write is the cooperative yield — the
        run-to-block engine switches to the frontend there, which reads
        the tick, sees its arrival due, and writes the next request."""
        clk = self.clock
        ne = getattr(clk, "next_event", None)
        if ne is not None and hasattr(clk, "advance_to"):
            clk.advance_to(ne)
        tick_out.try_write(clk())      # fill the capacity-1 channel...
        tick_out.write(clk())          # ...then block until it drains

    def _after_step(self, tick_out, t_wall0) -> None:
        """Per-decode-step bookkeeping: advance virtual time + tick, and
        feed the measured (or simulated) per-token latency to the
        admission controller's deadline-infeasibility estimator."""
        if tick_out is not None:
            self.clock.advance(self.step_dt)
            tick_out.try_write(self.clock())   # lossy: frontend may lag
            dt = self.step_dt
        else:
            dt = (time.perf_counter() - t_wall0) \
                if t_wall0 is not None else None
        if self.admission is not None and dt:
            self.admission.observe_token_latency(dt)

    def _mk_slot(self, rid, max_new, prompt, deadline,
                 tenant: str = "default", t_arr: Optional[float] = None,
                 seeded: Optional[list] = None) -> dict:
        """One decode-slot record.  ``seeded`` (journal replay) pre-loads
        tokens the crashed process already emitted: they join the prompt
        for the re-prefill — greedy decoding of a causal model then
        continues exactly where the journal left off — and pre-fill
        ``new`` so ``max_new`` / result accounting stay unchanged."""
        seeded = list(seeded or [])
        prompt = (list(prompt) + seeded)[-(self.scfg.max_seq - 1):]
        return {"rid": rid, "prompt": prompt, "plen": len(prompt),
                "max_new": max_new, "new": seeded, "seeded": len(seeded),
                "deadline": deadline, "tenant": tenant, "t_arr": t_arr,
                "t0": self.clock()}

    def _slot_for(self, r, out_chan) -> Optional[dict]:
        """Journal-aware slot construction for one admitted request.

        Returns None when no slot is needed: the rid already retired (its
        result re-emits straight from the journal — never recomputed), or
        the request finishes inline (``max_new <= 0``, or a journal-seeded
        slot that was already at its last token when the process died).
        Fresh rids are journaled *before* any compute happens for them.
        """
        _, rid, max_new, prompt, deadline, tenant, t_arr = r
        j = self.journal
        if j is not None:
            done = j.completed.get(rid)
            if done is not None:
                if isinstance(done, tuple):
                    self._emit_err(out_chan, rid, done[0], done[1])
                else:
                    self._emit(out_chan, rid, done)
                return None
            rec = j.inflight.pop(rid, None)
            if rec is not None:
                s = self._mk_slot(rid, rec["max_new"], rec["prompt"],
                                  rec.get("deadline"), tenant, t_arr,
                                  seeded=rec["toks"])
                if s["new"] and self._finished(s):
                    self._emit(out_chan, rid, s["new"], slot=s)
                    return None
                return s
            j.admit(rid, prompt, max_new, deadline)
        if max_new <= 0:
            self._emit(out_chan, rid, [],
                       slot={"tenant": tenant, "t_arr": t_arr})
            return None
        return self._mk_slot(rid, max_new, prompt, deadline, tenant, t_arr)

    def _scheduler_per_slot(self, req_in, out_chan, tick_out=None) -> None:
        scfg = self.scfg
        coop = tick_out is not None        # virtual pacing (tick coupling)
        slots: list[Optional[dict]] = [None] * scfg.batch_slots
        shutdown = False
        while True:
            if not shutdown and self._stop_requested():
                self._drain_reject(req_in, out_chan)
                shutdown = True
            # Admit while a slot is free; block only when fully idle
            # (under virtual pacing never block here — _timed_idle is the
            # yield point, so the frontend can still advance time).
            while not shutdown:
                free = next((i for i, s in enumerate(slots) if s is None),
                            None)
                if free is None:
                    break
                r = self._admit_one(
                    req_in, can_wait=not coop and not any(
                        s is not None for s in slots))
                if r[0] == "shutdown":
                    shutdown = True
                    break
                if r[0] == "none":
                    break
                s = self._slot_for(r, out_chan)
                if s is not None:
                    slots[free] = s

            live = [s for s in slots if s is not None]
            if not live:
                if shutdown:
                    break
                if coop:
                    self._timed_idle(tick_out)
                continue

            t_wall0 = time.perf_counter() \
                if (self.admission is not None and not coop) else None
            self._step_batch(slots)
            self._after_step(tick_out, t_wall0)

            # retire finished/failed slots (one transaction per request)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ab = self._abnormal(s)
                if ab is not None:
                    self._emit_err(out_chan, s["rid"], *ab, slot=s)
                    slots[i] = None
                elif self._finished(s):
                    self._emit(out_chan, s["rid"], s["new"], slot=s)
                    slots[i] = None

    def _do_prefill(self, s: dict) -> None:
        toks = np.asarray(s["prompt"], np.int32)[None, :]
        prefill = self._aot_prefill.get(toks.shape, self.prefill_fn)
        logits, cache = prefill(toks)
        s["cache"] = cache
        s["next"] = int(np.argmax(np.asarray(logits)[0]))
        self._note_tok(s, s["next"])
        # decide the AOT-vs-eager decode path once per slot, not
        # per token (the kv signature is fixed after prefill)
        if self._aot_decode is not None:
            from ..core.compile_cache import aval_signature
            sig, exe = self._aot_decode
            tok0 = np.zeros((1,), np.int32)
            s["aot_decode"] = exe if aval_signature(
                (tok0, cache), {}) == sig else None

    def _do_decode(self, s: dict) -> None:
        tok = np.asarray([s["next"]], np.int32)
        decode = s.get("aot_decode") or self.decode_fn
        try:
            logits, s["cache"] = decode(tok, s["cache"])
        except (TypeError, ValueError):
            # a decode_fn that reshapes its cache mid-stream falls off
            # the AOT fast path instead of erroring
            if decode is self.decode_fn:
                raise
            s["aot_decode"] = None
            logits, s["cache"] = self.decode_fn(tok, s["cache"])
        s["next"] = int(np.argmax(np.asarray(logits)[0]))
        self._note_tok(s, s["next"])

    def _step_slot(self, site: str, s: dict, fn) -> None:
        """One per-slot step with quarantine: a failing request marks only
        its own slot (``s["error"]``); neighbours keep decoding."""
        from .admission import BreakerOpen
        try:
            self._call_step(site, [s["rid"]], fn, s, slots=[s])
        except PoisonError as e:
            s["error"] = ("poisoned", str(e))
        except BreakerOpen as e:
            s["error"] = ("overloaded", str(e))
        except Exception as e:  # noqa: BLE001 - incl. exhausted transients
            s["error"] = ("error", repr(e)[:200])

    def _step_batch(self, slots: list) -> None:
        """One prefill-or-decode step over the live slots (per-slot path)."""
        # prefill any slot that has no cache yet
        for s in slots:
            if s is not None and "cache" not in s and "error" not in s:
                self._step_slot("prefill", s, self._do_prefill)
        # decode all live slots, one call per slot (the seed hot loop the
        # batched path replaces)
        for s in slots:
            if s is None or "error" in s or self._finished(s):
                continue
            self._step_slot("decode", s, self._do_decode)

    # -- batched fast path -----------------------------------------------------

    def _scheduler_batched(self, req_in, out_chan, tick_out=None) -> None:
        from .admission import BreakerOpen
        scfg = self.scfg
        coop = tick_out is not None        # virtual pacing (tick coupling)
        n = scfg.batch_slots
        slots: list[Optional[dict]] = [None] * n
        packed = self.batched.init_slots(n)
        step_exe, _ = self._resolve_step()
        retire_exe = self._resolve_retire()
        toks = np.zeros((n,), np.int32)    # reused host-side staging buffer
        shutdown = False
        step_i = 0

        while True:
            if not shutdown and self._stop_requested():
                self._drain_reject(req_in, out_chan)
                shutdown = True
            # -- admission: collect requests for every free slot ----------
            newly = []
            while not shutdown and sum(s is None for s in slots) > len(newly):
                r = self._admit_one(
                    req_in,
                    can_wait=not coop and not newly and not any(
                        s is not None for s in slots))
                if r[0] == "shutdown":
                    shutdown = True
                    break
                if r[0] == "none":
                    break
                s = self._slot_for(r, out_chan)
                if s is not None:
                    newly.append(s)
            if newly:
                packed, step_i = self._prefill_admit(newly, slots, packed,
                                                     step_i, out_chan)
                # a request can finish at prefill (max_new == 1 / eos)
                for i, s in enumerate(slots):
                    if s is not None and self._finished(s):
                        self._emit(out_chan, s["rid"], s["new"], slot=s)
                        packed = retire_exe(packed, np.int32(i))
                        slots[i] = None

            # -- retire deadline-blown / cancelled slots before stepping --
            for i, s in enumerate(slots):
                if s is None:
                    continue
                ab = self._abnormal(s)
                if ab is not None:
                    self._emit_err(out_chan, s["rid"], *ab, slot=s)
                    packed = retire_exe(packed, np.int32(i))
                    slots[i] = None

            if not any(s is not None for s in slots):
                if shutdown:
                    break
                if coop:
                    self._timed_idle(tick_out)
                continue

            # -- ONE jitted decode step for the whole slot array ----------
            toks.fill(0)
            for i, s in enumerate(slots):
                if s is not None:
                    toks[i] = s["next"]
            rids = [s["rid"] for s in slots if s is not None]
            t_wall0 = time.perf_counter() \
                if (self.admission is not None and not coop) else None
            try:
                nxt, packed = self._call_step("decode", rids, step_exe,
                                              toks, packed, np.int32(step_i),
                                              slots=slots)
            except PoisonError as e:
                # raised before the step executed, so the donated packed
                # cache is still valid: retire only the poisoned slot
                for i, s in enumerate(slots):
                    if s is not None and s["rid"] == e.rid:
                        self._emit_err(out_chan, e.rid, "poisoned", str(e),
                                       slot=s)
                        packed = retire_exe(packed, np.int32(i))
                        slots[i] = None
                continue
            except BreakerOpen as e:
                # also raised before the step executed (donated cache
                # valid): fast-fail every live request with a structured
                # overload error — no compute is spent while the backend
                # is suspect; the half-open probe will test recovery
                for i, s in enumerate(slots):
                    if s is not None:
                        self._emit_err(out_chan, s["rid"], "overloaded",
                                       str(e), slot=s)
                        packed = retire_exe(packed, np.int32(i))
                        slots[i] = None
                continue
            except Exception as e:  # noqa: BLE001 - unattributable failure
                # the one jitted step covers every slot and donated the
                # packed cache — the failure cannot be pinned on a single
                # request and the cache may be consumed.  Fail all live
                # requests with structured errors and rebuild the cache:
                # the scheduler survives to serve what is still queued.
                for i, s in enumerate(slots):
                    if s is not None:
                        self._emit_err(out_chan, s["rid"], "error",
                                       repr(e)[:200], slot=s)
                        slots[i] = None
                packed = self.batched.init_slots(n)
                continue
            step_i += 1
            self._after_step(tick_out, t_wall0)
            nxt = np.asarray(nxt)   # [slots] — the only per-step transfer

            for i, s in enumerate(slots):
                if s is None:
                    continue
                t = int(nxt[i])
                self._note_tok(s, t)
                s["next"] = t
                if self._finished(s):
                    self._emit(out_chan, s["rid"], s["new"], slot=s)
                    packed = retire_exe(packed, np.int32(i))
                    slots[i] = None

    def _prefill_admit(self, newly: list, slots: list, packed,
                       step_i: int, out_chan):
        """Bucketed batched prefill for a group of admitted requests.

        Prompts are right-padded to the smallest power-of-two bucket and
        same-bucket requests share one prefill call whose batch dimension
        is itself padded to a power of two — so the shape space stays
        bounded and every shape is a compile-cache key.  Returns
        ``(packed, step_i)``: the step counter advances once per prefill
        call so every sampler invocation folds a distinct key.

        A poisoned request is isolated here: it gets an error transaction
        and its group retries without it (PoisonError fires before the
        prefill executes, so nothing is torn).  A real prefill failure
        fails only the group sharing that call, never the whole wave.
        """
        buckets = self.buckets()
        groups: dict[int, list] = {}
        for s in newly:
            # a prompt longer than every configured bucket pads straight to
            # max_seq (admission already truncated it to max_seq - 1)
            L = next((b for b in buckets if b >= s["plen"]),
                     self.scfg.max_seq)
            groups.setdefault(L, []).append(s)
        free = iter(i for i, s in enumerate(slots) if s is None)
        for L, grp in sorted(groups.items()):
            while grp:
                bk = _pow2_at_least(len(grp), self.scfg.batch_slots)
                toks = np.full((bk, L), self.pad, np.int32)
                lens = np.zeros((bk,), np.int32)
                for row, s in enumerate(grp):
                    toks[row, :s["plen"]] = s["prompt"]
                    lens[row] = s["plen"]
                exe, _ = self._resolve_prefill(bk, L)
                rids = [s["rid"] for s in grp]
                try:
                    first, cache = self._call_step("prefill", rids, exe,
                                                   toks, lens,
                                                   np.int32(step_i),
                                                   slots=grp)
                except PoisonError as e:
                    bad = next(s for s in grp if s["rid"] == e.rid)
                    self._emit_err(out_chan, e.rid, "poisoned", str(e),
                                   slot=bad)
                    grp = [s for s in grp if s["rid"] != e.rid]
                    continue                # retry the group without it
                except Exception as e:  # noqa: BLE001 - group-level failure
                    from .admission import BreakerOpen
                    st = "overloaded" if isinstance(e, BreakerOpen) \
                        else "error"
                    for s in grp:
                        self._emit_err(out_chan, s["rid"], st,
                                       str(e) if st == "overloaded"
                                       else repr(e)[:200], slot=s)
                    break
                step_i += 1
                first = np.asarray(first)  # [bk] sampled on device
                write = self._resolve_write(bk)
                for row, s in enumerate(grp):
                    slot = next(free)
                    packed = write(packed, cache, np.int32(row),
                                   np.int32(slot))
                    s["next"] = int(first[row])
                    self._note_tok(s, s["next"])
                    slots[slot] = s
                break
        return packed, step_i

    def collector(self, out_in, results: dict) -> None:
        while True:
            if out_in.eot():               # shutdown transaction
                out_in.open()
                break
            hdr = out_in.read()
            if hdr[0] == "err":            # quarantined/rejected request
                _, rid, status, detail, retry_after = hdr
                for _ in out_in.read_transaction():
                    pass
                results[rid] = RequestError(rid, status, detail,
                                            retry_after_s=retry_after)
                continue
            kind, rid = hdr
            assert kind == "hdr"
            results[rid] = [t for (_, t) in out_in.read_transaction()]

    # -- top ------------------------------------------------------------------

    def top(self, requests: list, results: dict) -> None:
        cap = self.scfg.queue_cap          # bounded admission queue
        req = channel(capacity=cap, name="requests")
        out = channel(capacity=cap, name="outputs")
        # traffic-timed mode: requests carrying arrival times, an
        # admission controller, or an explicit pace select the paced
        # frontend; plain request lists keep the seed task graph
        timed = (self.admission is not None or self.pace is not None
                 or any(getattr(r, "t_arrival", None) is not None
                        for r in requests))
        if timed:
            tick = channel(capacity=1, name="ticks") \
                if self.pace == "virtual" else None
            task() \
                .invoke(self.traffic_frontend, requests, req, tick,
                        results) \
                .invoke(self.scheduler, req, out, tick) \
                .invoke(self.collector, out, results)
        else:
            task() \
                .invoke(self.frontend, requests, req, results) \
                .invoke(self.scheduler, req, out) \
                .invoke(self.collector, out, results)


def serve_requests(engine: ServingEngine, requests: list,
                   sim_engine: str = "coroutine", faults: Any = None,
                   watchdog_s: Optional[float] = None) -> dict:
    """One-call host API for serving (paper Section 3.1.4).

    ``faults`` (a FaultPlan or FaultInjector) arms BOTH the serving-level
    faults (poison/cancel/transient, via ``engine.faults``) and the
    channel/task-level faults of the simulation engine that hosts the
    serving task graph; ``watchdog_s`` bounds the whole run's wall clock
    with the unified deadlock watchdog.
    """
    results: dict = {}
    if faults is not None:
        if not hasattr(faults, "serving_check"):
            faults = faults.injector()
        engine.faults = faults
    rep = ENGINES[sim_engine](faults=faults,
                              watchdog_s=watchdog_s).run(
        engine.top, requests, results)
    if not rep.ok:
        raise RuntimeError(f"serving failed: {rep.error}")
    return results
