"""Checkpointing: atomic, sharded-aware, resumable, async-capable.

Survival requirements at pod scale:

* **Atomicity** — a half-written checkpoint must never be restorable: write
  into ``step_XXXX.tmp`` and ``os.rename`` at the end (rename is atomic on
  POSIX), with a ``DONE`` marker carrying a content manifest.
* **Restartability** — ``restore_latest`` scans for the newest complete
  step; corrupted/incomplete directories are skipped, so a job killed
  mid-save restarts from the previous good step.
* **Sharded arrays** — each process saves only the *addressable* shards of
  every jax.Array (single-controller CPU: that's the whole array; on a pod:
  its local shards), one ``.npy`` per leaf per shard-set, re-assembled and
  re-sharded at restore via ``jax.device_put`` with the target sharding.
* **Async** — ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes in a daemon thread, overlapping I/O with
  the next training steps; ``wait()`` joins before the next save.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

Params = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr)
    return buf.getvalue()


def _flatten_with_names(tree: Params) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name or "leaf", leaf))
    return out


def save_pytree(tree: Params, directory: Path) -> dict:
    """Write one pytree; returns the manifest.

    Each leaf's manifest entry records the sha256 of its ``.npy`` file
    bytes, and every file is read back and compared after writing
    (verify-after-write): a torn or silently failed write is caught here,
    while the data is still in memory, rather than at restore time.
    """
    directory.mkdir(parents=True, exist_ok=True)
    manifest = {}
    for name, leaf in _flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        data = _npy_bytes(arr)
        digest = hashlib.sha256(data).hexdigest()
        path = directory / fn
        for attempt in (0, 1):
            path.write_bytes(data)
            if hashlib.sha256(path.read_bytes()).hexdigest() == digest:
                break
            if attempt:
                raise OSError(f"verify-after-write failed for {path}")
        manifest[name] = {"file": fn, "shape": list(arr.shape),
                          "dtype": str(arr.dtype), "sha256": digest}
    return manifest


def load_pytree(like: Params, directory: Path,
                shardings: Optional[Params] = None) -> Params:
    """Read a pytree saved by save_pytree, shaped like ``like``; device_put
    with ``shardings`` when given (elastic restore re-shards here)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, shard_flat):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        fn = (name or "leaf").replace("/", "__") + ".npy"
        arr = np.load(directory / fn)
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            if arr.dtype.kind == "V" and \
                    arr.dtype.itemsize == np.dtype(want).itemsize:
                # numpy round-trips ml_dtypes (bfloat16, fp8) as raw void —
                # the bytes are already right, only the view is lost
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keep-last-k atomic checkpoints of {params, opt_state, extra-state}."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 faults: Any = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # chaos harness (repro.core.faults): injected transient write
        # failures and post-publish truncation; None in normal operation
        if faults is not None and not hasattr(faults, "io_error"):
            faults = faults.injector()
        self.faults = faults
        self._thread: Optional[threading.Thread] = None
        self._thread_exc: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, params: Params, opt_state: Params,
             extra: Optional[dict] = None, blocking: bool = True) -> Path:
        """Snapshot to host memory now; write (possibly async) to disk."""
        self.wait()

        # synchronous snapshot: device -> host copy happens here, so the
        # training loop may donate/overwrite the arrays right after return.
        # device_get is zero-copy whenever it can be (numpy leaves come
        # back as the SAME buffer; on the CPU backend jax Arrays come back
        # as a view of the device buffer), so any result that does not own
        # fresh memory must be copied — otherwise a post-save in-place
        # update or donation would corrupt the in-flight async write.
        def _snap(x):
            arr = np.asarray(jax.device_get(x))
            if isinstance(x, np.ndarray) or not arr.flags.owndata:
                arr = arr.copy()
            return arr

        host_p = jax.tree.map(_snap, params)
        host_o = jax.tree.map(_snap, opt_state)
        extra = dict(extra or {})

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            # one retry on a transient IO failure: the snapshot is still in
            # host memory, so a failed attempt only costs a rewrite of the
            # staging dir (a second failure propagates — that's persistent)
            for attempt in (0, 1):
                try:
                    if self.faults is not None and \
                            self.faults.io_error("ckpt"):
                        raise OSError(
                            "injected transient checkpoint IO failure")
                    if tmp.exists():
                        shutil.rmtree(tmp)
                    man = {
                        "step": step,
                        "time": time.time(),
                        "params": save_pytree(host_p, tmp / "params"),
                        "opt_state": save_pytree(host_o, tmp / "opt_state"),
                        "extra": extra,
                    }
                    (tmp / "DONE").write_text(json.dumps(man))
                    break
                except OSError:
                    shutil.rmtree(tmp, ignore_errors=True)
                    if attempt:
                        raise
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomic publish
            if self.faults is not None:
                self._maybe_truncate(final, step)
            self._gc()

        if blocking:
            write()
        else:
            # a daemon thread swallows exceptions by default; capture the
            # first failure so wait() (and therefore the next save()) can
            # re-raise it instead of silently dropping the step
            def guarded():
                try:
                    write()
                except BaseException as e:  # noqa: BLE001 - re-raised in wait
                    self._thread_exc = e

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()
        return self.dir / f"step_{step:08d}"

    def wait(self) -> None:
        """Join the in-flight async write, re-raising its failure (if any).

        An async save that died in the background — persistent IO error,
        full disk — would otherwise look exactly like a successful save
        until restore time; surfacing it at the next synchronization point
        keeps the at-most-one-lost-step contract honest.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._thread_exc is not None:
            exc, self._thread_exc = self._thread_exc, None
            raise exc

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def _maybe_truncate(self, final: Path, step: int) -> None:
        """Chaos-only: truncate one data file of a *published* checkpoint
        (simulating corruption after the atomic rename — the case atomicity
        cannot defend against), proving ``restore_latest`` skips it."""
        if not self.faults.truncate_step(step):
            return
        npys = sorted(final.rglob("*.npy"))
        if npys:
            data = npys[0].read_bytes()
            npys[0].write_bytes(data[:max(1, len(data) // 2)])

    # -- read ----------------------------------------------------------------
    def steps(self) -> list[int]:
        """Complete (DONE-marked) checkpoint steps, ascending."""
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "DONE").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, params_like: Params, opt_like: Params,
                param_shardings: Optional[Params] = None,
                opt_shardings: Optional[Params] = None) -> tuple:
        """Returns (params, opt_state, extra)."""
        d = self.dir / f"step_{step:08d}"
        man = json.loads((d / "DONE").read_text())
        p = load_pytree(params_like, d / "params", param_shardings)
        o = load_pytree(opt_like, d / "opt_state", opt_shardings)
        return p, o, man.get("extra", {})

    def verify(self, step: int) -> list:
        """Integrity-check one published step against its manifest digests.

        Returns a list of ``(file, problem)`` tuples — empty means sound.
        Legacy checkpoints whose manifests predate the sha256 field verify
        existence only.
        """
        d = self.dir / f"step_{step:08d}"
        try:
            man = json.loads((d / "DONE").read_text())
        except Exception as e:  # noqa: BLE001 - any unreadable manifest
            return [("DONE", repr(e))]
        bad = []
        for part in ("params", "opt_state"):
            for name, ent in man.get(part, {}).items():
                p = d / part / ent["file"]
                if not p.exists():
                    bad.append((f"{part}/{ent['file']}", "missing"))
                    continue
                want = ent.get("sha256")
                if want is not None and \
                        hashlib.sha256(p.read_bytes()).hexdigest() != want:
                    bad.append((f"{part}/{ent['file']}", "digest mismatch"))
        return bad

    def restore_latest(self, params_like: Params, opt_like: Params,
                       **kw) -> Optional[tuple]:
        """Restore the newest step that passes integrity verification.

        A published-then-corrupted step (truncated file, digest mismatch,
        unreadable manifest) is skipped and the scan falls back to the
        previous good step — the crash-mid-save guarantee, extended to
        post-publish corruption.
        """
        for step in reversed(self.steps()):
            if self.verify(step):
                continue
            return (step, *self.restore(step, params_like, opt_like, **kw))
        return None
