"""The paper's motivating example: an edge-centric PageRank accelerator as
a task graph (Figure 3), numerically verified against numpy power
iteration.

Run:  PYTHONPATH=src python examples/pagerank_dataflow.py

Demonstrates exactly what Section 2.3 motivates:
  * EoT transactions delimit each iteration's update stream (Listing 2),
  * the UpdateHandler accumulates in registers and commits per transaction
    (Listing 1),
  * Ctrl <-> VertexHandler is a feedback loop, so the sequential engine
    FAILS on this program while coroutine/thread simulate it (Fig. 7).
"""

from repro.apps import page_rank


def main():
    print("PageRank accelerator task graph "
          "(Ctrl / VertexHandler / ComputeUnit / UpdateHandler)\n")
    for engine in ("coroutine", "thread", "sequential"):
        r = page_rank.run(engine=engine, n_vertices=64, n_edges=512,
                          n_pe=4, n_iters=8)
        if r.report.ok:
            print(f"[{engine:10s}] simulated: instances="
                  f"{r.report.n_instances} channels={r.report.n_channels} "
                  f"switches={r.report.switches} | verified vs numpy: "
                  f"correct={r.correct} max_err={r.max_err:.2e}")
        else:
            print(f"[{engine:10s}] FAILED (expected for sequential): "
                  f"{r.report.error[:100]}")


if __name__ == "__main__":
    main()
