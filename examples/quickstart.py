"""Quickstart: the TAPA-JAX programming model in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py

Shows the paper's three contributions end to end:
  C1 — channels with peek + EoT transactions, hierarchical task().invoke
  C2 — the same program under all three simulation engines
  C3 — task-graph metadata extraction + definition-deduplicated compile
"""

import repro


# --- tasks (paper Listing 4 style) -----------------------------------------

def Producer(out: repro.OStream, n: int):
    """Write two transactions: [0..n) and [n..2n)."""
    for base in (0, n):
        for i in range(n):
            out.write(base + i)
        out.close()                      # end-of-transaction


def Router(inp: repro.IStream, evens: repro.OStream, odds: repro.OStream):
    """Peek to route without consuming (paper Listing 1's whole point)."""
    for _ in range(2):                   # two transactions
        while not inp.eot():
            head = inp.peek()            # inspect...
            dst = evens if head % 2 == 0 else odds
            dst.write(inp.read())        # ...then commit
        inp.open()
        evens.close()
        odds.close()


def Consumer(inp: repro.IStream, sink: list):
    for _ in range(2):
        sink.append([v for v in inp])    # `for v in stream` drains one txn


# --- parent task (paper Listing 5 style) ------------------------------------

def Top(evens_out: list, odds_out: list):
    a = repro.channel(capacity=4, name="a")
    e = repro.channel(capacity=4, name="evens")
    o = repro.channel(capacity=4, name="odds")
    repro.task() \
        .invoke(Producer, a, 8) \
        .invoke(Router, a, e, o) \
        .invoke(Consumer, e, evens_out) \
        .invoke(Consumer, o, odds_out)


def main():
    # C2: one source, three engines
    for engine in ("coroutine", "thread", "sequential"):
        evens, odds = [], []
        report = repro.run(Top, evens, odds, engine=engine)
        print(f"[{engine:10s}] ok={report.ok} switches={report.switches} "
              f"evens={evens[0][:4]}... odds={odds[0][:4]}...")

    # C3: extract the task graph and compile each definition once
    graph = repro.elaborate(Top, [], [])
    print(f"\ntask graph: {graph.summary()}")
    print(graph.to_dot()[:200], "...")

    # C1 host side: the whole thing as ONE function call
    evens, odds = [], []
    repro.invoke(Top, evens, odds, target="sim")
    print(f"\ninvoke() -> evens txn sizes {[len(t) for t in evens]}, "
          f"odds txn sizes {[len(t) for t in odds]}")


if __name__ == "__main__":
    main()
