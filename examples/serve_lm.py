"""Serving example: continuous batching with EoT-transaction requests.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b

The admission scheduler peeks the request channel and admits a request
only when a decode slot is free (the paper's switch pattern); each request
travels as one EoT-delimited transaction.  Compute is the jit'd
prefill/decode pair of the selected architecture.
"""

import sys

from repro.launch.serve import serve


if __name__ == "__main__":
    sys.exit(serve(sys.argv[1:] or ["--requests", "8", "--max-new", "6"]))
