"""Pipeline parallelism as a TAPA task graph, verified then compiled.

Run:  PYTHONPATH=src python examples/pipeline_parallel.py

1. The GPipe schedule (4 stages x 8 microbatches) is built as a
   task-parallel program — stages are tasks, hand-offs are bounded
   channels — and VERIFIED by the coroutine engine in milliseconds
   (deadlock-freedom, FIFO delivery, occupancy <= capacity).
2. The same schedule is lowered to shard_map + lax.ppermute over a
   4-device 'stage' mesh axis and checked against the single-device
   reference, forward and backward (grad runs the reverse pipeline).
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                                                          # noqa: E402
import jax.numpy as jnp                                             # noqa: E402

from repro.distributed.pipeline import (PipelineConfig,             # noqa: E402
                                        pipeline_apply,
                                        pipeline_loss_fn,
                                        schedule_task_graph,
                                        stack_stage_params)


def main():
    S, M, mb, d = 4, 8, 2, 32
    pcfg = PipelineConfig(n_stages=S, n_microbatches=M)

    rep = schedule_task_graph(pcfg)
    print(f"schedule sim: ok={rep.ok} FIFO={rep.result == list(range(M))} "
          f"switches={rep.switches}")
    print(f"max channel occupancy: "
          f"{max(occ for (_, _, occ) in rep.channels)} "
          f"(capacity {pcfg.channel_capacity}); "
          f"bubble fraction {pcfg.bubble_fraction:.2f}")

    mesh = jax.make_mesh((S,), ("stage",))
    ks = jax.random.split(jax.random.PRNGKey(0), S)
    per_stage = [{"w": jax.random.normal(k, (d, d)) * 0.3} for k in ks]
    stacked = stack_stage_params(per_stage)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"][0])

    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    out = pipeline_apply(mesh, stage_fn, stacked, xs)
    ref = xs
    for p in per_stage:
        ref = jnp.tanh(ref @ p["w"])
    print(f"compiled pipeline fwd max err vs single device: "
          f"{float(jnp.max(jnp.abs(out - ref))):.2e}")

    ys = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))
    lf = pipeline_loss_fn(mesh, stage_fn, lambda o, y: jnp.mean((o - y) ** 2))
    g = jax.grad(lf)(stacked, xs, ys)
    print(f"reverse-pipeline grad computed: |dw| = "
          f"{float(jnp.linalg.norm(g['w'])):.4f}")


if __name__ == "__main__":
    main()
