"""Cannon's algorithm on an 8x8 toroidal PE mesh + hierarchical compile.

Run:  PYTHONPATH=src python examples/cannon_systolic.py

The torus wrap-around links are feedback loops: sequential simulation must
fail (paper Fig. 7), cooperative simulation verifies the matmul in
milliseconds.  The same PE definition is instantiated 64 times — the
hierarchical compiler (C3) compiles it ONCE, the monolithic baseline 64
times.
"""

import jax.numpy as jnp

from repro.apps import cannon
from repro.core.hier_compile import StageInstance, compile_stages


def main():
    print("Cannon's algorithm, 8x8 PEs, 64x64 blocks:")
    for engine in ("coroutine", "sequential"):
        r = cannon.run(engine=engine, P=8, n=8)
        if r.report.ok:
            print(f"  [{engine:10s}] instances={r.report.n_instances} "
                  f"channels={r.report.n_channels} correct={r.correct} "
                  f"err={r.max_err:.2e} wall={r.report.wall_s*1e3:.1f}ms")
        else:
            print(f"  [{engine:10s}] FAILED as the paper documents "
                  f"(feedback loops)")

    # C3 on the PE definition: 64 instances, ONE compile
    def pe_body(a, b, acc):
        return acc + a @ b

    a = jnp.ones((64, 64), jnp.bfloat16)
    insts = [StageInstance(fn=pe_body, args=(a, a, a), name=f"PE{i}")
             for i in range(64)]
    # cache=False: this comparison isolates the dedup factor — a warm
    # persistent cache would make hierarchical wall-time trivially ~0
    rep_h = compile_stages(insts, mode="hierarchical", cache=False)
    insts2 = [StageInstance(fn=pe_body, args=(a, a, a), name=f"PE{i}")
              for i in range(64)]
    rep_m = compile_stages(insts2, mode="monolithic")
    print(f"\nhierarchical compile: {rep_h.n_unique} compilation(s) for "
          f"{rep_h.n_instances} instances in {rep_h.wall_s:.3f}s")
    print(f"monolithic compile:  {len(rep_m.per_key_s)} compilations in "
          f"{rep_m.wall_s:.3f}s "
          f"({rep_m.wall_s/max(rep_h.wall_s,1e-9):.1f}x slower)")


if __name__ == "__main__":
    main()
