"""End-to-end LM training example (deliverable b: the e2e driver).

CPU-runnable default: a ~10M-parameter qwen3-family model for 300 steps —
loss drops visibly.  On real hardware drop --reduced and raise sizes; the
driver resumes from the latest checkpoint automatically, so preempting it
mid-run and re-running the same command is the fault-tolerance demo.

Run:  PYTHONPATH=src python examples/train_lm.py
      PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m
"""

import argparse
import sys

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs accelerators)")
    args, rest = ap.parse_known_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", f"/tmp/repro_train_{args.arch}",
            "--log-every", "20"] + rest
    if not args.full:
        argv.append("--reduced")
    return train(argv)


if __name__ == "__main__":
    sys.exit(main())
