"""Typed interface layer: mmap / async_mmap / scalar (paper Table 2).

Covers the engine conformance matrix (same stream+mmap+EoT body under all
three engines), async_mmap request/response overlap, the one-writer and
one-port rules, annotation-driven binding, the per-definition interface
table in the graph IR, and the zero-closure-capture property of the
migrated apps.  The XLA-side contract (mmap args as device buffers, value-
independent structural keys) lives in the ``slow``-marked tests at the
bottom.
"""

import inspect

import numpy as np
import pytest

import repro
from repro.core import (AsyncMMap, ChannelMisuse, InterfaceInfo, MMap,
                        Scalar, instance_key)
from repro.core.engines import ENGINES
from repro.core.graph import elaborate

ALL_ENGINES = ("sequential", "thread", "coroutine")


# ---------------------------------------------------------------------------
# conformance matrix: one body, every interface kind, every engine
# ---------------------------------------------------------------------------

def Loader(src: MMap, out, rows: int):
    """mmap -> stream: one burst load, one EoT-delimited transaction."""
    out.write_burst(list(src.read_burst(0, rows)))
    out.close()


def Doubler(inp, out, gain):
    for row in inp:                 # drains one transaction
        out.write(row * gain)
    out.close()


def Storer(inp, dst: MMap):
    rows = inp.read_transaction()
    dst.write_burst(0, np.stack(rows))


def _mk_pipeline(n_rows=6, width=4):
    data = np.arange(n_rows * width, dtype=np.float64).reshape(n_rows, width)
    src, dst = repro.mmap(data, "src"), repro.mmap(np.zeros_like(data), "dst")

    def Top(a: MMap, b: MMap):
        c1, c2 = repro.channel(2), repro.channel(3)
        repro.task() \
            .invoke(Loader, a, c1, n_rows) \
            .invoke(Doubler, c1, c2, repro.scalar(2.0)) \
            .invoke(Storer, c2, b)

    return Top, (src, dst), data, dst


@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("track_stats", [False, True])
def test_stream_mmap_eot_conformance(engine, track_stats):
    """The same stream+mmap+EoT body produces identical memory contents
    under every engine, with and without statistics."""
    top, args, data, dst = _mk_pipeline()
    rep = ENGINES[engine](track_stats=track_stats).run(top, *args)
    assert rep.ok, rep.error
    np.testing.assert_allclose(dst.data, data * 2.0)
    if track_stats:
        stats = {name: s for name, kind, s in rep.interfaces}
        assert stats["src"]["load_elems"] == data.size
        assert stats["dst"]["store_elems"] == data.size


def AsyncGather(mem: AsyncMMap, out, n: int):
    out.write_burst(mem.read_pipelined(range(n)))
    out.close()


def _async_top(depth, latency=4, n=16):
    data = np.arange(100, 100 + n, dtype=np.int64)
    port = repro.async_mmap(data, latency=latency, depth=depth, name="port")
    sink: list = []

    def Top(mem: AsyncMMap):
        ch = repro.channel(capacity=n)
        repro.task() \
            .invoke(AsyncGather, mem, ch, n) \
            .invoke(lambda inp, acc: acc.extend(inp.read_transaction()),
                    ch, sink, name="Sink")

    return Top, (port,), data, sink


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_async_mmap_conformance(engine):
    """Pipelined async reads return every element, in order, on all three
    engines; the sequential engine *records* its synchronous deliveries."""
    top, args, data, sink = _async_top(depth=4)
    rep = ENGINES[engine](track_stats=True).run(top, *args)
    assert rep.ok, rep.error
    assert sink == list(data)
    if engine == "sequential":
        assert rep.async_violations > 0     # cannot overlap: recorded
    else:
        assert rep.async_violations == 0


def test_async_mmap_write_path_all_engines():
    for engine in ALL_ENGINES:
        data = np.zeros(8, np.int64)
        port = repro.async_mmap(data, latency=3, depth=2, name="w")

        def Writer(mem: AsyncMMap):
            acked = 0
            for i in range(8):
                mem.write_addr.write(i)
                mem.write_data.write(10 * i)
                while mem.write_resp.try_read()[0]:
                    acked += 1
            while acked < 8:
                mem.write_resp.read()
                acked += 1

        def Top(mem: AsyncMMap):
            repro.task().invoke(Writer, mem)

        rep = ENGINES[engine]().run(Top, port)
        assert rep.ok, (engine, rep.error)
        assert list(data) == [10 * i for i in range(8)], engine


# ---------------------------------------------------------------------------
# overlap: the point of the five-channel decomposition
# ---------------------------------------------------------------------------

def test_async_mmap_outstanding_depth_overlaps():
    """With depth > 1 the coroutine engine shows genuine request/response
    overlap: several reads in flight at once and fewer scheduler switches
    than the depth-1 serialization of the same access stream."""
    results = {}
    for depth in (1, 4):
        top, args, data, sink = _async_top(depth=depth)
        eng = ENGINES["coroutine"](track_stats=True)
        rep = eng.run(top, *args)
        assert rep.ok and sink == list(data)
        stats = {name: s for name, kind, s in rep.interfaces}
        results[depth] = (stats["port"]["max_outstanding_reads"],
                          rep.switches)
    assert results[1][0] == 1
    assert results[4][0] == 4                   # measurable overlap
    assert results[4][1] < results[1][1]        # fewer stalls when deep


@pytest.mark.parametrize("engine", ["coroutine", "thread"])
def test_deferred_port_does_not_mask_later_event(engine):
    """A flooded port whose deliveries defer (undrained response FIFO)
    must not shadow a later-due response on a *different* port: the
    fast-forward tries every pending event, not just the earliest."""
    a_port = repro.async_mmap(np.arange(8), latency=2, depth=2, name="a")
    b_port = repro.async_mmap(np.arange(100, 108), latency=50, depth=2,
                              name="b")
    out: list = []

    def Flooder(mem: AsyncMMap):
        for i in range(8):
            mem.read_addr.write(i)      # never drains read_data
        while True:
            pass_token = mem.write_resp.try_read()  # idle forever
            if not pass_token[0]:
                break

    def Reader(mem: AsyncMMap, sink):
        mem.read_addr.write(3)
        sink.append(mem.read_data.read())

    def Top(a: AsyncMMap, b: AsyncMMap):
        repro.task() \
            .invoke(Flooder, a, detach=True) \
            .invoke(Reader, b, out)

    rep = ENGINES[engine]().run(Top, a_port, b_port)
    assert rep.ok, (engine, rep.error)
    assert out == [103]


def test_async_mmap_latency_zero_and_depth_one():
    top, args, data, sink = _async_top(depth=1, latency=0)
    rep = ENGINES["coroutine"]().run(top, *args)
    assert rep.ok and sink == list(data)


# ---------------------------------------------------------------------------
# binding rules
# ---------------------------------------------------------------------------

def test_mmap_one_writer_rule():
    m = repro.mmap(np.zeros(4))

    def W(mm: MMap, i):
        mm[i] = 1.0

    def Top(mm: MMap):
        repro.task().invoke(W, mm, 0).invoke(W, mm, 1)

    rep = ENGINES["coroutine"]().run(Top, m)
    assert not rep.ok and "one-writer" in rep.error


def test_mmap_many_readers_ok():
    m = repro.mmap(np.arange(4.0))
    acc: list = []

    def R(mm: MMap, sink, i):
        sink.append(mm[i])

    def Top(mm: MMap):
        t = repro.task()
        for i in range(4):
            t = t.invoke(R, mm, acc, i)

    rep = ENGINES["coroutine"]().run(Top, m)
    assert rep.ok and sorted(acc) == [0.0, 1.0, 2.0, 3.0]


def test_async_mmap_exclusive_port():
    """Two sibling tasks may not share one async port (it models a single
    memory channel); a parent passing it through to one child is fine."""
    port = repro.async_mmap(np.arange(4), name="p")

    def U(mem: AsyncMMap):
        pass

    def Top(mem: AsyncMMap):
        repro.task().invoke(U, mem).invoke(U, mem, name="U2")

    rep = ENGINES["coroutine"]().run(Top, port)
    assert not rep.ok and "one memory port" in rep.error


def test_scalar_unwraps_and_ndarray_autowraps():
    got = {}

    def Child(m: MMap, k: Scalar, plain):
        got["m"] = type(m).__name__
        got["k"] = k
        got["plain"] = plain
        got["sum"] = float(np.sum(m.read_burst(0, 2)))

    def Top(arr, k):
        repro.task().invoke(Child, arr, k, 7)

    # raw ndarray + MMap annotation -> auto-wrapped; Scalar -> raw value
    rep = ENGINES["coroutine"]().run(
        Top, np.ones((2, 3)), repro.scalar(5, dtype="int32"))
    assert rep.ok, rep.error
    assert got == {"m": "MMap", "k": 5, "plain": 7, "sum": 6.0}


def test_autowrap_shares_wrapper_and_enforces_one_writer():
    """Two MMap-annotated tasks receiving the same *raw* ndarray share one
    engine-adopted wrapper: the one-writer rule holds and the interface
    shows up in the report, exactly as for an explicit repro.mmap."""
    buf = np.zeros(4)

    def W(m: MMap, i):
        m[i] = 1.0

    def Top(arr):
        repro.task().invoke(W, arr, 0).invoke(W, arr, 1)

    eng = ENGINES["coroutine"]()
    rep = eng.run(Top, buf)
    assert not rep.ok and "one-writer" in rep.error
    assert len(rep.interfaces) == 1 and rep.interfaces[0][1] == "mmap"


def test_async_mmap_direction_observed():
    """An actively-driven async port reports its observed direction in
    the per-definition table, not 'unused'."""
    top, args, data, sink = _async_top(depth=2)
    eng = ENGINES["coroutine"]()
    rep = eng.run(top, *args)
    assert rep.ok
    from repro.core.graph import extract_graph
    rows = _table(extract_graph(eng, rep), "AsyncGather")
    assert rows["mem"].kind == "async_mmap"
    assert rows["mem"].direction == "read"


def test_request_channels_reject_eot():
    port = repro.async_mmap(np.arange(4), name="p")

    def U(mem: AsyncMMap):
        mem.read_addr.close()

    def Top(mem: AsyncMMap):
        repro.task().invoke(U, mem)

    rep = ENGINES["coroutine"]().run(Top, port)
    assert not rep.ok and "EoT" in rep.error


# ---------------------------------------------------------------------------
# graph IR: the per-definition interface table
# ---------------------------------------------------------------------------

def _table(graph, defn_name):
    for d in graph.definitions:
        if d.name == defn_name:
            return {r.param: r for r in d.interfaces}
    raise AssertionError(f"definition {defn_name} not found")


def test_graph_interface_table_smoke():
    top, args, data, dst = _mk_pipeline()
    g = elaborate(top, *args)
    g.validate()
    rows = _table(g, "Loader")
    assert isinstance(next(iter(rows.values())), InterfaceInfo)
    assert rows["src"].kind == "mmap" and rows["src"].direction == "read"
    assert rows["out"].kind == "ostream"
    assert rows["rows"].kind == "scalar"
    rows = _table(g, "Storer")
    assert rows["dst"].kind == "mmap" and rows["dst"].direction == "write"
    assert rows["inp"].kind == "istream"


@pytest.mark.parametrize("app", ["gemm", "gaussian", "page_rank", "cannon"])
def test_migrated_apps_interface_tables(app):
    """Every migrated app exposes a per-definition interface table with
    its memory traffic typed as mmap/async_mmap and its run parameters as
    scalars — and validates."""
    from repro.apps import APPS

    mod = APPS[app]
    top, args, _ = mod.build()
    eng = ENGINES["coroutine"]()
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    from repro.core.graph import extract_graph
    g = extract_graph(eng, rep)
    g.validate()
    kinds = {r.kind for d in g.definitions for r in d.interfaces}
    assert "mmap" in kinds and "scalar" in kinds
    if app == "page_rank":
        assert "async_mmap" in kinds
    # the DOT export names the memory interfaces
    dot = g.to_dot()
    assert "cylinder" in dot


@pytest.mark.parametrize("app", ["gemm", "gaussian", "page_rank", "cannon"])
def test_migrated_apps_zero_closure_captured_arrays(app):
    """No task definition in the migrated apps closure-captures an array:
    data reaches the graph only through declared interfaces."""
    from repro.apps import APPS

    mod = APPS[app]
    top, args, _ = mod.build()
    eng = ENGINES["coroutine"]()
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    for inst in eng.instances:
        closure = getattr(inst.fn, "__closure__", None) or ()
        for name, cell in zip(inst.fn.__code__.co_freevars, closure):
            try:
                v = cell.cell_contents
            except ValueError:
                continue
            assert not isinstance(v, np.ndarray), (
                f"{app}: task {inst.name} closure-captures array {name!r}")


def test_mmap_direction_readwrite_merges():
    m = repro.mmap(np.zeros(4), "rw")

    def T(mm: MMap):
        mm[0] = 1.0
        assert mm[0] == 1.0

    def Top(mm: MMap):
        repro.task().invoke(T, mm)

    eng = ENGINES["coroutine"]()
    rep = eng.run(Top, m)
    assert rep.ok
    from repro.core.graph import extract_graph
    g = extract_graph(eng, rep)
    rows = _table(g, "T")
    assert rows["mm"].direction == "readwrite"


# ---------------------------------------------------------------------------
# thread engine: burst wakeups (no direct coverage before this matrix)
# ---------------------------------------------------------------------------

def test_thread_engine_burst_wakeups():
    """A blocked burst reader is woken by a burst write and vice versa:
    capacity (2) is smaller than the burst (8), so both sides park and are
    repeatedly woken at batch granularity under the preemptive engine."""
    out: list = []

    def P(o):
        o.write_burst(list(range(8)))
        o.close()

    def C(i, sink):
        while True:
            chunk = i.read_burst(8)
            sink.extend(chunk)
            if len(chunk) < 8:
                break
        i.open()

    def Top(sink):
        ch = repro.channel(capacity=2)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    rep = ENGINES["thread"]().run(Top, out)
    assert rep.ok, rep.error
    assert out == list(range(8))


def test_thread_engine_async_under_contention():
    """Many concurrent async ports under the preemptive engine: the
    RLock-guarded pump/deliver path must neither race nor deadlock."""
    n_ports, n = 4, 12
    datas = [np.arange(p * 100, p * 100 + n, dtype=np.int64)
             for p in range(n_ports)]
    ports = [repro.async_mmap(d, latency=2, depth=3, name=f"p{i}")
             for i, d in enumerate(datas)]
    sinks: list = [[] for _ in range(n_ports)]

    def G(mem: AsyncMMap, sink):
        sink.extend(mem.read_pipelined(range(n)))

    def Top(ps):
        t = repro.task()
        for i, p in enumerate(ps):
            t = t.invoke(G, p, sinks[i], name=f"G{i}")

    rep = ENGINES["thread"]().run(Top, ports)
    assert rep.ok, rep.error
    for i in range(n_ports):
        assert sinks[i] == list(datas[i])


# ---------------------------------------------------------------------------
# compile path: mmap args are device buffers, not baked constants
# ---------------------------------------------------------------------------

def test_instance_key_value_independent_for_mmap():
    """Two stage instances that differ only in mmap *data* share one
    structural key (they compile once); closure-captured arrays — the
    pre-interface idiom — still hash apart."""
    def stage(x, m):
        return x + 1

    a = repro.mmap(np.zeros((4, 4), np.float32))
    b = repro.mmap(np.ones((4, 4), np.float32))
    spec = np.zeros((4, 4), np.float32)
    assert instance_key(stage, (spec, a)) == instance_key(stage, (spec, b))
    # different aval -> different key
    c = repro.mmap(np.ones((8, 4), np.float32))
    assert instance_key(stage, (spec, a)) != instance_key(stage, (spec, c))
    # the closure-capture idiom hashes by content (so it *cannot* dedup)
    def mk(arr):
        return lambda x: x + arr
    assert instance_key(mk(np.zeros(4)), (spec,)) != \
        instance_key(mk(np.ones(4)), (spec,))


def test_scalar_in_key_by_value():
    def stage(x, k):
        return x * k

    spec = np.zeros((2, 2), np.float32)
    assert instance_key(stage, (spec, repro.scalar(2))) == \
        instance_key(stage, (spec, repro.scalar(2)))
    assert instance_key(stage, (spec, repro.scalar(2))) != \
        instance_key(stage, (spec, repro.scalar(3)))


@pytest.mark.slow
def test_dataflow_program_feeds_mmap_buffers():
    """A compiled stage with an mmap arg executes against the buffer's
    *current* contents — edit the array in place, rerun, no recompile."""
    import jax.numpy as jnp

    from repro.core.hier_compile import (StageInstance, build_dataflow,
                                         compile_stages)

    buf = np.full((4,), 2.0, np.float32)
    m = repro.mmap(buf, "weights")

    def scale(x, w):
        return x * w

    inst = StageInstance(fn=scale,
                        args=(jnp.zeros((4,), jnp.float32), m),
                        name="scale")
    rep = compile_stages([inst], mode="hierarchical", cache=False)
    assert rep.n_compiled == 1
    prog = build_dataflow([inst], wiring={})
    x = jnp.ones((4,), jnp.float32)
    np.testing.assert_allclose(np.asarray(prog(x)), np.full(4, 2.0))
    buf *= 3.0                              # in-place edit, same aval
    np.testing.assert_allclose(np.asarray(prog(x)), np.full(4, 6.0))


@pytest.mark.slow
def test_compile_stages_dedups_across_mmap_values():
    """N instances over different mmap buffers of one definition compile
    exactly once (the dedup the paper's hierarchical codegen exploits and
    closure capture defeated)."""
    import jax.numpy as jnp

    from repro.core.hier_compile import StageInstance, compile_stages

    def stage(x, m):
        return x @ m

    spec = jnp.zeros((4, 4), jnp.float32)
    insts = [
        StageInstance(fn=stage,
                      args=(spec, repro.mmap(
                          np.random.rand(4, 4).astype(np.float32))),
                      name=f"s{i}")
        for i in range(5)
    ]
    rep = compile_stages(insts, mode="hierarchical", cache=False)
    assert rep.n_instances == 5 and rep.n_unique == 1
    assert rep.n_compiled == 1


def test_interfaces_reusable_across_engine_runs():
    """A host-created interface re-simulates under fresh engines: run-
    scoped binding state (writer, port ownership, FIFO contents) resets
    at registration, so elaboration after simulation just works."""
    top, args, data, dst = _mk_pipeline()
    for engine in ("coroutine", "thread", "coroutine"):
        dst.data[...] = 0.0
        rep = ENGINES[engine]().run(top, *args)
        assert rep.ok, (engine, rep.error)
        np.testing.assert_allclose(dst.data, data * 2.0)
    # async ports too
    top, pargs, pdata, sink = _async_top(depth=3)
    for engine in ("coroutine", "thread"):
        del sink[:]
        rep = ENGINES[engine]().run(top, *pargs)
        assert rep.ok and sink == list(pdata), engine


def test_sim_report_repr_mentions_interfaces():
    top, args, data, dst = _mk_pipeline()
    rep = ENGINES["coroutine"](track_stats=True).run(top, *args)
    assert len(rep.interfaces) == 2
    names = {n for n, k, s in rep.interfaces}
    assert names == {"src", "dst"}
