"""Parametrized smoke test over every registered model architecture.

The config registry had 10 entries of which most were never imported by
any test; this sweep builds each one and sanity-checks the published
dimensions, so a typo in a config module fails fast instead of surfacing
as a shape error deep inside a launch script.
"""

import pytest

from repro.configs import ARCH_IDS, all_configs, canonical, get_config
from repro.models.config import ModelConfig


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_builds_and_is_sane(arch):
    cfg = get_config(arch)
    assert isinstance(cfg, ModelConfig)
    assert cfg.n_layers > 0
    assert cfg.d_model > 0
    assert cfg.vocab > 0
    assert cfg.max_seq_len > 0
    assert cfg.family in ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
    if cfg.family in ("ssm", "hybrid"):
        # attention-free backbones: SSD dimensions replace heads/FFN
        assert cfg.ssm is not None
        assert cfg.ssm.d_inner(cfg.d_model) % cfg.ssm.head_dim == 0
    else:
        assert cfg.d_ff > 0
        assert cfg.n_heads > 0
        assert cfg.n_kv_heads > 0
        assert cfg.n_heads % cfg.n_kv_heads == 0
        head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
        assert head_dim > 0
    if cfg.family == "moe":
        assert cfg.moe is not None
        assert 0 < cfg.moe.top_k <= cfg.moe.n_experts
    if cfg.family == "hybrid":
        assert cfg.ssm is not None and cfg.hybrid is not None
    if cfg.family == "audio":
        assert cfg.encdec is not None
    if cfg.family == "vlm":
        assert cfg.vlm is not None


@pytest.mark.parametrize("alias,arch", [
    ("qwen3-0.6b", "qwen3_0_6b"),
    ("phi-3-vision-4.2b", "phi_3_vision_4_2b"),
    ("zamba2-1.2b", "zamba2_1_2b"),
])
def test_canonical_aliases(alias, arch):
    assert canonical(alias) == arch
    assert get_config(alias).name is not None


def test_all_configs_unique_names():
    cfgs = all_configs()
    assert len(cfgs) == len(ARCH_IDS)
    names = [c.name for c in cfgs.values()]
    assert len(set(names)) == len(names)
