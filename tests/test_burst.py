"""Burst channel I/O semantics (no hypothesis required; the randomized
equivalence sweep lives in test_properties.py).

The burst API must be observationally identical to scalar ops: same token
sequences, same EoT boundaries, same blocking behavior, exact capacity —
under all three engines — while touching the runtime once per batch.
"""

import pytest

import repro
from repro.core.errors import ChannelMisuse

ALL = ("coroutine", "thread", "sequential")
PARALLEL = ("coroutine", "thread")


def run_pair(producer, consumer, capacity=2, engine="coroutine"):
    out = []

    def Top(sink):
        ch = repro.channel(capacity=capacity)
        repro.task().invoke(producer, ch).invoke(consumer, ch, sink)

    rep = repro.run(Top, out, engine=engine)
    return rep, out


@pytest.mark.parametrize("eng", ALL)
def test_burst_roundtrip_all_engines(eng):
    def P(o):
        o.write_burst(range(50))
        o.close()

    def C(i, sink):
        sink.extend(i.read_transaction())

    rep, out = run_pair(P, C, capacity=8, engine=eng)
    assert rep.ok, rep.error
    assert out == list(range(50))


@pytest.mark.parametrize("eng", ALL)
def test_burst_vs_scalar_identical_sequences(eng):
    """Burst producer + scalar consumer and vice versa move identical
    sequences (the cross-mode half of the equivalence claim)."""
    vals = [(-1) ** k * k for k in range(37)]

    def Pb(o):
        o.write_burst(vals)
        o.close()

    def Cs(i, sink):
        sink.extend(v for v in i)

    def Ps(o):
        for v in vals:
            o.write(v)
        o.close()

    def Cb(i, sink):
        while True:
            chunk = i.read_burst(5)
            sink.extend(chunk)
            if len(chunk) < 5:
                break
        i.open()

    for prod, cons in ((Pb, Cs), (Ps, Cb), (Pb, Cb)):
        rep, out = run_pair(prod, cons, capacity=3, engine=eng)
        assert rep.ok, (eng, rep.error)
        assert out == vals


def test_read_burst_stops_at_eot_without_consuming():
    """A burst that hits an EoT returns short and leaves the EoT for
    open(); a burst at an EoT head returns empty."""
    def P(o):
        o.write_burst([1, 2, 3])
        o.close()
        o.write_burst([4])
        o.close()

    def C(i, sink):
        first = i.read_burst(10)
        sink.append(tuple(first))          # short: EoT after 3 tokens
        assert i.read_burst(10) == []      # EoT still at head
        i.open()
        sink.append(tuple(i.read_burst(1)))
        i.open()

    rep, out = run_pair(P, C, capacity=8)
    assert rep.ok, rep.error
    assert out == [(1, 2, 3), (4,)]


def test_read_burst_blocks_until_n():
    """read_burst(n) waits across producer batches until n tokens arrive
    (it is n scalar reads, not 'whatever is there')."""
    def P(o):
        for base in (0, 3, 6):
            o.write_burst([base, base + 1, base + 2])
        o.close()

    def C(i, sink):
        sink.append(tuple(i.read_burst(7)))    # spans three producer bursts
        sink.append(tuple(i.read_burst(7)))    # short: only 2 left
        i.open()

    rep, out = run_pair(P, C, capacity=2)      # tiny capacity: many refills
    assert rep.ok, rep.error
    assert out == [(0, 1, 2, 3, 4, 5, 6), (7, 8)]


@pytest.mark.parametrize("eng", ALL)
def test_write_burst_honors_capacity(eng):
    """Burst writes never overfill the channel: occupancy stays bounded by
    capacity in the parallel engines (sequential records violations
    instead, exactly as for scalar writes)."""
    cap = 3

    def P(o):
        o.write_burst(range(20))
        o.close()

    def C(i, sink):
        while True:
            got = i.read_burst(1)
            if not got:
                break
            assert i.channel.size() <= i.channel.capacity
            sink.extend(got)
        i.open()

    def Top(sink):
        ch = repro.channel(capacity=cap)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    out = []
    rep = repro.run(Top, out, engine=eng, track_stats=True)
    assert rep.ok, rep.error
    assert out == list(range(20))
    if eng == "sequential":
        assert rep.capacity_violations > 0
    else:
        assert rep.capacity_violations == 0
        # stats are tracked: highwater mark respected the bound
        assert all(occ <= cap for _, _, occ in rep.channels)


def test_try_write_burst_partial():
    def P(o):
        wrote = o.try_write_burst([1, 2, 3, 4, 5])
        assert wrote == 3                       # capacity 3, empty channel
        assert o.try_write_burst([9]) == 0      # now full
        o.write_burst([4, 5])                   # blocking finishes the job
        o.close()

    def C(i, sink):
        sink.extend(i.read_transaction())

    rep, out = run_pair(P, C, capacity=3)
    assert rep.ok, rep.error
    assert out == [1, 2, 3, 4, 5]


def test_try_read_burst_partial():
    def P(o):
        o.write_burst([1, 2])
        o.close()

    def C(i, sink):
        got = i.try_read_burst(10)
        sink.append(tuple(got))
        assert i.try_read_burst(10) == []       # only EoT left
        i.open()

    rep, out = run_pair(P, C, capacity=8)
    assert rep.ok, rep.error
    assert out == [(1, 2)]


def test_burst_rejects_eot_token():
    def P(o):
        with pytest.raises(ChannelMisuse):
            o.write_burst([1, repro.EOT, 2])
        with pytest.raises(ChannelMisuse):
            o.try_write_burst([repro.EOT])
        o.close()

    def C(i, sink):
        i.open()

    rep, _ = run_pair(P, C)
    assert rep.ok, rep.error


@pytest.mark.parametrize("eng", PARALLEL)
def test_multiple_transactions_burst(eng):
    def P(o):
        for t in range(3):
            o.write_burst([(t, k) for k in range(t + 2)])
            o.close()

    def C(i, sink):
        for _ in range(3):
            sink.append(tuple(i.read_transaction()))

    rep, out = run_pair(P, C, capacity=2, engine=eng)
    assert rep.ok, rep.error
    assert out == [tuple((t, k) for k in range(t + 2)) for t in range(3)]


# ---------------------------------------------------------------------------
# stats flag
# ---------------------------------------------------------------------------

def test_default_run_does_no_bookkeeping():
    def P(o):
        o.write_burst(range(10))
        o.close()

    def C(i, sink):
        sink.extend(i.read_transaction())

    def Top(sink):
        ch = repro.channel(capacity=4)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    rep = repro.run(Top, [], engine="coroutine")
    assert rep.ok and rep.tokens == 0
    assert all(w == 0 and occ == 0 for _, w, occ in rep.channels)


@pytest.mark.parametrize("eng", ALL)
def test_track_stats_counts_at_burst_granularity(eng):
    def P(o):
        o.write_burst(range(10))
        o.close()

    def C(i, sink):
        sink.extend(i.read_transaction())

    def Top(sink):
        ch = repro.channel(capacity=4)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    out = []
    rep = repro.run(Top, out, engine=eng, track_stats=True)
    assert rep.ok and out == list(range(10))
    assert rep.tokens == 11                 # 10 data + 1 EoT


# ---------------------------------------------------------------------------
# fast path: switch counts and wakeups
# ---------------------------------------------------------------------------

def test_burst_cuts_switches_vs_scalar():
    """On a deep pipeline with ample capacity the burst path must not
    switch more than the scalar path — and both must equal the dataflow
    stall count, not the token count."""
    N, STAGES, CAP = 512, 4, 64

    def build(burst):
        def Source(o):
            if burst:
                o.write_burst(list(range(N)))
            else:
                for v in range(N):
                    o.write(v)
            o.close()

        def Relay(i, o):
            if burst:
                while True:
                    chunk = i.read_burst(CAP)
                    if chunk:
                        o.write_burst(chunk)
                    if len(chunk) < CAP:
                        break
                i.open()
                o.close()
            else:
                for v in i:
                    o.write(v)
                o.close()

        def Sink(i, sink):
            sink.extend(i.read_transaction() if burst else list(i))

        def Top(sink):
            chans = [repro.channel(capacity=CAP) for _ in range(STAGES + 1)]
            t = repro.task().invoke(Source, chans[0])
            for s in range(STAGES):
                t = t.invoke(Relay, chans[s], chans[s + 1])
            t.invoke(Sink, chans[STAGES], sink)

        return Top

    outs = {}
    switches = {}
    for mode in (False, True):
        sink = []
        rep = repro.run(build(mode), sink, engine="coroutine")
        assert rep.ok and sink == list(range(N))
        switches[mode] = rep.switches
        outs[mode] = sink
    assert outs[False] == outs[True]
    assert switches[True] <= switches[False]
    # switches scale with N/CAP stalls, not with N tokens
    assert switches[True] < N
