"""Overload robustness: traffic shaping, admission control, shedding,
the circuit breaker, and deterministic simulated-time overload runs.

Structure mirrors the overload layer (PR 8):

* trace generation — seeded determinism, scale knob, chaos overlays;
* admission controller units — DRR fairness, priority classes, the three
  shed mechanisms, the accounting invariant;
* circuit breaker — unit transitions on a fake clock plus end-to-end
  open/half-open/closed cycles against injected step faults;
* hardening satellites — capped deadline-aware retry backoff, bounded
  full-queue admission (both the blocking and fail-fast contracts);
* journal — shed records are write-ahead, replay exactly-once, and
  survive torn tails interleaved with admit/tok/retire;
* end-to-end virtual-time overload runs — every offered request answered,
  ``offered == admitted + shed``, byte-identical across runs and
  processes, and the shed-off arm demonstrably collapses where the
  shed-on arm stays inside its deadline.

The e2e tests honour ``REPRO_TRAFFIC_SEED`` (CI sweeps seeds 0..2).
"""

import hashlib
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core.faults import FaultPlan
from repro.serve import (AdmissionConfig, AdmissionController, BreakerOpen,
                         CircuitBreaker, Request, RequestError, ServeConfig,
                         ServeJournal, ServeMetrics, ServingEngine,
                         TenantSpec, VirtualClock, make_trace,
                         noisy_neighbor_mix, serve_requests, trace_digest,
                         uniform_mix)

SRC = str(Path(__file__).resolve().parent.parent / "src")
SEED = int(os.environ.get("REPRO_TRAFFIC_SEED", "0"))

V = 16   # toy vocab (next token = (prev + 1) % V)


def _toy_engine(scfg: ServeConfig, **kw) -> ServingEngine:
    def prefill(toks):
        last = int(toks[0, -1]) % V
        return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

    def decode(tok, cache):
        return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

    return ServingEngine(scfg, prefill, decode, **kw)


def _virtual_setup(trace_kw=None, ctrl_kw=None, slots=2, step_dt=0.01,
                   shed=True, journal=None, duration=2.0, rate=35.0,
                   deadline_s=0.4, seed=SEED):
    """One deterministic overload run's parts: engine + trace + metrics.

    The VirtualClock is shared by the engine, the controller and the
    metrics (the engine ctor wires it through), so the entire run —
    arrivals, queue dynamics, sheds, TTFT percentiles — is a pure
    function of (seed, config).
    """
    vc = VirtualClock()
    metrics = ServeMetrics()
    ctrl = None
    if shed:
        ctrl = AdmissionController(AdmissionConfig(
            est_token_s=step_dt, queue_limit=8,
            **(ctrl_kw or {})))
    scfg = ServeConfig(batch_slots=slots, max_seq=64, prefill_buckets=(8,))
    eng = _toy_engine(scfg, admission=ctrl, metrics=metrics,
                      journal=journal, clock=vc, pace="virtual",
                      step_dt=step_dt)
    tenants = uniform_mix(2, rate=rate, deadline_s=deadline_s,
                          max_new=(4, 8), prompt_len=(2, 6))
    trace = make_trace(tenants, duration, seed=seed, vocab=V,
                       **(trace_kw or {}))
    if ctrl is not None:
        ctrl.register_tenants(tenants)
    return eng, trace, metrics


# ---------------------------------------------------------------------------
# traffic generation
# ---------------------------------------------------------------------------

def test_trace_same_seed_is_byte_identical():
    mix = uniform_mix(3, rate=11.0, deadline_s=0.25)
    a = make_trace(mix, 2.0, seed=SEED, vocab=64)
    b = make_trace(mix, 2.0, seed=SEED, vocab=64)
    assert a == b
    assert trace_digest(a) == trace_digest(b)
    c = make_trace(mix, 2.0, seed=SEED + 1, vocab=64)
    assert trace_digest(c) != trace_digest(a)


def test_trace_is_sorted_with_sequential_rids():
    t = make_trace(noisy_neighbor_mix(), 2.0, seed=SEED, vocab=64)
    assert [r.rid for r in t] == list(range(len(t)))
    arr = [r.t_arrival for r in t]
    assert arr == sorted(arr)
    assert {r.tenant for r in t} == {"victim", "flood"}


def test_trace_scale_densifies_not_reshapes():
    """2x scale doubles the arrival density but keeps every tenant's
    request-shape stream aligned (the 1x-vs-2x benchmark contract)."""
    mix = uniform_mix(2, rate=10.0)
    one = make_trace(mix, 3.0, seed=SEED, vocab=64)
    two = make_trace(mix, 3.0, seed=SEED, vocab=64, scale=2.0)
    assert len(two) > 1.5 * len(one)
    for tenant in ("t0", "t1"):
        a = [(r.prompt, r.max_new) for r in one if r.tenant == tenant]
        b = [(r.prompt, r.max_new) for r in two if r.tenant == tenant]
        # shape draws are keyed per-tenant by arrival index, so the
        # 1x stream is a prefix of the densified 2x stream
        assert b[:len(a)] == a


def test_trace_digest_matches_across_processes():
    mix = uniform_mix(2, rate=8.0, deadline_s=0.5)
    want = trace_digest(make_trace(mix, 2.0, seed=SEED, vocab=32))
    code = (
        "from repro.serve import make_trace, trace_digest, uniform_mix\n"
        f"mix = uniform_mix(2, rate=8.0, deadline_s=0.5)\n"
        f"t = make_trace(mix, 2.0, seed={SEED}, vocab=32)\n"
        "print(trace_digest(t))\n")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=120,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip() == want


def test_arrival_burst_overlay_adds_arrivals_in_window():
    mix = uniform_mix(2, rate=5.0)
    base = make_trace(mix, 2.0, seed=SEED, vocab=32)
    plan = FaultPlan(seed=7, arrival_burst={
        "t0": {"at_s": 0.5, "dur_s": 0.5, "rate": 60.0}})
    inj = plan.injector()
    burst = make_trace(mix, 2.0, seed=SEED, vocab=32, faults=inj)
    extra = len(burst) - len(base)
    assert extra > 10
    # overlay arrivals land inside the window, on the targeted tenant
    base_t0 = [r.t_arrival for r in base if r.tenant == "t0"]
    burst_t0 = [r.t_arrival for r in burst if r.tenant == "t0"]
    new_times = sorted(set(burst_t0) - set(base_t0))
    assert new_times and all(0.5 <= t < 1.0 for t in new_times)
    assert [r.t_arrival for r in burst if r.tenant == "t1"] == \
        [r.t_arrival for r in base if r.tenant == "t1"]
    assert any(e[0] == "arrival_burst" and e[1] == "t0"
               for e in inj.log)


def test_tenant_flood_overlay_injects_low_priority_tenant():
    mix = uniform_mix(1, rate=4.0)
    plan = FaultPlan(seed=3, tenant_flood={
        "flood": {"rate": 50.0, "start_s": 0.0, "dur_s": 1.0}})
    inj = plan.injector()
    assert inj.affects_traffic
    t = make_trace(mix, 2.0, seed=SEED, vocab=32, faults=inj)
    flood = [r for r in t if r.tenant == "flood"]
    assert len(flood) > 20
    assert all(r.t_arrival < 1.0 for r in flood)
    assert any(e[0] == "tenant_flood" for e in inj.log)
    # fault seed is independent of the traffic seed: the base tenant's
    # arrivals are untouched by the overlay
    base = make_trace(mix, 2.0, seed=SEED, vocab=32)
    assert [r.t_arrival for r in t if r.tenant == "t0"] == \
        [r.t_arrival for r in base]


# ---------------------------------------------------------------------------
# admission controller: fair queuing
# ---------------------------------------------------------------------------

def _req(rid, tenant, max_new=8, prompt_len=0, deadline=None, t_arr=None):
    return Request(rid=rid, prompt=[1] * prompt_len, max_new=max_new,
                   deadline_s=deadline, tenant=tenant, t_arrival=t_arr)


def test_drr_equal_weights_alternate():
    # quantum == request cost: one serve per turn -> strict alternation
    ctrl = AdmissionController(AdmissionConfig(queue_limit=64,
                                               quantum_tokens=8.0))
    ctrl.register("a")
    ctrl.register("b")
    for i in range(8):
        assert ctrl.offer(_req(i, "a" if i < 4 else "b")) is None
    order = [ctrl.pop().tenant for _ in range(8)]
    assert order.count("a") == order.count("b") == 4
    assert all(x != y for x, y in zip(order, order[1:]))


def test_drr_weight_scales_token_share():
    ctrl = AdmissionController(AdmissionConfig(queue_limit=1000,
                                               quantum_tokens=8.0))
    ctrl.register("heavy", weight=2.0)
    ctrl.register("light", weight=1.0)
    for i in range(60):
        ctrl.offer(_req(i, "heavy" if i % 2 else "light", max_new=8))
    first = [ctrl.pop().tenant for _ in range(30)]
    share = first.count("heavy") / len(first)
    # weight 2 gets ~2/3 of the dispatched token budget while both are
    # backlogged
    assert 0.55 < share < 0.8, share


def test_priority_class_served_first():
    ctrl = AdmissionController(AdmissionConfig(queue_limit=64))
    ctrl.register("bulk", priority=1)
    ctrl.register("interactive", priority=0)
    for i in range(6):
        ctrl.offer(_req(i, "bulk"))
    for i in range(6, 9):
        ctrl.offer(_req(i, "interactive"))
    order = [ctrl.pop().tenant for _ in range(9)]
    assert order[:3] == ["interactive"] * 3
    assert order[3:] == ["bulk"] * 6


def test_unregistered_tenant_autoregisters():
    ctrl = AdmissionController(AdmissionConfig(queue_limit=4))
    assert ctrl.offer(_req(0, "surprise")) is None
    assert ctrl.pop().tenant == "surprise"
    assert ctrl.pop() is None


# ---------------------------------------------------------------------------
# admission controller: shedding
# ---------------------------------------------------------------------------

def test_reject_new_sheds_past_queue_limit():
    metrics = ServeMetrics(clock=lambda: 0.0)
    ctrl = AdmissionController(AdmissionConfig(queue_limit=2,
                                               retry_after_s=0.25),
                               metrics=metrics)
    verdicts = [ctrl.offer(_req(i, "t0")) for i in range(5)]
    assert verdicts[:2] == [None, None]
    for v in verdicts[2:]:
        assert isinstance(v, RequestError)
        assert v.status == "overloaded" and v.retry_after_s == 0.25
    assert ctrl.backlog() == 2 and ctrl.shed_total == 3
    assert metrics.shed_reasons == {"reject-new": 3}


def test_drop_oldest_evicts_lowest_priority_backlog():
    ctrl = AdmissionController(AdmissionConfig(shed_policy="drop-oldest",
                                               queue_limit=4))
    ctrl.register("victim", priority=0)
    ctrl.register("flood", priority=1)
    for i in range(2):
        assert ctrl.offer(_req(i, "victim")) is None
    for i in range(2, 4):
        assert ctrl.offer(_req(i, "flood")) is None
    # queue full: a new victim arrival evicts the FLOOD's oldest, not
    # its own tenant's — the flooder absorbs the shedding
    assert ctrl.offer(_req(4, "victim")) is None
    errs = ctrl.drain_errors()
    assert len(errs) == 1 and errs[0].rid == 2
    assert errs[0].status == "overloaded"
    assert ctrl.backlog() == 4
    tenants = []
    while (r := ctrl.pop()) is not None:
        tenants.append((r.rid, r.tenant))
    assert (2, "flood") not in tenants
    assert {rid for rid, _ in tenants} == {0, 1, 3, 4}


def test_deadline_infeasible_shed_at_offer():
    clock = VirtualClock()
    ctrl = AdmissionController(
        AdmissionConfig(est_token_s=0.1, queue_limit=64), clock=clock)
    # 8 tokens x 0.1 s/token = 0.8s estimated > 0.3s budget
    v = ctrl.offer(_req(0, "t0", max_new=8, deadline=0.3, t_arr=0.0))
    assert isinstance(v, RequestError) and v.status == "overloaded"
    assert "deadline" in v.detail
    # a feasible deadline is admitted
    assert ctrl.offer(_req(1, "t0", max_new=2, deadline=5.0,
                           t_arr=0.0)) is None


def test_deadline_infeasible_shed_at_dispatch():
    clock = VirtualClock()
    ctrl = AdmissionController(
        AdmissionConfig(est_token_s=0.01, queue_limit=64), clock=clock)
    assert ctrl.offer(_req(0, "t0", max_new=4, deadline=0.5,
                           t_arr=0.0)) is None
    clock.advance(10.0)                    # request went stale in queue
    assert ctrl.pop() is None
    errs = ctrl.drain_errors()
    assert [e.rid for e in errs] == [0]
    assert errs[0].status == "overloaded" and "unreachable" in errs[0].detail


def test_token_latency_ewma_refines_estimate():
    ctrl = AdmissionController(AdmissionConfig(est_token_s=0.0, ewma=0.5))
    assert ctrl.token_s == 0.0
    ctrl.observe_token_latency(0.1)        # first sample seeds the EWMA
    assert ctrl.token_s == pytest.approx(0.1)
    ctrl.observe_token_latency(0.2)
    assert ctrl.token_s == pytest.approx(0.15)
    ctrl.observe_token_latency(-1.0)       # non-positive samples ignored
    assert ctrl.token_s == pytest.approx(0.15)


def test_admission_config_rejects_unknown_policy():
    with pytest.raises(ValueError, match="shed_policy"):
        AdmissionConfig(shed_policy="fifo")


def test_metrics_accounting_invariant_catches_leaks():
    m = ServeMetrics(clock=lambda: 0.0)
    m.note_offered("a")
    m.note_admitted("a")
    m.note_offered("a")
    with pytest.raises(AssertionError, match="offered 2"):
        m.check_accounting()
    m.note_shed("a", "reject-new")
    m.check_accounting()


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_transitions_on_fake_clock():
    clock = VirtualClock()
    br = CircuitBreaker(fail_threshold=3, cooldown_s=1.0, clock=clock)
    br.failure("e1")
    br.failure("e2")
    assert br.state == "closed"
    br.check()                              # still closed: no-op
    br.failure("e3")
    assert br.state == "open"
    with pytest.raises(BreakerOpen) as ei:
        br.check()
    assert 0 < ei.value.retry_after_s <= 1.0
    clock.advance(1.5)                      # cooldown elapses
    br.check()                              # admits the probe
    assert br.state == "half-open"
    br.failure("probe died")
    assert br.state == "open"               # probe failure re-opens
    clock.advance(1.5)
    br.check()
    br.success()
    assert br.state == "closed" and br.consecutive == 0
    states = [(frm, to) for _, frm, to, _ in br.log]
    assert states == [("closed", "open"), ("open", "half-open"),
                      ("half-open", "open"), ("open", "half-open"),
                      ("half-open", "closed")]


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(fail_threshold=2, clock=VirtualClock())
    br.failure()
    br.success()
    br.failure()
    assert br.state == "closed"             # never two in a row


def test_breaker_e2e_fast_fails_requests_while_open():
    """First real step failure opens the breaker (threshold 1, huge
    cooldown): every subsequent request fast-fails with a structured
    "overloaded" verdict and a retry hint, no compute spent."""
    scfg = ServeConfig(batch_slots=1, max_seq=32, max_retries=0,
                       prefill_buckets=(8,))
    br = CircuitBreaker(fail_threshold=1, cooldown_s=1e9)
    eng = _toy_engine(scfg, breaker=br,
                      faults=FaultPlan(transient={"decode": 1}))
    reqs = [Request(rid=i, prompt=[i], max_new=3) for i in range(4)]
    res = serve_requests(eng, reqs)
    assert len(res) == 4
    assert isinstance(res[0], RequestError)         # the opening failure
    for rid in (1, 2, 3):
        assert isinstance(res[rid], RequestError), rid
        assert res[rid].status == "overloaded"
        assert res[rid].retry_after_s > 0
    assert br.state == "open"


def test_breaker_e2e_half_open_probe_recovers():
    """cooldown 0: after opening, the next step call is admitted as a
    half-open probe; once the injected transients run out the probe
    succeeds, the breaker closes, and serving finishes normally.

    The faults hit *prefill* so the failures are consecutive across
    requests — a decode failure retires its slot, and the next request's
    successful prefill would reset the consecutive count."""
    scfg = ServeConfig(batch_slots=1, max_seq=32, max_retries=0,
                       prefill_buckets=(8,))
    br = CircuitBreaker(fail_threshold=2, cooldown_s=0.0)
    eng = _toy_engine(scfg, breaker=br,
                      faults=FaultPlan(transient={"prefill": 3}))
    reqs = [Request(rid=i, prompt=[i], max_new=3) for i in range(6)]
    res = serve_requests(eng, reqs)
    assert len(res) == 6
    assert br.state == "closed"
    states = [(frm, to) for _, frm, to, _ in br.log]
    assert states == [("closed", "open"), ("open", "half-open"),
                      ("half-open", "open"), ("open", "half-open"),
                      ("half-open", "closed")]
    # the tail requests decode clean once the breaker closes
    ok = [rid for rid, v in res.items() if not isinstance(v, RequestError)]
    assert len(ok) >= 3


# ---------------------------------------------------------------------------
# hardening satellites: backoff + bounded admission wait
# ---------------------------------------------------------------------------

def test_backoff_total_capped_per_step_call():
    """Seed bug: base * 2**attempt backoff was uncapped — a few retries
    could stall the decode loop for minutes.  The total backoff for one
    step call is now bounded by retry_max_s."""
    scfg = ServeConfig(batch_slots=1, max_seq=32, max_retries=4,
                       retry_base_s=10.0, retry_max_s=0.05,
                       prefill_buckets=(8,))
    eng = _toy_engine(scfg, faults=FaultPlan(transient={"decode": 3}))
    t0 = time.perf_counter()
    res = serve_requests(eng, [Request(rid=0, prompt=[1], max_new=3)])
    wall = time.perf_counter() - t0
    assert res[0] == [2, 3, 4]              # retries eventually succeed
    assert len(eng.retry_log) == 3
    assert wall < 2.0, f"backoff not capped: {wall:.1f}s"


def test_backoff_never_sleeps_past_live_deadline():
    scfg = ServeConfig(batch_slots=1, max_seq=32,
                       retry_base_s=1.0, retry_max_s=60.0)
    clock = VirtualClock()
    eng = _toy_engine(scfg, clock=clock)
    slot = {"rid": 0, "deadline": 0.02, "t0": 0.0, "t_arr": None}
    t0 = time.perf_counter()
    slept = eng._backoff(6, 0.0, [slot])    # exponential term: 64s
    assert time.perf_counter() - t0 < 1.0
    assert slept <= 0.02 + 1e-6             # clamped to deadline remaining
    # without a deadline the cap is retry_max_s - slept
    slept = eng._backoff(6, 59.99, [{"rid": 1, "deadline": None,
                                     "t0": 0.0}])
    assert slept <= 0.01 + 1e-6


def test_full_queue_blocking_default_still_serves_all():
    """Seed behaviour preserved: without admit_timeout_s the frontend
    blocks on a full request channel (cooperative hand-off) and every
    request is eventually served."""
    scfg = ServeConfig(batch_slots=1, max_seq=32, queue_cap=2,
                       prefill_buckets=(8,))
    reqs = [Request(rid=i, prompt=[i % V], max_new=2) for i in range(12)]
    res = serve_requests(_toy_engine(scfg), reqs)
    assert len(res) == 12
    assert not any(isinstance(v, RequestError) for v in res.values())


def test_full_queue_fail_fast_with_admit_timeout(tmp_path):
    """With admit_timeout_s set, a frontend facing a persistently full
    channel sheds with a journaled structured "overloaded" error after
    the bounded wait instead of blocking forever."""
    def prefill(toks):
        last = int(toks[0, -1]) % V
        return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

    def decode(tok, cache):
        time.sleep(0.01)                    # slow backend: queue backs up
        return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

    # queue_cap == one transaction (hdr + 1 prompt token + EoT): each
    # buffered request fills the channel exactly, so a stalled scheduler
    # leaves it observably full at the next offer — the stuck-backend
    # shape the bounded wait exists for
    scfg = ServeConfig(batch_slots=1, max_seq=32, queue_cap=3,
                       admit_timeout_s=0.01, prefill_buckets=(8,))
    jp = tmp_path / "j.jsonl"
    metrics = ServeMetrics()
    eng = ServingEngine(scfg, prefill, decode, journal=jp, metrics=metrics)
    reqs = [Request(rid=i, prompt=[i % V], max_new=4) for i in range(20)]
    res = serve_requests(eng, reqs, sim_engine="thread")
    assert len(res) == 20                   # nobody silently dropped
    shed = {r for r, v in res.items()
            if isinstance(v, RequestError) and v.status == "overloaded"}
    served = {r for r, v in res.items() if not isinstance(v, RequestError)}
    assert shed, "expected overload sheds from the full queue"
    assert served, "expected some requests served"
    assert shed | served == set(range(20))
    metrics.check_accounting()
    # every shed was journaled write-ahead: a replay folds it to a verdict
    completed, _ = ServeJournal.replay(jp)
    for rid in shed:
        assert completed[rid][0] == "overloaded", rid


# ---------------------------------------------------------------------------
# journal: overload records
# ---------------------------------------------------------------------------

def test_journal_shed_records_fold_to_verdicts(tmp_path):
    j = ServeJournal(tmp_path / "j.jsonl")
    j.admit(0, [1, 2], 4, None)
    j.tok(0, 3)
    j.shed(1, detail="queue full (8 backlogged)")
    j.retire(0, toks=[3, 4])
    j.shed(2, detail="deadline 0.2s unreachable")
    j.close()
    completed, inflight = ServeJournal.replay(tmp_path / "j.jsonl")
    assert completed[0] == [3, 4]
    assert completed[1] == ("overloaded", "queue full (8 backlogged)")
    assert completed[2] == ("overloaded", "deadline 0.2s unreachable")
    assert not inflight


def test_journal_shed_then_restart_never_readmits(tmp_path):
    """Crash-restart exactly-once for sheds: a rid shed before the crash
    answers from the journal on replay — it must not be recomputed or
    re-admitted even though capacity is now free."""
    jp = tmp_path / "j.jsonl"
    j = ServeJournal(jp)
    j.shed(1, detail="queue full")
    j.close()
    scfg = ServeConfig(batch_slots=2, max_seq=32, prefill_buckets=(8,))
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(3)]
    res = serve_requests(_toy_engine(scfg, journal=jp), reqs)
    assert res[0] == [2, 3] and res[2] == [4, 5]
    assert isinstance(res[1], RequestError)
    assert res[1].status == "overloaded" and "queue full" in res[1].detail
    # the replayed verdict is not re-journaled as new work
    completed, inflight = ServeJournal.replay(jp)
    assert completed[1] == ("overloaded", "queue full") and not inflight


def test_controller_replays_journaled_shed_verdict(tmp_path):
    jp = tmp_path / "j.jsonl"
    j = ServeJournal(jp)
    j.shed(5, detail="dropped for newer arrival 9")
    j.retire(6, toks=[1, 2])
    j.close()
    metrics = ServeMetrics(clock=lambda: 0.0)
    ctrl = AdmissionController(AdmissionConfig(), journal=ServeJournal(jp),
                               metrics=metrics, clock=lambda: 0.0)
    v5 = ctrl.offer(_req(5, "t0"))
    assert v5 == ("replayed", ("overloaded", "dropped for newer arrival 9"))
    v6 = ctrl.offer(_req(6, "t0"))
    assert v6 == ("replayed", [1, 2])
    metrics.check_accounting()              # replays keep the invariant


def test_journal_torn_tail_with_interleaved_overload_records(tmp_path):
    p = tmp_path / "j.jsonl"
    j = ServeJournal(p)
    j.admit(0, [1], 3, None)
    j.shed(1, detail="reject-new")
    j.tok(0, 2)
    j.admit(2, [5], 2, None)
    j.retire(0, toks=[2, 3, 4])
    j.close()
    with open(p, "a") as f:
        f.write('{"t":"shed","rid":2,"de')   # crash mid-append
    completed, inflight = ServeJournal.replay(p)
    assert completed[0] == [2, 3, 4]
    assert completed[1] == ("overloaded", "reject-new")
    assert inflight[2]["toks"] == []         # torn shed dropped: still live
    j2 = ServeJournal(p)                     # reopen repairs the tail
    j2.shed(2, detail="re-shed after restart")
    j2.close()
    completed, inflight = ServeJournal.replay(p)
    assert completed[2] == ("overloaded", "re-shed after restart")
    assert not inflight


# ---------------------------------------------------------------------------
# end-to-end: deterministic virtual-time overload runs
# ---------------------------------------------------------------------------

def test_virtual_overload_accounting_and_total_answers():
    eng, trace, metrics = _virtual_setup()
    res = serve_requests(eng, trace)
    assert len(res) == len(trace)           # no silent absence, ever
    metrics.check_accounting()
    summ = metrics.summary()
    assert summ["offered"] == len(trace)
    assert summ["shed"] > 0, "overload run should shed"
    assert summ["admitted"] + summ["shed"] == summ["offered"]
    for r in trace:
        v = res[r.rid]
        assert isinstance(v, (list, RequestError)), r.rid


def test_virtual_overload_is_deterministic_in_process():
    runs = []
    for _ in range(2):
        eng, trace, metrics = _virtual_setup()
        res = serve_requests(eng, trace)
        runs.append((sorted(res.items(), key=lambda kv: kv[0]).__repr__(),
                     metrics.summary()))
    assert runs[0][0] == runs[1][0]
    assert runs[0][1] == runs[1][1]


def test_virtual_sheds_respect_priority_classes():
    """Noisy neighbor under drop-oldest: a full queue evicts from the
    lowest-priority backlogged tenant, so the flooder absorbs the
    shedding and the interactive victim keeps a materially higher admit
    rate (reject-new would shed whoever happens to arrive)."""
    vc = VirtualClock()
    metrics = ServeMetrics()
    ctrl = AdmissionController(AdmissionConfig(shed_policy="drop-oldest",
                                               est_token_s=0.02,
                                               queue_limit=6))
    scfg = ServeConfig(batch_slots=2, max_seq=64, prefill_buckets=(8,))
    eng = _toy_engine(scfg, admission=ctrl, metrics=metrics, clock=vc,
                      pace="virtual", step_dt=0.02)
    mix = noisy_neighbor_mix(victim_rate=4.0, flood_rate=40.0,
                             deadline_s=1.0)
    ctrl.register_tenants(mix)
    trace = make_trace(mix, 3.0, seed=SEED, vocab=V)
    res = serve_requests(eng, trace)
    assert len(res) == len(trace)
    metrics.check_accounting()
    t = metrics.summary()["tenants"]
    v_admit = t["victim"]["admitted"] / max(1, t["victim"]["offered"])
    f_admit = t["flood"]["admitted"] / max(1, t["flood"]["offered"])
    assert t["flood"]["shed"] > 0
    assert v_admit > f_admit + 0.2, (v_admit, f_admit)


@pytest.mark.parametrize("seed", [SEED, SEED + 1])
def test_shed_off_collapses_where_shed_on_holds(seed):
    """The benchmark's collapse arm, asserted in simulated time: the same
    supersaturated trace violates deadlines without admission control,
    while with shedding every admitted request's TTFT stays inside the
    deadline (the infeasible ones were shed up front)."""
    deadline = 0.3
    kw = dict(duration=1.5, rate=30.0, deadline_s=deadline, seed=seed,
              step_dt=0.02, slots=2)
    eng_off, trace, m_off = _virtual_setup(shed=False, **kw)
    res_off = serve_requests(eng_off, trace)
    late = [v for v in res_off.values()
            if isinstance(v, RequestError) and v.status == "deadline"]
    assert late, "shed-off arm must blow deadlines"
    assert m_off.deadline_violations == len(late)

    eng_on, trace_on, m_on = _virtual_setup(shed=True, **kw)
    assert trace_digest(trace_on) == trace_digest(trace)
    res_on = serve_requests(eng_on, trace_on)
    assert len(res_on) == len(trace_on)
    m_on.check_accounting()
    summ = m_on.summary()
    assert summ["shed"] > 0
    assert summ["deadline_violations"] < len(late)
    if summ["ttft_p99_s"] is not None:
        assert summ["ttft_p99_s"] <= deadline


_REPLAY_PROC = r"""
import sys
from repro.serve import (AdmissionConfig, AdmissionController, ServeConfig,
                         ServeMetrics, VirtualClock, make_trace,
                         serve_requests, uniform_mix)
import numpy as np
from repro.serve import ServingEngine

V = 16
def prefill(toks):
    last = int(toks[0, -1]) % V
    return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}
def decode(tok, cache):
    return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

seed, path = int(sys.argv[1]), sys.argv[2]
vc = VirtualClock()
ctrl = AdmissionController(AdmissionConfig(est_token_s=0.01, queue_limit=8))
mix = uniform_mix(2, rate=35.0, deadline_s=0.4, max_new=(4, 8),
                  prompt_len=(2, 6))
ctrl.register_tenants(mix)
eng = ServingEngine(ServeConfig(batch_slots=2, max_seq=64,
                                prefill_buckets=(8,)),
                    prefill, decode, admission=ctrl, journal=path,
                    metrics=ServeMetrics(), clock=vc, pace="virtual",
                    step_dt=0.01)
trace = make_trace(mix, 2.0, seed=seed, vocab=V)
res = serve_requests(eng, trace)
assert len(res) == len(trace)
eng.journal.close()
"""


def test_overload_journal_is_byte_identical_across_processes(tmp_path):
    """The replay contract end-to-end: two processes running the same
    seeded overload trace under virtual time write byte-identical
    admit/shed/tok/retire journals."""
    digests = []
    for run in ("a", "b"):
        jp = tmp_path / f"{run}.jsonl"
        r = subprocess.run(
            [sys.executable, "-c", _REPLAY_PROC, str(SEED), str(jp)],
            capture_output=True, text=True, timeout=300,
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu", "HOME": str(tmp_path)})
        assert r.returncode == 0, r.stderr[-3000:]
        blob = jp.read_bytes()
        assert b'"shed"' in blob            # the run actually shed
        digests.append(hashlib.sha256(blob).hexdigest())
    assert digests[0] == digests[1]
