"""Serving-engine semantics: admission, batched decode, eos, slot churn.

The fast section drives both decode paths with toy step functions (the
batched toy adapter is a pure-jnp counter model so its compiles are
trivial); the slow section checks batched-vs-per-slot greedy parity on a
real reduced model and cross-process compile-cache reuse.
"""

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compile_cache import CompileCache
from repro.models.lm import ServingAdapter
from repro.serve import Request, ServeConfig, ServingEngine, serve_requests

SRC = str(Path(__file__).resolve().parent.parent / "src")

V = 16   # toy vocab


# ---------------------------------------------------------------------------
# toy engines for both paths: next token = (prev + 1) % V
# ---------------------------------------------------------------------------

def toy_per_slot_engine(scfg: ServeConfig) -> ServingEngine:
    def prefill(toks):
        last = int(toks[0, -1]) % V
        return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

    def decode(tok, cache):
        return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

    return ServingEngine(scfg, prefill, decode)


def toy_batched_adapter(max_seq: int) -> ServingAdapter:
    """Minimal ServingAdapter: the 'model' is a mod-V counter.  The packed
    cache is {"len": [slots], "last": [1, slots]} — every non-"len" leaf
    carries its batch on axis 1, exactly like the real KV pytree."""

    def prefill_fn(tokens, true_len, step):
        idx = jnp.clip(true_len - 1, 0, tokens.shape[1] - 1)
        last = jnp.take_along_axis(tokens, idx[:, None], axis=1)[:, 0]
        first = (last + 1) % V
        cache = {"len": jnp.asarray(true_len, jnp.int32),
                 "last": first[None].astype(jnp.int32)}
        return first.astype(jnp.int32), cache

    def step_fn(tokens, packed, step):
        live = packed["len"] > 0
        nxt = jnp.where(live, (tokens + 1) % V, 0).astype(jnp.int32)
        return nxt, {"len": jnp.where(live, packed["len"] + 1, 0),
                     "last": nxt[None]}

    from repro.models.lm import retire_slot, write_slot

    class ToyAdapter(ServingAdapter):
        def init_slots(self, slots, abstract=False):
            mk = (jax.ShapeDtypeStruct if abstract
                  else lambda s, d: jnp.zeros(s, d))
            return {"len": mk((slots,), jnp.int32),
                    "last": mk((1, slots), jnp.int32)}

    return ToyAdapter(cfg=None, max_seq=max_seq,
                      prefill_fn=prefill_fn, step_fn=step_fn,
                      write_slot_fn=write_slot, retire_fn=retire_slot)


def toy_batched_engine(scfg: ServeConfig) -> ServingEngine:
    eng = ServingEngine(scfg, batched=toy_batched_adapter(scfg.max_seq))
    info = eng.warmup(cache=CompileCache(disk=False))
    assert info["ok"], info
    return eng


ENGINES = {"per_slot": toy_per_slot_engine, "batched": toy_batched_engine}


def expected(prompt, max_new, eos=-1):
    last = (prompt[-1] if prompt else 0) % V
    out = []
    for _ in range(max_new):
        last = (last + 1) % V
        out.append(last)
        if eos >= 0 and last == eos:
            break
    return out


# ---------------------------------------------------------------------------
# semantics both decode paths must preserve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_eos_token_early_stop(variant):
    scfg = ServeConfig(batch_slots=2, max_seq=32, eos_token=5,
                       prefill_buckets=(8,))
    eng = ENGINES[variant](scfg)
    # prompt ends at 3 -> generates 4, 5(eos): stops after 2 of 8 tokens;
    # prompt ends at 5 -> generates 6..: runs to max_new
    reqs = [Request(0, [1, 2, 3], max_new=8),
            Request(1, [5], max_new=4)]
    res = serve_requests(eng, reqs)
    assert res[0] == [4, 5]
    assert res[1] == [6, 7, 8, 9]


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_more_requests_than_slots_churn(variant):
    scfg = ServeConfig(batch_slots=2, max_seq=32, prefill_buckets=(8,))
    eng = ENGINES[variant](scfg)
    reqs = [Request(i, [(3 * i) % V], max_new=2 + i % 3)
            for i in range(9)]
    res = serve_requests(eng, reqs)
    assert set(res) == set(range(9))
    for r in reqs:
        assert res[r.rid] == expected(r.prompt, r.max_new), r.rid


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_empty_prompt_and_zero_max_new(variant):
    scfg = ServeConfig(batch_slots=2, max_seq=32, prefill_buckets=(8,))
    eng = ENGINES[variant](scfg)
    res = serve_requests(eng, [Request(0, [], max_new=3),
                               Request(1, [4, 5], max_new=0),
                               Request(2, [7], max_new=2)])
    # empty prompt decodes from a single pad token (token 0)
    assert res[0] == [1, 2, 3]
    assert res[1] == []
    assert res[2] == [8, 9]


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_max_seq_capacity_stop(variant):
    """A request whose prompt + generation would overflow the cache is
    retired at the capacity bound instead of scattering out of range."""
    scfg = ServeConfig(batch_slots=1, max_seq=8, prefill_buckets=(8,))
    eng = ENGINES[variant](scfg)
    res = serve_requests(eng, [Request(0, [1, 2, 3, 4], max_new=32)])
    assert res[0] == expected([1, 2, 3, 4], 4)   # 4 + 4 = max_seq


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_prompt_longer_than_largest_bucket(variant):
    """A prompt that fits no configured bucket pads straight to max_seq
    (and an over-long prompt keeps its most recent max_seq-1 tokens)."""
    scfg = ServeConfig(batch_slots=1, max_seq=16, prefill_buckets=(4,))
    eng = ENGINES[variant](scfg)
    res = serve_requests(eng, [Request(0, [1] * 9 + [7], max_new=2),
                               Request(1, list(range(40)), max_new=2)])
    assert res[0] == [8, 9]
    # 40-token prompt keeps its last 15 tokens (last = 39 = 7 mod V) and
    # the capacity stop retires it after one token (15 + 1 == max_seq)
    assert res[1] == [8]


def test_batched_single_step_call_per_iteration():
    """The tentpole invariant: one jitted decode call per iteration,
    independent of how many slots are live."""
    scfg = ServeConfig(batch_slots=4, max_seq=32, prefill_buckets=(8,))
    eng = toy_batched_engine(scfg)
    calls = {"n": 0}
    step_exe = eng._exe[("step",)]

    def counting(*args):
        calls["n"] += 1
        return step_exe(*args)

    eng._exe[("step",)] = counting
    # one admission wave, staggered finishes: slots stay ragged throughout
    reqs = [Request(i, [i], max_new=mn)
            for i, mn in enumerate((3, 5, 7, 9))]
    res = serve_requests(eng, reqs)
    for r in reqs:
        assert res[r.rid] == expected(r.prompt, r.max_new)
    # the longest request needs 8 decode steps after its prefill token;
    # a per-slot loop would have paid 3+5+7+9-4 = 20 decode calls
    assert calls["n"] == 8, calls["n"]


def test_admission_consumes_peeked_header_once():
    """Regression for the double-peek bug: the scheduler must base
    admission on the peeked header and consume it exactly once (prompt
    token counts must never shift by a stale header read)."""
    scfg = ServeConfig(batch_slots=1, max_seq=32, prefill_buckets=(8,))
    eng = toy_batched_engine(scfg)
    reqs = [Request(i, [(i + 1) % V, (i + 2) % V], max_new=2)
            for i in range(6)]
    res = serve_requests(eng, reqs)
    for r in reqs:
        assert res[r.rid] == expected(r.prompt, r.max_new), r.rid


def test_warmup_reports_bucket_sources():
    scfg = ServeConfig(batch_slots=2, max_seq=32)
    eng = ServingEngine(scfg, batched=toy_batched_adapter(32))
    cc = CompileCache(disk=False)
    info = eng.warmup(cache=cc)
    assert info["ok"]
    assert set(info["buckets"]) == {"1x8", "1x16", "1x32"}
    assert all(v == "compiled" for v in info["buckets"].values())
    assert info["decode"] == "compiled"
    # same process, fresh engine: everything resolves from memory
    eng2 = ServingEngine(scfg, batched=toy_batched_adapter(32))
    info2 = eng2.warmup(cache=cc)
    assert all(v == "memory" for v in info2["buckets"].values())
    assert info2["decode"] == "memory"


# ---------------------------------------------------------------------------
# real model: batched fast path == per-slot seed path (greedy)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_matches_per_slot_on_real_model():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("qwen3-0.6b").with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    max_seq = 32
    scfg = ServeConfig(batch_slots=3, max_seq=max_seq)

    @jax.jit
    def prefill_fn(tokens):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq)

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab,
                                    1 + int(rng.integers(0, 13))).tolist(),
                    max_new=4)
            for i in range(7)]
    reqs.append(Request(7, [], max_new=3))            # empty prompt

    want = serve_requests(ServingEngine(scfg, prefill_fn, decode_fn), reqs)

    adapter = lm.serving_adapter(params, cfg, max_seq=max_seq)
    eng = ServingEngine(scfg, batched=adapter)
    assert eng.warmup(cache=CompileCache(disk=False))["ok"]
    got = serve_requests(eng, reqs)
    for r in reqs:
        assert got[r.rid] == want[r.rid], r.rid


@pytest.mark.slow
def test_serving_adapter_rejects_recurrent_families():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("mamba2-130m").with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="per-slot"):
        lm.serving_adapter(params, cfg, max_seq=32)


@pytest.mark.slow
def test_on_device_sampling_temperature_topk():
    """temperature>0 sampling stays inside the model's support and top_k=1
    degenerates to greedy."""
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("qwen3-0.6b").with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    max_seq = 32
    scfg = ServeConfig(batch_slots=2, max_seq=max_seq)
    reqs = [Request(0, [1, 2, 3], max_new=4), Request(1, [9], max_new=4)]

    greedy_ad = lm.serving_adapter(params, cfg, max_seq=max_seq)
    eng_g = ServingEngine(scfg, batched=greedy_ad)
    assert eng_g.warmup(cache=CompileCache(disk=False))["ok"]
    want = serve_requests(eng_g, reqs)

    topk1 = lm.serving_adapter(params, cfg, max_seq=max_seq,
                               temperature=0.7, top_k=1)
    eng_k = ServingEngine(scfg, batched=topk1)
    assert eng_k.warmup(cache=CompileCache(disk=False))["ok"]
    assert serve_requests(eng_k, reqs) == want

    hot = lm.serving_adapter(params, cfg, max_seq=max_seq,
                             temperature=1.5, top_k=8, seed=3)
    eng_h = ServingEngine(scfg, batched=hot)
    assert eng_h.warmup(cache=CompileCache(disk=False))["ok"]
    res = serve_requests(eng_h, reqs)
    assert all(0 <= t < cfg.vocab for seq in res.values() for t in seq)
    assert [len(v) for v in res.values()] == [4, 4]


# ---------------------------------------------------------------------------
# cross-process: a warm serving process pays zero XLA compiles
# ---------------------------------------------------------------------------

_SERVE_PROC = r"""
import json
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serve import Request, ServeConfig, ServingEngine, serve_requests

cfg = get_config("qwen3-0.6b").with_reduced()
params = lm.init_params(cfg, jax.random.key(0))
max_seq = 32
adapter = lm.serving_adapter(params, cfg, max_seq=max_seq)
eng = ServingEngine(ServeConfig(batch_slots=2, max_seq=max_seq),
                    batched=adapter)
info = eng.warmup()
assert info["ok"], info
rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(0, cfg.vocab, 4 + 3 * (i % 3)).tolist(), 3)
        for i in range(5)]
res = serve_requests(eng, reqs)
assert len(res) == 5 and all(len(v) == 3 for v in res.values())
report = {"warmup": info,
          "log": [[k, list(map(int, np.ravel(s))), src]
                  for k, s, src in eng.compile_log]}
print("REPORT " + json.dumps(report))
"""


@pytest.mark.slow
def test_second_serving_process_compiles_nothing(tmp_path):
    outs = []
    for _ in range(2):
        r = subprocess.run(
            [sys.executable, "-c", _SERVE_PROC], capture_output=True,
            text=True, timeout=600,
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                 "REPRO_COMPILE_CACHE": str(tmp_path),
                 "JAX_PLATFORMS": "cpu", "HOME": str(tmp_path)})
        assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("REPORT")]
        outs.append(json.loads(line[0][len("REPORT "):]))
    cold, warm = outs
    # first process compiled every warmup shape ...
    assert all(v == "compiled" for v in cold["warmup"]["buckets"].values())
    assert cold["warmup"]["decode"] == "compiled"
    # ... the second resolves every one of them (and every lazily-resolved
    # serving shape: larger prefill batches, write_slot, retire) from disk
    assert all(v == "disk" for v in warm["warmup"]["buckets"].values())
    assert warm["warmup"]["decode"] == "disk"
    lazy = [(k, tuple(s)) for k, s, src in warm["log"] if src == "compiled"]
    assert lazy == [], lazy
