"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret=True executes the kernel bodies in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:      # bare env: skip only the property sweeps
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, nh, nkv, hd, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 8, 8, 32, True, None, jnp.float32),
    (2, 128, 128, 4, 1, 64, False, None, jnp.float32),
    (1, 256, 256, 4, 2, 64, True, 96, jnp.float32),
    (1, 128, 128, 6, 2, 128, True, None, jnp.float32),
    (2, 128, 128, 4, 2, 64, True, None, jnp.bfloat16),
    (1, 384, 384, 2, 2, 64, True, 128, jnp.float32),
]


@pytest.mark.parametrize(
    "B,Sq,Sk,nh,nkv,hd,causal,window,dtype", FLASH_CASES)
def test_flash_attention_vs_ref(B, Sq, Sk, nh, nkv, hd, causal, window,
                                dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Sq, nh, hd), dtype)
    k = rand(ks[1], (B, Sk, nkv, hd), dtype)
    v = rand(ks[2], (B, Sk, nkv, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 128, 4, 32))
    k = rand(ks[1], (1, 128, 2, 32))
    v = rand(ks[2], (1, 128, 2, 32))

    def f_k(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v) ** 2)

    def f_r(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v) ** 2)

    g1 = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_attention_odd_shape_falls_back():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 100, 4, 64))          # 100 not a block multiple
    k = rand(ks[1], (1, 100, 2, 64))
    v = rand(ks[2], (1, 100, 2, 64))
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 4, 2, 64, 512, 100, jnp.float32),
    (1, 8, 1, 32, 256, 256, jnp.float32),
    (3, 6, 2, 64, 512, 1, jnp.float32),
    (2, 8, 4, 64, 512, 300, jnp.bfloat16),
    (1, 16, 2, 128, 1024, 777, jnp.float32),
]


@pytest.mark.parametrize("B,nh,nkv,hd,Smax,kvlen,dtype", DECODE_CASES)
def test_decode_attention_vs_ref(B, nh, nkv, hd, Smax, kvlen, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, nh, hd), dtype)
    k = rand(ks[1], (B, Smax, nkv, hd), dtype)
    v = rand(ks[2], (B, Smax, nkv, hd), dtype)
    # impl="interpret" pins the kernel path: the default dispatch resolves
    # to the reference on non-TPU backends, which would test ref vs ref
    got = ops.decode_attention(q, k, v, jnp.asarray(kvlen),
                               impl="interpret")
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(kvlen))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


def test_decode_attention_per_batch_lengths():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, Smax = 3, 256
    q = rand(ks[0], (B, 4, 64))
    k = rand(ks[1], (B, Smax, 2, 64))
    v = rand(ks[2], (B, Smax, 2, 64))
    lens = jnp.asarray([1, 100, 256], jnp.int32)
    got = ops.decode_attention(q, k, v, lens, impl="interpret")
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_attention_ragged_lengths_vs_ref():
    """The serving contract of the flash-decode kernel: mixed per-row
    lengths, lengths that end mid-block, and length-0 (dead-slot) rows."""
    from repro.kernels.decode_attention import decode_attention_fwd
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    B, nkv, group, hd, Smax, bk = 5, 2, 2, 32, 64, 16
    q = rand(ks[0], (B, nkv, group, hd))
    k = rand(ks[1], (B, nkv, Smax, hd))
    v = rand(ks[2], (B, nkv, Smax, hd))
    # 0: dead slot; 5/23: mid-block (not multiples of block_k=16);
    # 16: exactly one block; 64: full cache
    lens = jnp.asarray([0, 5, 16, 23, 64], jnp.int32)
    got = decode_attention_fwd(q, k, v, lens, block_k=bk, interpret=True)
    # oracle in model layout: [B, nh, hd] q / [B, S, nkv, hd] kv
    q_m = q.reshape(B, nkv * group, hd)
    want = ref.decode_attention_ref(q_m, jnp.swapaxes(k, 1, 2),
                                    jnp.swapaxes(v, 1, 2), lens)
    want = want.reshape(B, nkv, group, hd)
    # rows with a valid prefix match the oracle exactly
    np.testing.assert_allclose(np.asarray(got)[1:], np.asarray(want)[1:],
                               atol=2e-5, rtol=2e-5)
    # a length-0 row skips every KV block and returns exact zeros (the
    # oracle instead softmaxes a fully-masked row into a uniform average,
    # so it is NOT the ground truth there)
    np.testing.assert_array_equal(np.asarray(got)[0],
                                  np.zeros_like(np.asarray(got)[0]))


def test_decode_attention_dispatch_modes_agree():
    """ref / interpret dispatch modes produce the same numbers through the
    public entry point (pallas mode needs real TPU hardware)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    B, Smax = 2, 128
    q = rand(ks[0], (B, 4, 32))
    k = rand(ks[1], (B, Smax, 2, 32))
    v = rand(ks[2], (B, Smax, 2, 32))
    lens = jnp.asarray([7, 127], jnp.int32)
    a = ops.decode_attention(q, k, v, lens, impl="ref")
    b = ops.decode_attention(q, k, v, lens, impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, H, P, G, N, chunk, dtype
    (2, 128, 4, 16, 2, 32, 32, jnp.float32),
    (1, 64, 2, 64, 1, 128, 16, jnp.float32),
    (2, 100, 4, 16, 2, 32, 32, jnp.float32),   # pad path (100 % 32 != 0)
    (1, 128, 4, 64, 1, 64, 64, jnp.bfloat16),
    (1, 256, 8, 32, 4, 32, 128, jnp.float32),
]


@pytest.mark.parametrize("B,S,H,P,G,N,chunk,dtype", SSD_CASES)
def test_ssd_scan_vs_ref(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(rand(ks[1], (B, S, H)))
    A = -jnp.exp(rand(ks[2], (H,), scale=0.5))
    Bm = rand(ks[3], (B, S, G, N), dtype, scale=0.3)
    Cm = rand(ks[4], (B, S, G, N), dtype, scale=0.3)
    D = jnp.ones((H,))
    y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    y2, s2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=max(tol(dtype), 1e-3), rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-2)


def test_ssd_scan_init_state_chaining():
    """Processing [x1; x2] at once == processing x1 then x2 with the carried
    state (the chunked-prefill invariant)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 32
    x = rand(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(rand(ks[1], (B, S, H)))
    A = -jnp.exp(rand(ks[2], (H,), scale=0.5))
    Bm = rand(ks[3], (B, S, G, N), scale=0.3)
    Cm = rand(ks[4], (B, S, G, N), scale=0.3)
    D = jnp.zeros((H,))
    y_all, s_all = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=16)
    half = S // 2
    y1, s1 = ops.ssd_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                          Cm[:, :half], D, chunk=16)
    y2, s2 = ops.ssd_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                          Cm[:, half:], D, chunk=16, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               atol=1e-4, rtol=1e-3)


def test_ssd_scan_grads_vs_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 32
    x = rand(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(rand(ks[1], (B, S, H)))
    A = -jnp.exp(rand(ks[2], (H,), scale=0.5))
    Bm = rand(ks[3], (B, S, G, N), scale=0.3)
    Cm = rand(ks[4], (B, S, G, N), scale=0.3)
    D = jnp.ones((H,))

    def f_k(*a):
        return jnp.sum(ops.ssd_scan(*a, chunk=16)[0] ** 2)

    def f_r(*a):
        return jnp.sum(ref.ssd_scan_ref(*a)[0] ** 2)

    g1 = jax.grad(f_k, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm, D)
    g2 = jax.grad(f_r, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm, D)
    for a, b in zip(g1, g2):
        scale = max(1.0, float(jnp.max(jnp.abs(b))))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3 * scale)


# ---------------------------------------------------------------------------
# hypothesis sweeps (random small shapes; collected only when hypothesis
# is installed — see requirements-dev.txt)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(b=st.integers(1, 2), sq=st.sampled_from([128, 256]),
           nkv=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 3]),
           hd=st.sampled_from([16, 32, 64]), causal=st.booleans())
    @settings(max_examples=12, deadline=None)
    def test_flash_attention_property(b, sq, nkv, g, hd, causal):
        nh = nkv * g
        ks = jax.random.split(jax.random.PRNGKey(hash((b, sq, nh)) % 2**31),
                              3)
        q = rand(ks[0], (b, sq, nh, hd))
        k = rand(ks[1], (b, sq, nkv, hd))
        v = rand(ks[2], (b, sq, nkv, hd))
        got = ops.flash_attention(q, k, v, causal=causal)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5)

    @given(s=st.sampled_from([32, 64, 96]), h=st.sampled_from([1, 2, 4]),
           p=st.sampled_from([8, 16]), n=st.sampled_from([16, 32]),
           chunk=st.sampled_from([16, 32]))
    @settings(max_examples=10, deadline=None)
    def test_ssd_scan_property(s, h, p, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(hash((s, h, p, n)) % 2**31),
                              5)
        x = rand(ks[0], (1, s, h, p))
        dt = jax.nn.softplus(rand(ks[1], (1, s, h)))
        A = -jnp.exp(rand(ks[2], (h,), scale=0.5))
        Bm = rand(ks[3], (1, s, 1, n), scale=0.3)
        Cm = rand(ks[4], (1, s, 1, n), scale=0.3)
        D = jnp.ones((h,))
        y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
        y2, s2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm, D)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-3, rtol=1e-2)
