"""Data pipeline: determinism, host sharding, memmap source, TAPA producer."""

import numpy as np
import pytest

import repro
from repro.data import DataConfig, TokenPipeline, make_pipeline
from repro.data.pipeline import write_token_file


def test_deterministic_restart():
    a = make_pipeline(vocab=1000, seq_len=32, global_batch=8, seed=5)
    batches = [a.next_batch() for _ in range(5)]
    st = a.state_dict()
    nxt = a.next_batch()

    b = make_pipeline(vocab=1000, seq_len=32, global_batch=8, seed=5)
    b.load_state_dict(st)
    np.testing.assert_array_equal(b.next_batch()["tokens"], nxt["tokens"])


def test_labels_are_shifted_tokens():
    p = make_pipeline(vocab=100, seq_len=16, global_batch=2)
    # labels[t] continues tokens[t] (same underlying stream, shifted by 1)
    b = p.next_batch()
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert b["tokens"].max() < 100 and b["tokens"].min() >= 0


def test_host_sharding_disjoint_and_deterministic():
    hosts = [make_pipeline(vocab=100, seq_len=8, global_batch=8,
                           n_hosts=4, host_id=h, seed=9) for h in range(4)]
    batches = [h.next_batch()["tokens"] for h in hosts]
    assert all(b.shape == (2, 8) for b in batches)
    # different hosts draw different data
    assert not np.array_equal(batches[0], batches[1])
    # re-running host 0 gives identical data
    again = make_pipeline(vocab=100, seq_len=8, global_batch=8,
                          n_hosts=4, host_id=0, seed=9).next_batch()
    np.testing.assert_array_equal(batches[0], again["tokens"])


def test_memmap_source(tmp_path):
    toks = np.arange(10_000) % 50_000
    f = tmp_path / "corpus.bin"
    write_token_file(f, toks, vocab=50_000)
    p = TokenPipeline(DataConfig(vocab=50_000, seq_len=64, global_batch=4,
                                 source="memmap", path=str(f)))
    b = p.next_batch()
    assert b["tokens"].shape == (4, 64)
    # windows are contiguous slices of the corpus: labels = tokens shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_as_task_prefetch_queue():
    p = make_pipeline(vocab=100, seq_len=8, global_batch=2)
    producer = p.as_task(n_batches=5)
    got = []

    def Consumer(i, sink):
        for b in i:
            sink.append(b["tokens"].shape)

    def Top(sink):
        ch = repro.channel(capacity=2)    # bounded prefetch queue
        repro.task().invoke(producer, ch).invoke(Consumer, ch, sink)

    rep = repro.run(Top, got, engine="coroutine")
    assert rep.ok and got == [(2, 8)] * 5


def test_bad_configs_rejected():
    with pytest.raises(ValueError):
        TokenPipeline(DataConfig(vocab=10, seq_len=4, global_batch=3,
                                 n_hosts=2))
    with pytest.raises(ValueError):
        TokenPipeline(DataConfig(vocab=10, seq_len=4, global_batch=2,
                                 source="memmap"))
