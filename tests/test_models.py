"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs; plus
prefill/decode consistency (the serving path must agree with the training
forward token-by-token)."""

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run


def _inputs(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    extra = {}
    if cfg.vlm is not None:
        extra["patches"] = jnp.zeros(
            (B, cfg.vlm.n_patches, cfg.vlm.d_patch), jnp.bfloat16)
    if cfg.encdec is not None:
        extra["frames"] = jnp.zeros(
            (B, cfg.encdec.n_audio_ctx, cfg.d_model), jnp.bfloat16)
    return toks, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_config(arch).with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    toks, extra = _inputs(cfg)
    logits, aux = lm.forward(params, cfg, toks, extra=extra)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_decreases_nothing_nan(arch):
    cfg = get_config(arch).with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = adamw_init(params, opt)
    toks, extra = _inputs(cfg)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1), **extra}

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(lm.loss_fn)(p, cfg, b)
        p2, s2, m = adamw_update(g, s, p, opt)
        return p2, s2, loss

    p, s, loss0 = step(params, state, batch)
    for _ in range(3):
        p, s, loss = step(p, s, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss))
    assert float(loss) < float(loss0)        # same-batch overfit must drop


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_1b_a400m",
                                  "mamba2_130m", "zamba2_1_2b",
                                  "whisper_small", "phi_3_vision_4_2b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy continuation computed by (prefill + decode_step) must match
    the full-sequence forward pass logits at every position."""
    cfg = get_config(arch).with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    B, S, S_max = 2, 16, 32
    toks, extra = _inputs(cfg, B, S)

    last_logits, cache = lm.prefill(params, cfg, toks, extra=extra,
                                    max_seq=S_max)
    # decode 4 tokens greedily
    decoded = [jnp.argmax(last_logits, -1).astype(jnp.int32)]
    for _ in range(3):
        lg, cache = lm.decode_step(params, cfg, decoded[-1], cache)
        decoded.append(jnp.argmax(lg, -1).astype(jnp.int32))

    # reference: run forward on the growing sequence each time
    seq = toks
    for t in range(4):
        logits, _ = lm.forward(params, cfg, seq, extra=extra)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt),
                                      np.asarray(decoded[t]),
                                      err_msg=f"token {t}")
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_hybrid_shared_attention_weights_are_shared():
    """zamba2's shared block: ONE parameter set, many applications — the
    paper's one-definition/many-instances pattern with shared weights."""
    cfg = get_config("zamba2_1_2b").with_reduced(n_layers=4)
    params = lm.init_params(cfg, jax.random.key(0))
    flat = jax.tree_util.tree_leaves_with_path(params)
    shared = [p for p, _ in flat if "shared_attn" in str(p)]
    assert shared, "hybrid model must carry a shared attention block"
    # exactly one copy (no leading layer axis on shared leaves)
    for path, leaf in flat:
        if "shared_attn" in str(path) and "wq" in str(path):
            assert leaf.ndim == 2


def test_param_count_analytic_matches_actual():
    for arch in ("qwen3_0_6b", "yi_6b", "mamba2_130m",
                 "granite_moe_1b_a400m"):
        cfg = get_config(arch).with_reduced()
        params = lm.init_params(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == pytest.approx(cfg.param_count(), rel=0.05), arch


def test_moe_aux_loss_and_capacity():
    cfg = get_config("granite_moe_1b_a400m").with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    toks, _ = _inputs(cfg)
    _, aux = lm.forward(params, cfg, toks)
    assert float(aux) > 0.0                  # load-balance loss active


def test_use_kernel_matches_xla_path():
    """use_kernel=True (Pallas flash attention + SSD) must agree with the
    pure-XLA path."""
    for arch in ("qwen3_0_6b", "mamba2_130m"):
        cfg = get_config(arch).with_reduced(
            n_layers=2, max_seq_len=512)
        params = lm.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (1, 128), 0, cfg.vocab)
        l1, _ = lm.forward(params, cfg, toks, use_kernel=False)
        l2, _ = lm.forward(params, cfg, toks, use_kernel=True)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   atol=5e-2, rtol=5e-2)
