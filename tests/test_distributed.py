"""Distributed-layer tests that need >1 device run in subprocesses with
--xla_force_host_platform_device_count (the main process must keep seeing
one device; see conftest).  Single-device-safe pieces run inline."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, n_devices: int = 4) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROCESS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROCESS_OK" in r.stdout
    return r.stdout


# ---------------------------------------------------------------------------
# pipeline parallelism
# ---------------------------------------------------------------------------

def test_pipeline_schedule_sim_and_bubble():
    from repro.distributed.pipeline import (PipelineConfig,
                                            schedule_task_graph)
    pcfg = PipelineConfig(n_stages=4, n_microbatches=8, channel_capacity=2)
    rep = schedule_task_graph(pcfg)
    assert rep.ok and rep.result == list(range(8))
    # channel occupancy never exceeds the declared capacity
    assert all(occ <= 2 for (_, _, occ) in rep.channels)
    assert pcfg.bubble_fraction == pytest.approx(3 / 11)


def test_pipeline_deadlocks_without_capacity():
    """A stage that buffers two tokens before forwarding deadlocks when the
    channel capacity is 1 and the feeder blocks — the simulator catches the
    schedule bug before any hardware run (the paper's C2 applied to PP)."""
    import repro

    def Feeder(o):
        for i in range(2):
            o.write(i)
        o.close()

    def Greedy(i, o):
        a = i.read()
        b = i.read()                    # 2 tokens flow one-by-one: fine
        i.open()
        o.write(a + b)
        o.close()

    def Top(sink):
        c1 = repro.channel(capacity=1)
        c2 = repro.channel(capacity=1)
        repro.task().invoke(Greedy, c1, c2).invoke(Feeder, c1) \
            .invoke(lambda i, s: s.extend(v for v in i), c2, sink)

    sink = []
    rep = repro.run(Top, sink, engine="coroutine")
    assert rep.ok and sink == [1]        # capacity 1 works for this shape
    # now a schedule that NEEDS capacity 2: the stage writes its second
    # output before reading again while the feeder still must push —
    # with capacity 1 the simulator must report deadlock, not hang
    def Hostage(i, o):
        o.write(99)                      # fills c2 (capacity 1)
        o.write(100)                     # blocks; never reads c1
        o.close()

    def Top2():
        c1 = repro.channel(capacity=1)
        c2 = repro.channel(capacity=1)
        repro.task().invoke(Hostage, c1, c2).invoke(Feeder, c1)

    rep2 = repro.run(Top2, engine="coroutine")
    assert not rep2.ok and "deadlock" in rep2.error.lower()


def test_pipeline_spmd_equivalence():
    run_sub("""
        from repro.distributed.pipeline import (pipeline_apply,
                                                pipeline_loss_fn,
                                                stack_stage_params)
        mesh = jax.make_mesh((4,), ("stage",))
        S, M, mb, d = 4, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), S)
        per_stage = [{"w": jax.random.normal(k, (d, d)) * 0.3} for k in ks]
        stacked = stack_stage_params(per_stage)

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"][0])

        xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        out = pipeline_apply(mesh, stage_fn, stacked, xs)
        ref = xs
        for p in per_stage:
            ref = jnp.tanh(ref @ p["w"])
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5

        labels = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))
        lf = pipeline_loss_fn(mesh, stage_fn,
                              lambda o, y: jnp.mean((o - y) ** 2))
        def ref_loss(st, xs, ys):
            h = xs
            for i in range(S):
                h = jnp.tanh(h @ st["w"][i])
            return jnp.mean((h - ys) ** 2)
        g1 = jax.grad(lf)(stacked, xs, labels)
        g2 = jax.grad(ref_loss)(stacked, xs, labels)
        assert float(jnp.max(jnp.abs(g1["w"] - g2["w"]))) < 1e-5
    """)


def test_sharded_train_step_matches_single_device():
    """dp=2 x tp=2 sharded train step == single-device train step."""
    run_sub("""
        from functools import partial
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.steps import make_train_step
        from repro.models import lm
        from repro.optim import AdamWConfig, adamw_init, opt_state_specs

        cfg = get_config("qwen3-0.6b").with_reduced()
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        params = lm.init_params(cfg, jax.random.key(0))
        state = adamw_init(params, opt)
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        step = make_train_step(cfg, opt)

        # single-device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        pol = shd.for_mesh(mesh)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              shd.param_specs(cfg, mesh, pol))
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              opt_state_specs(cfg, mesh, pol))
        bshard = {k: NamedSharding(mesh, v)
                  for k, v in shd.batch_spec(cfg, mesh, 4, pol).items()}
        pd = jax.device_put(params, pshard)
        sd = jax.device_put(state, oshard)
        bd = {k: jax.device_put(v, bshard[k]) for k, v in batch.items()}
        with mesh:
            p2, s2, m2 = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                                 out_shardings=(pshard, oshard, None))(
                                     pd, sd, bd)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \
            (float(m1["loss"]), float(m2["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-2, worst
    """)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_error_bounded():
    from repro.distributed import compress as C
    g = jnp.asarray(np.random.randn(64, 64).astype(np.float32))
    assert C.compression_error(g) < 0.01


def test_error_feedback_reduces_bias():
    """With EF, the *accumulated* quantization error stays bounded instead
    of growing with steps (EF-SGD property)."""
    from repro.distributed import compress as C
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                       {"g": g_true})
    total_sent = jnp.zeros_like(g_true)
    for step in range(20):
        qs, err = C.compress_grads({"g": g_true}, err)
        q, s = qs["g"]
        total_sent = total_sent + C.dequantize_int8(q, s)
    # mean of sent gradients converges to the true gradient
    rel = float(jnp.linalg.norm(total_sent / 20 - g_true) /
                jnp.linalg.norm(g_true))
    assert rel < 1e-3


def test_compressed_psum_shard_map():
    run_sub("""
        from repro.distributed import compress as C
        mesh = jax.make_mesh((4,), ("data",))
        gs = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8))

        def body(g):
            out, new_err = C.ef_compressed_mean(
                {"g": g[0]}, {"g": jnp.zeros_like(g[0])}, "data")
            return out["g"][None]

        got = jax.shard_map(body, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check_vma=False)(gs)
        want = jnp.mean(gs, axis=0)
        rel = float(jnp.linalg.norm(got[0] - want) /
                    jnp.linalg.norm(want))
        assert rel < 0.02, rel
    """)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_restart_exact_resume(tmp_path):
    """Train 6 steps straight == train 3, 'crash', restore, train 3."""
    from functools import partial
    from repro.ckpt import CheckpointManager
    from repro.configs import get_config
    from repro.data import make_pipeline
    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.optim import AdamWConfig, adamw_init

    cfg = get_config("qwen3-0.6b").with_reduced()
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    step = jax.jit(make_train_step(cfg, opt))

    def fresh():
        p = lm.init_params(cfg, jax.random.key(0))
        return p, adamw_init(p, opt)

    def batch_at(data):
        b = data.next_batch()
        return {k: jnp.asarray(v) for k, v in b.items()}

    # straight run
    p, s = fresh()
    data = make_pipeline(cfg.vocab, 32, 4, seed=3)
    for _ in range(6):
        p, s, m = step(p, s, batch_at(data))
    loss_straight = float(m["loss"])

    # crash/restore run
    p, s = fresh()
    data = make_pipeline(cfg.vocab, 32, 4, seed=3)
    mgr = CheckpointManager(tmp_path, keep=2)
    for _ in range(3):
        p, s, m = step(p, s, batch_at(data))
    mgr.save(3, p, s, extra={"data": data.state_dict()})
    del p, s                                  # "crash"

    aparams = lm.abstract_params(cfg)
    aopt = jax.eval_shape(partial(adamw_init, c=opt), aparams)
    st = mgr.latest_step()
    p, s, extra = mgr.restore(st, aparams, aopt)
    data2 = make_pipeline(cfg.vocab, 32, 4, seed=3)
    data2.load_state_dict(extra["data"])
    for _ in range(3):
        p, s, m = step(p, s, batch_at(data2))
    assert float(m["loss"]) == pytest.approx(loss_straight, abs=1e-5)


def test_checkpoint_atomicity_partial_ignored(tmp_path):
    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    p = {"w": jnp.ones((4,))}
    mgr.save(1, p, p)
    # a torn checkpoint: directory exists but no DONE marker
    torn = tmp_path / "step_00000002"
    (torn / "params").mkdir(parents=True)
    assert mgr.latest_step() == 1


def test_elastic_remesh_shrinks_data_axis():
    from repro.ft import ElasticMesh
    assert ElasticMesh.shrink(512, 16) == (32, 16)
    assert ElasticMesh.shrink(448, 16) == (28, 16)   # lost 4 hosts
    with pytest.raises(ValueError):
        ElasticMesh.shrink(8, 16)


def test_preemption_guard_trigger():
    from repro.ft import PreemptionGuard
    g = PreemptionGuard(install=False)
    assert not g.requested
    g.trigger()
    assert g.requested


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serving_continuous_batching_toy():
    from repro.serve import Request, ServeConfig, ServingEngine, \
        serve_requests

    def prefill(toks):
        return np.eye(1, 16, k=int(toks[0, -1]) % 16), {"n": toks.shape[1]}

    def decode(tok, cache):
        return np.eye(1, 16, k=int(tok[0] + 1) % 16), \
            {"n": cache["n"] + 1}

    eng = ServingEngine(ServeConfig(batch_slots=2), prefill, decode)
    reqs = [Request(i, list(range(1, 2 + i)), max_new=3 + i % 2)
            for i in range(5)]
    res = serve_requests(eng, reqs)
    assert set(res) == set(range(5))
    for r in reqs:
        assert len(res[r.rid]) == r.max_new


def test_serving_real_model_greedy_matches_forward():
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Request, ServeConfig, ServingEngine, \
        serve_requests

    cfg = get_config("qwen3-0.6b").with_reduced()
    params = lm.init_params(cfg, jax.random.key(0))

    @jax.jit
    def prefill_fn(tokens):
        return lm.prefill(params, cfg, tokens, max_seq=64)

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    eng = ServingEngine(ServeConfig(batch_slots=2, max_seq=64),
                        prefill_fn, decode_fn)
    prompts = [[1, 2, 3, 4], [7, 8, 9]]
    res = serve_requests(eng, [Request(0, prompts[0], 3),
                               Request(1, prompts[1], 3)])
    # greedy reference via full forward
    for rid, prompt in enumerate(prompts):
        seq = jnp.asarray([prompt], jnp.int32)
        want = []
        for _ in range(3):
            logits, _ = lm.forward(params, cfg, seq)
            nxt = int(jnp.argmax(logits[0, -1]))
            want.append(nxt)
            seq = jnp.concatenate(
                [seq, jnp.asarray([[nxt]], jnp.int32)], axis=1)
        assert res[rid] == want, rid
