"""Mesh floorplanner (repro.core.floorplan) + partitioned lowering.

Fast tests (tier-1) drive the optimizer with synthetic cost models so
its choices are assertable without touching XLA, and cover the refusal
diagnostics and the content-addressing of placement artifacts.  Bit-
parity against the single-device program and the zero-recompile reuse
contract compile real programs and are marked slow — they run in the CI
partition-parity job under a forced 8-device host platform.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import repro
from repro import StepTask, SynthesisError, channel, mmap

jnp = pytest.importorskip("jax.numpy")
import jax  # noqa: E402

from repro.core.compile_cache import CompileCache  # noqa: E402
from repro.core.cost import phase_key  # noqa: E402
from repro.core.floorplan import (Placement, channel_endpoints,  # noqa: E402
                                  channel_traffic, placement_key,
                                  plan_placement)
from repro.core.synth import elaborate_step_graph  # noqa: E402

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def relay_pipeline(n_tokens=32, stages=2, burst=8, capacity=16, bias=0):
    """Step-form Source -> stages x Relay -> Sink; ``bias`` edits the
    relay body (cost-cell dirtying tests)."""
    fires = n_tokens // burst

    def source_step(k, out):
        out.write_burst(k * burst + jnp.arange(burst, dtype=jnp.int32))
        return k + 1

    def relay_step(state, inp, out):
        out.write_burst(inp.read_burst(burst) + bias)
        return state

    def sink_step(k, inp, res):
        res.write_burst(k * burst, inp.read_burst(burst))
        return k + 1

    Source = StepTask(source_step, steps=fires, init=jnp.int32(0),
                      name="Source")
    Relay = StepTask(relay_step, steps=fires, name="Relay")
    Sink = StepTask(sink_step, steps=fires, init=jnp.int32(0), name="Sink")

    buf = np.zeros(n_tokens, np.int32)
    res = mmap(buf, "res")

    def Top(res):
        chans = [channel(capacity, f"c{i}", dtype=np.int32, shape=())
                 for i in range(stages + 1)]
        t = repro.task().invoke(Source, chans[0], name="Source")
        for s in range(stages):
            t = t.invoke(Relay, chans[s], chans[s + 1], name=f"Relay{s}")
        t.invoke(Sink, chans[stages], res, name="Sink")

    return Top, (res,), buf


def _plan(stages=2, **kw):
    top, args, _ = relay_pipeline(stages=stages, **kw)
    plan, graph, _ = elaborate_step_graph(top, *args)
    return plan, graph


def _flat_cost(plan, tp):
    return 1.0


# ---------------------------------------------------------------------------
# the optimizer (synthetic costs: no XLA)
# ---------------------------------------------------------------------------

def test_placement_is_deterministic():
    plan, graph = _plan(stages=4)
    a = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost)
    b = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost)
    assert a.owners == b.owners
    assert a.objective == b.objective
    assert a.source == "partitioned"


def test_placement_balances_flat_costs():
    """Six unit-cost tasks on two devices: the greedy + refine passes
    must land a 3/3 split (max load == half the total)."""
    plan, graph = _plan(stages=4)
    pl = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost)
    loads = pl.objective["loads_s"]
    assert sorted(loads) == [3.0, 3.0]
    assert pl.objective["max_load_s"] == 3.0


def test_heavy_task_isolated():
    """One task worth more than everything else combined gets a device
    to itself."""
    plan, graph = _plan(stages=3)

    def cost(plan, tp):
        return 100.0 if tp.inst.name == "Relay1" else 1.0

    pl = plan_placement(plan, graph, 2, cache=False, cost_fn=cost)
    heavy = dict(zip(pl.task_names, pl.owners))["Relay1"]
    others = [d for n, d in zip(pl.task_names, pl.owners) if n != "Relay1"]
    assert all(d != heavy for d in others)


def test_single_device_placement_has_no_cuts():
    plan, graph = _plan(stages=2)
    pl = plan_placement(plan, graph, 1, cache=False, cost_fn=_flat_cost)
    assert set(pl.owners) == {0}
    assert pl.objective["cut_bytes"] == 0
    assert pl.objective["cut_channels"] == []


def test_overrides_pin_tasks():
    plan, graph = _plan(stages=2)
    pl = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost,
                        overrides={"Source": 1, "Sink": 1})
    byname = dict(zip(pl.task_names, pl.owners))
    assert byname["Source"] == 1 and byname["Sink"] == 1


def test_override_unknown_task_refuses_with_names():
    plan, graph = _plan(stages=1)
    with pytest.raises(SynthesisError, match="Relayz.*known instances"):
        plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost,
                       overrides={"Relayz": 0})


def test_override_device_out_of_range_refuses():
    plan, graph = _plan(stages=1)
    with pytest.raises(SynthesisError, match="'Source' to device 5"):
        plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost,
                       overrides={"Source": 5})


def test_channel_traffic_counts_full_run_bytes():
    plan, _ = _plan(stages=1, n_tokens=32, burst=8)
    traffic = channel_traffic(plan)
    ep = channel_endpoints(plan)
    # every pipeline channel moves all 32 int32 tokens over the run
    assert all(t == 32 * 4 for t in traffic)
    assert all(p >= 0 and c >= 0 for p, c in ep)


# ---------------------------------------------------------------------------
# content addressing + memoization
# ---------------------------------------------------------------------------

def test_placement_key_sensitivity():
    plan, graph = _plan(stages=2)
    h = graph.structural_hash()
    base = placement_key(h, 2)
    assert base == placement_key(h, 2)
    assert base != placement_key(h, 4)
    assert base != placement_key(h, 2, {"Source": 1})
    assert placement_key(h, 2, {"Source": 1}) \
        != placement_key(h, 2, {"Source": 0})
    assert base != placement_key(h + "x", 2)
    assert base.startswith("place_")


def test_placement_memo_round_trip(tmp_path):
    plan, graph = _plan(stages=3)
    cc = CompileCache(root=tmp_path)
    a = plan_placement(plan, graph, 2, cache=cc, cost_fn=_flat_cost)
    assert a.source == "partitioned"
    b = plan_placement(plan, graph, 2, cache=cc, cost_fn=_flat_cost)
    assert b.source == "memo"
    assert b.owners == a.owners
    assert b.objective == a.objective


def test_cost_cell_key_dirties_only_edited_task():
    """Editing one task's body changes that task's cost cell address and
    nobody else's — the incremental-pricing contract."""
    plan_a, _ = _plan(stages=2, bias=0)
    plan_b, _ = _plan(stages=2, bias=1)
    keys_a = {tp.inst.name: phase_key(plan_a, tp, tp.phases[0])
              for tp in plan_a.tasks}
    keys_b = {tp.inst.name: phase_key(plan_b, tp, tp.phases[0])
              for tp in plan_b.tasks}
    assert keys_a["Source"] == keys_b["Source"]
    assert keys_a["Sink"] == keys_b["Sink"]
    assert keys_a["Relay0"] != keys_b["Relay0"]
    assert keys_a["Relay1"] != keys_b["Relay1"]


def test_to_dot_colors_devices_and_cuts():
    plan, graph = _plan(stages=2)
    pl = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost)
    dot = graph.to_dot(placement=pl)
    assert "fillcolor" in dot and "dev0" in dot and "dev1" in dot
    assert ("color=red" in dot) == (len(pl.objective["cut_channels"]) > 0)
    assert "fillcolor" not in graph.to_dot()


# ---------------------------------------------------------------------------
# refusal diagnostics (never reach XLA)
# ---------------------------------------------------------------------------

def test_partitioned_ports_refuse_naming_port_and_task():
    """async_mmap latency queues have no cut protocol yet; the refusal
    must name the port AND the tasks bound to it."""
    from repro.core import async_mmap

    data = np.arange(8, dtype=np.int32)
    port = async_mmap(data.copy(), latency=2, depth=2, name="mem")
    buf = np.zeros(8, np.int32)
    res = mmap(buf, "res")

    def warm(k, port, res):
        port.read_addr.write(k)
        return k + 1

    def step(k, port, res):
        res.write_burst(k - 2, port.read_data.read()[None])
        port.read_addr.write(k)
        return k + 1

    def flush(k, port, res):
        res.write_burst(k - 2, port.read_data.read()[None])
        return k + 1

    Fetch = StepTask(step, steps=6, init=jnp.int32(0), warmup=warm,
                     n_warmup=2, flush=flush, n_flush=2, name="Fetch")

    def Top(port, res):
        repro.task().invoke(Fetch, port, res)

    with pytest.raises(SynthesisError, match="mem.*Fetch"):
        repro.ENGINES["compiled"](mesh=1, cache=False).run(Top, port, res)


def test_non_1d_mesh_refuses():
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("a", "b"))
    top, args, _ = relay_pipeline(stages=1)
    with pytest.raises(SynthesisError, match="1-D mesh"):
        repro.ENGINES["compiled"](mesh=mesh, cache=False).run(top, *args)


def test_mesh_wider_than_visible_devices_refuses():
    from repro.distributed.sharding import device_mesh
    n = jax.device_count()
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        device_mesh(n + 1)


def test_placement_reuse_mismatch_refuses():
    plan, graph = _plan(stages=2)
    pl = plan_placement(plan, graph, 2, cache=False, cost_fn=_flat_cost)
    wrong = Placement(n_devices=pl.n_devices + 1, owners=pl.owners,
                      task_names=pl.task_names, objective=pl.objective)
    top, args, _ = relay_pipeline(stages=2)
    with pytest.raises(SynthesisError, match="placement reuse mismatch"):
        repro.ENGINES["compiled"](mesh=1, cache=False,
                                  placement=wrong).run(top, *args)


# ---------------------------------------------------------------------------
# bit-parity with the single-device program (slow; multi-device CI job)
# ---------------------------------------------------------------------------

def _gemm_bytes(engine_kwargs):
    from repro.apps import gemm
    top, args, check = gemm.build_step(P=2, n=4, K=2)
    eng = repro.ENGINES["compiled"](**engine_kwargs)
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    assert check()[0]
    got = np.concatenate([np.asarray(m.data) for m in args[2]])
    return got.tobytes(), eng


def _page_rank_bytes(engine_kwargs):
    from repro.apps import page_rank
    top, args, check = page_rank.build_step(n_vertices=16, n_edges=48,
                                            n_pe=2, n_iters=4)
    eng = repro.ENGINES["compiled"](**engine_kwargs)
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    assert check()[0]
    return np.asarray(args[1].data).tobytes(), eng


@pytest.mark.slow
@pytest.mark.parametrize("n_dev", [2, 4])
def test_gemm_partitioned_bit_identical(n_dev):
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    golden, _ = _gemm_bytes({})
    got, eng = _gemm_bytes({"mesh": n_dev})
    assert got == golden
    assert eng.placement_used.n_devices == n_dev
    assert len(set(eng.placement_used.owners)) > 1


@pytest.mark.slow
@pytest.mark.parametrize("n_dev", [2, 4])
def test_page_rank_partitioned_bit_identical(n_dev):
    """The feedback-loop graph (cyclic dataflow) survives partitioning:
    cut channels inside the cycle still deliver bit-identical ranks."""
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} devices "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    golden, _ = _page_rank_bytes({})
    got, eng = _page_rank_bytes({"mesh": n_dev})
    assert got == golden
    assert eng.partition_source in ("partitioned", "memo")


@pytest.mark.slow
def test_manual_placement_bit_identical_and_keyed_apart():
    """A manual override produces the same answer over a different cut,
    and its compiled program caches under a different key."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices")
    golden, _ = _gemm_bytes({})
    auto, eng_a = _gemm_bytes({"mesh": 2})
    manual, eng_m = _gemm_bytes(
        {"mesh": 2, "placement": {"PE0_0": 0, "PE1_1": 1}})
    assert auto == golden and manual == golden
    byname = dict(zip(eng_m.placement_used.task_names,
                      eng_m.placement_used.owners))
    assert byname["PE0_0"] == 0 and byname["PE1_1"] == 1
    if eng_a.placement_used.owners != eng_m.placement_used.owners:
        assert eng_a.compile_key != eng_m.compile_key


# ---------------------------------------------------------------------------
# cross-process reuse: zero re-partition, zero XLA compiles (slow)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import json, sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import repro
    from repro.core.compile_cache import CompileCache
    from repro.core.floorplan import placement_key
    from repro.core.synth import elaborate_step_graph
    from repro.apps import gemm

    cc = CompileCache(root={root!r})
    top, args, check = gemm.build_step(P=2, n=4, K=2)
    eng = repro.ENGINES["compiled"](mesh=2, cache=cc)
    rep = eng.run(top, *args)
    assert rep.ok and check()[0]
    top, args, _ = gemm.build_step(P=2, n=4, K=2)
    plan, graph, _ = elaborate_step_graph(top, *args)
    key = placement_key(graph.structural_hash(), 2)
    art = json.dumps(cc.memo_get(key), sort_keys=True)
    print("PSOURCE", eng.partition_source)
    print("CSOURCE", eng.compile_source)
    print("CKEY", eng.compile_key)
    print("ART", art)
""")


@pytest.mark.slow
def test_second_process_zero_repartition_zero_compiles(tmp_path):
    """Process 1 floorplans + compiles; process 2 must read both back
    from the content-addressed store (placement source == memo, compile
    source == disk) and see a byte-identical placement artifact."""
    import os
    prog = _CHILD.format(src=SRC, root=str(tmp_path))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", prog],
                           capture_output=True, text=True, timeout=600,
                           env=env)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append({ln.split(" ", 1)[0]: ln.split(" ", 1)[1]
                     for ln in r.stdout.strip().splitlines()
                     if " " in ln})
    assert outs[0]["PSOURCE"] == "partitioned"
    assert outs[0]["CSOURCE"] == "compiled"
    assert outs[1]["PSOURCE"] == "memo"          # zero re-partitioning
    assert outs[1]["CSOURCE"] == "disk"          # zero XLA compiles
    assert outs[0]["CKEY"] == outs[1]["CKEY"]
    assert outs[0]["ART"] == outs[1]["ART"]      # byte-identical artifact
    assert json.loads(outs[0]["ART"])["n_devices"] == 2
