"""Compile cache: structural hashing (fast) + store behaviour (slow).

Hash-only tests run in tier-1; anything that triggers an XLA compile or
spawns a subprocess is marked slow.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core.compile_cache import (CompileCache, instance_key,
                                      structural_digest)

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# structural hash (no JAX compiles — tier-1)
# ---------------------------------------------------------------------------

def _make_stage(coef, shift):
    def stage(x):
        return x * coef + shift
    return stage


def test_recreated_closures_hash_equal():
    """The failure mode of id(fn): re-created identical closures must
    dedup to one definition."""
    assert structural_digest(_make_stage(2.0, 1)) == \
        structural_digest(_make_stage(2.0, 1))


def test_edited_constant_dirties_hash():
    base = structural_digest(_make_stage(2.0, 1))
    assert structural_digest(_make_stage(2.5, 1)) != base
    assert structural_digest(_make_stage(2.0, 2)) != base


def test_closure_array_content_hashed():
    """Closure-captured weights are part of the compiled program."""
    w1, w2 = np.ones(4), np.ones(4) * 2

    def make(w):
        def stage(x):
            return x + w
        return stage

    assert structural_digest(make(w1)) == structural_digest(make(w1.copy()))
    assert structural_digest(make(w1)) != structural_digest(make(w2))


def test_referenced_global_data_hashed():
    import types
    ns1 = {"K": np.eye(2), "np": np}
    ns2 = {"K": np.eye(2) * 3, "np": np}
    src = "def f(x):\n    return np.dot(K, x)\n"
    f1, f2, f3 = [], [], []
    exec(src, ns1); f1 = ns1["f"]           # noqa: E702
    exec(src, ns2); f2 = ns2["f"]           # noqa: E702
    ns3 = {"K": np.eye(2), "np": np}
    exec(src, ns3); f3 = ns3["f"]           # noqa: E702
    assert structural_digest(f1) == structural_digest(f3)
    assert structural_digest(f1) != structural_digest(f2)


def test_instance_key_includes_aval_signature():
    f = _make_stage(2.0, 1)
    a = np.zeros((4, 4), np.float32)
    b = np.zeros((8, 8), np.float32)
    c = np.zeros((4, 4), np.float64)
    assert instance_key(f, (a,)) == instance_key(f, (a.copy(),))
    assert instance_key(f, (a,)) != instance_key(f, (b,))
    assert instance_key(f, (a,)) != instance_key(f, (c,))
    assert instance_key(f, (a,)) != instance_key(f, (a,), extra="x")


def test_jit_wrapped_closures_unwrap_to_content():
    """jax.jit wrappers have no __code__; the digest must reach through
    __wrapped__ or different-weight models would share cache keys."""
    import jax

    def make(w):
        def f(x):
            return x * w
        return f

    assert structural_digest(jax.jit(make(2.0))) == \
        structural_digest(jax.jit(make(2.0)))
    assert structural_digest(jax.jit(make(2.0))) != \
        structural_digest(jax.jit(make(99.0)))


def test_bound_method_receiver_state_hashed():
    class Stepper:
        def __init__(self, w):
            self.w = w

        def step(self, x):
            return x * self.w

    assert structural_digest(Stepper(1.0).step) == \
        structural_digest(Stepper(1.0).step)
    assert structural_digest(Stepper(1.0).step) != \
        structural_digest(Stepper(2.0).step)


def test_global_read_from_nested_lambda_hashed():
    src = "def f(x):\n    g = lambda y: y * W\n    return g(x)\n"
    ns1, ns2, ns3 = {"W": 2.0}, {"W": 99.0}, {"W": 2.0}
    for ns in (ns1, ns2, ns3):
        exec(src, ns)
    assert structural_digest(ns1["f"]) == structural_digest(ns3["f"])
    assert structural_digest(ns1["f"]) != structural_digest(ns2["f"])


def test_inplace_mutation_of_captured_array_dirties_digest():
    """The QoR loop edits weights in place on a live function object; the
    digest must not be memoized past the edit."""
    w = np.ones(4)

    def f(x):
        return x * w

    before = structural_digest(f)
    w[:] = 5.0
    assert structural_digest(f) != before


def test_callable_object_instance_state_hashed():
    """A callable object's behaviour lives in its attributes; Scale(2.0)
    and Scale(3.0) captured in closures must not share a digest."""
    class Scale:
        def __init__(self, c):
            self.c = c

        def __call__(self, x):
            return x * self.c

    def make(op):
        def stage(x):
            return op(x)
        return stage

    assert structural_digest(make(Scale(2.0))) == \
        structural_digest(make(Scale(2.0)))
    assert structural_digest(make(Scale(2.0))) != \
        structural_digest(make(Scale(3.0)))
    # and as the top-level callable itself
    assert structural_digest(Scale(2.0)) != structural_digest(Scale(3.0))


def test_opaque_callables_never_share_keys():
    """C-implemented callables can't be content-hashed; they must get
    unique keys (recompile) rather than colliding (wrong executable)."""
    assert structural_digest(np.add) != structural_digest(np.multiply)


def test_module_and_nonjittable_values_hash_safely():
    """Channels/engines/modules in closures must never crash the hasher
    (graph dedup hashes simulation task bodies too)."""
    import repro.core as core

    def make(obj):
        def stage():
            return obj
        return stage

    for obj in (core, object(), {"nested": [core, (1, {2})]},
                lambda x: x + 1):
        assert isinstance(structural_digest(make(obj)), str)


def test_legacy_key_warns():
    from repro.core.hier_compile import StageInstance
    inst = StageInstance(fn=_make_stage(1.0, 0), args=())
    with pytest.warns(DeprecationWarning):
        inst.legacy_key


# ---------------------------------------------------------------------------
# memo store (file I/O only — tier-1)
# ---------------------------------------------------------------------------

def test_memo_roundtrip_and_corrupt_recovery(tmp_path):
    cc = CompileCache(root=tmp_path)
    key = "ab" + "0" * 62
    assert cc.memo_get(key) is None
    cc.memo_put(key, {"flops": 1.5, "bytes": 2})
    assert cc.memo_get(key) == {"flops": 1.5, "bytes": 2}
    assert cc.stats.memo_hits == 1
    # corrupt the entry: recovery deletes it and reports a miss
    p = cc._path(key, "memo")
    p.write_text("{not json")
    assert cc.memo_get(key) is None
    assert cc.stats.corrupt == 1
    assert not p.exists()


def test_lru_eviction_bound(tmp_path):
    import os
    import time
    cc = CompileCache(root=tmp_path, max_bytes=1 << 20)
    keys = [f"{i:02x}" + "0" * 62 for i in range(4)]
    for i, k in enumerate(keys):
        cc.memo_put(k, {"pad": "x" * 100})
        # strictly order mtimes (coarse filesystem timestamps)
        os.utime(cc._path(k, "memo"), (time.time() + i, time.time() + i))
    cc.max_bytes = 256           # shrink the bound: next op must evict
    cc.evict_to_fit()
    assert cc.disk_bytes() <= 256
    assert cc.stats.evictions >= 1
    # the newest entry survives, the oldest went first
    assert cc._path(keys[-1], "memo").exists()
    assert not cc._path(keys[0], "memo").exists()


# ---------------------------------------------------------------------------
# executable store + incremental compile (XLA compiles — slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hit_miss_and_warm_restart(tmp_path):
    import jax.numpy as jnp

    def make(c):
        def f(x):
            return jnp.tanh(x) * c
        return f

    cc = CompileCache(root=tmp_path)
    x = jnp.ones((8, 8))
    exe, src = cc.compile_cached(make(1.5), (x,))
    assert src == "compiled" and cc.stats.misses == 1
    exe2, src2 = cc.compile_cached(make(1.5), (x,))
    assert src2 == "memory" and exe2 is exe
    cc.clear_memory()                       # simulate process restart
    exe3, src3 = cc.compile_cached(make(1.5), (x,))
    assert src3 == "disk"
    np.testing.assert_allclose(np.asarray(exe3(x)), np.asarray(exe(x)))


@pytest.mark.slow
def test_corrupt_executable_recovers(tmp_path):
    import jax.numpy as jnp

    def f(x):
        return x * 3.0

    cc = CompileCache(root=tmp_path)
    x = jnp.ones((4,))
    _, src = cc.compile_cached(f, (x,))
    assert src == "compiled"
    key = instance_key(f, (x,))
    cc._path(key).write_bytes(b"garbage not a pickle")
    cc.clear_memory()
    exe, src2 = cc.compile_cached(f, (x,))   # recovery: delete + recompile
    assert src2 == "compiled" and cc.stats.corrupt == 1
    np.testing.assert_allclose(np.asarray(exe(x)), 3.0)


@pytest.mark.slow
def test_incremental_recompile_one_dirty_definition(tmp_path):
    import jax.numpy as jnp

    from repro.core.hier_compile import (StageInstance, compile_stages,
                                         diff_definitions)

    def make(c):
        def f(x):
            return jnp.tanh(x @ x.T) * c
        return f

    x = jnp.ones((16, 16))

    def instances(coefs):
        return [StageInstance(fn=make(c), args=(x,), name=f"s{i}")
                for i, c in enumerate(coefs)]

    cc = CompileCache(root=tmp_path)
    prev = compile_stages(instances([1.0, 2.0, 3.0] * 4), cache=cc)
    assert prev.n_unique == 3 and prev.n_compiled == 3
    # edit one definition (2.0 -> 2.5): only it recompiles
    edited = instances([1.0, 2.5, 3.0] * 4)
    clean, dirty = diff_definitions(prev, edited)
    assert len(clean) == 2 and len(dirty) == 1
    rep = compile_stages(edited, cache=CompileCache(root=tmp_path / "i"),
                         prev=prev)
    assert rep.n_reused == 2 and rep.n_compiled == 1
    assert all(i.executable is not None for i in edited)


@pytest.mark.slow
def test_cross_process_reuse_and_gaussian_zero_compiles(tmp_path):
    """The acceptance bar: a second elaborate+compile_stages run of the
    gaussian app — in a *fresh process* pointed at the same cache root —
    performs zero XLA compilations."""
    body = textwrap.dedent("""
        import json, numpy as np
        from repro.apps import gaussian
        g, rep, prog = gaussian.compile_app(iters=4)
        img = np.random.default_rng(0).standard_normal((12, 12)) \\
            .astype(np.float32)
        out = np.asarray(prog(img))
        ref = img
        for _ in range(4):
            ref = gaussian._stencil_ref(ref)
        assert float(np.abs(out - ref).max()) < 1e-4
        print("REPORT", json.dumps({
            "n_compiled": rep.n_compiled,
            "n_cache_hits": rep.n_cache_hits,
            "sources": sorted(set(rep.sources.values()))}))
    """)
    outs = []
    for _ in range(2):
        r = subprocess.run(
            [sys.executable, "-c", body], capture_output=True, text=True,
            timeout=600,
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                 "REPRO_COMPILE_CACHE": str(tmp_path),
                 "JAX_PLATFORMS": "cpu", "HOME": str(tmp_path)})
        assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
        line = [l for l in r.stdout.splitlines() if l.startswith("REPORT")]
        outs.append(json.loads(line[0][len("REPORT "):]))
    assert outs[0]["n_compiled"] == 3          # cold: 3 unique definitions
    assert outs[1]["n_compiled"] == 0          # warm process: all from disk
    assert outs[1]["sources"] == ["disk"]


@pytest.mark.slow
def test_serve_warmup_through_cache(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                    serve_requests)

    V = 16

    def prefill(toks):
        cache = jnp.sum(toks.astype(jnp.float32), axis=1)
        return jax.nn.one_hot((toks[:, -1] + 1) % V, V), cache

    def decode(tok, cache):
        return jax.nn.one_hot((tok + 1) % V, V), cache + 1.0

    cc = CompileCache(root=tmp_path)
    eng = ServingEngine(ServeConfig(batch_slots=2), prefill, decode)
    info = eng.warmup(prompt_len=3, cache=cc)
    assert info["ok"] and info["prefill"] == "compiled"
    res = serve_requests(eng, [Request(0, [1, 2, 3], max_new=3)])
    assert res[0] == [4, 5, 6]
    # a second engine (same shapes) resolves warmup from the cache
    eng2 = ServingEngine(ServeConfig(batch_slots=2), prefill, decode)
    info2 = eng2.warmup(prompt_len=3, cache=cc)
    assert info2["ok"] and info2["prefill"] in ("memory", "disk")
    # non-jittable toy engines degrade gracefully (np.asarray on a tracer
    # raises at trace time -> warmup falls back to eager)
    eng3 = ServingEngine(
        ServeConfig(),
        lambda t: (np.ones((1, V)) * float(np.asarray(t).sum()),
                   np.zeros(1)),
        lambda t, c: (np.ones((1, V)), c))
    assert eng3.warmup(cache=cc)["ok"] is False


@pytest.mark.slow
def test_cnn_gcn_compiled_apps_match_reference(tmp_path):
    from repro.apps import cnn, gcn

    cc = CompileCache(root=tmp_path)
    rep, prog, ref = cnn.compile_app(cache=cc)
    assert rep.n_unique == 2                  # P*P PEs share one definition
    np.testing.assert_allclose(np.asarray(prog()), ref, atol=1e-3)
    rep2, prog2, ref2 = gcn.compile_app(cache=cc)
    np.testing.assert_allclose(np.asarray(prog2()), ref2, atol=1e-3)
    # re-created closures: zero compiles on a rerun
    rep3, _, _ = cnn.compile_app(cache=cc)
    assert rep3.n_compiled == 0
