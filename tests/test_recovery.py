"""Crash recovery: graph snapshots, supervised restart, serving journal.

Four sections:

* **Snapshots + chunked execution** — ``run_recoverable`` produces
  bit-identical mmap outputs vs. the plain engines, on every engine, with
  and without a persistent :class:`SnapshotStore`.
* **Fault matrix** — inject a :class:`CrashFault` (task-site or chunk
  boundary), let :func:`run_supervised` restore the latest snapshot, and
  assert the final outputs match the fault-free run bit for bit — on gemm
  AND page_rank (the feedback case), across the coroutine and compiled
  engines, including snapshot-under-one-engine -> restore-under-another.
* **Edge-case capture/restore** — a channel frozen mid-burst, a full
  channel, EoT-propagated-but-unread, and an ``AsyncMMap`` with an
  accepted-but-undelivered (in-flight) request.
* **Serving journal** — replay folding, torn-tail repair, exactly-once
  delivery across a simulated and a real SIGKILL crash, and
  no-recompute-on-replay.
"""

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import CrashFault, StepTask, channel, mmap
from repro.core.channel import EOT
from repro.core.faults import FaultPlan
from repro.core.interface import async_mmap
from repro.ft.recovery import (RestartPolicy, SnapshotStore, capture_channel,
                               capture_port, restore_channel, restore_port,
                               run_recoverable, run_supervised)
from repro.serve import (Request, ServeConfig, ServeJournal, ServingEngine,
                         serve_requests)

SRC = str(Path(__file__).resolve().parent.parent / "src")
# crash faults are count-based (seed moves nothing), but the CI chaos
# sweep runs this file under several seeds like test_faults.py
SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _mmaps(args):
    """Every MMap in a (possibly nested) args tuple, in order."""
    from repro.core.interface import MMap
    out = []

    def walk(v):
        if isinstance(v, MMap):
            out.append(v)
        elif isinstance(v, (list, tuple)):
            for x in v:
                walk(x)
    walk(args)
    return out


def _outputs(args):
    return [np.array(np.asarray(m.data), copy=True) for m in _mmaps(args)]


def relay_pipeline(n_tokens=32, burst=8, capacity=16):
    fires = n_tokens // burst

    def source_step(k, out):
        out.write_burst(jnp.arange(burst, dtype=jnp.int32) + k * burst)
        return k + 1

    def relay_step(state, inp, out):
        out.write_burst(inp.read_burst(burst) * 2)
        return state

    def sink_step(k, inp, res):
        res.write_burst(k * burst, inp.read_burst(burst))
        return k + 1

    Source = StepTask(source_step, steps=fires, init=jnp.int32(0),
                      name="Source")
    Relay = StepTask(relay_step, steps=fires, name="Relay")
    Sink = StepTask(sink_step, steps=fires, init=jnp.int32(0), name="Sink")

    buf = np.zeros(n_tokens, np.int32)
    res = mmap(buf, "res")

    def Top(res):
        c0 = channel(capacity, "c0", dtype=np.int32, shape=())
        c1 = channel(capacity, "c1", dtype=np.int32, shape=())
        repro.task().invoke(Source, c0).invoke(Relay, c0, c1) \
            .invoke(Sink, c1, res)

    return Top, (res,), buf


def _build_app(app):
    if app == "gemm":
        from repro.apps import gemm
        return gemm.build_step(P=2, n=4, K=3, seed=0)
    from repro.apps import page_rank
    return page_rank.build_step(n_vertices=16, n_edges=48, n_pe=2,
                                n_iters=4, seed=0)


def _golden(app):
    top, args, check = _build_app(app)
    rep = repro.ENGINES["coroutine"]().run(top, *args)
    assert rep.ok, rep.error
    ok, err = check()
    assert ok, err
    return _outputs(args)


# ---------------------------------------------------------------------------
# snapshots + chunked execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine",
                         ["sequential", "thread", "coroutine", "compiled"])
def test_recoverable_matches_plain_every_engine(engine):
    top, args, buf = relay_pipeline()
    rep = repro.ENGINES["coroutine"]().run(top, *args)
    assert rep.ok
    golden = buf.copy()

    top, args, buf = relay_pipeline()
    rep = run_recoverable(engine, top, *args, snapshot_every=2)
    assert rep.ok, rep.error
    assert np.array_equal(buf, golden)


def test_recoverable_snapshots_cut_on_full_channels():
    """A tight capacity forces sweep cuts where channels are full — the
    snapshot must carry a full ring and restore it."""
    top, args, buf = relay_pipeline(n_tokens=48, burst=8, capacity=8)
    rep = repro.ENGINES["coroutine"]().run(top, *args)
    assert rep.ok
    golden = buf.copy()
    for engine in ("coroutine", "compiled"):
        top, args, buf = relay_pipeline(n_tokens=48, burst=8, capacity=8)
        rep = run_recoverable(engine, top, *args, snapshot_every=1)
        assert rep.ok, rep.error
        assert np.array_equal(buf, golden), engine


def test_store_resume_skips_completed_sweeps(tmp_path):
    top, args, buf = relay_pipeline()
    store = SnapshotStore(tmp_path)
    inj = FaultPlan(seed=SEED, crash={"chunk": 2}).injector()
    with pytest.raises(CrashFault):
        run_recoverable("coroutine", top, *args, store=store,
                        snapshot_every=1, faults=inj)
    partial = buf.copy()
    # the crash interrupted the run mid-way: some output rows are missing
    top2, args2, buf2 = relay_pipeline()
    rep = run_recoverable("coroutine", top2, *args2, store=store,
                          snapshot_every=1)
    assert rep.ok, rep.error
    top3, args3, buf3 = relay_pipeline()
    rep3 = repro.ENGINES["coroutine"]().run(top3, *args3)
    assert np.array_equal(buf2, buf3)
    assert not np.array_equal(partial, buf3)   # the crash really cut it


def test_stale_snapshot_of_other_graph_is_ignored(tmp_path):
    store = SnapshotStore(tmp_path)
    top, args, _ = relay_pipeline()
    rep = run_recoverable("coroutine", top, *args, store=store,
                          snapshot_every=2)
    assert rep.ok
    # a different graph with the same store directory starts from scratch
    top2, args2, buf2 = relay_pipeline(n_tokens=48, burst=8, capacity=8)
    rep = run_recoverable("coroutine", top2, *args2, store=store,
                          snapshot_every=2)
    assert rep.ok, rep.error
    top3, args3, buf3 = relay_pipeline(n_tokens=48, burst=8, capacity=8)
    repro.ENGINES["coroutine"]().run(top3, *args3)
    assert np.array_equal(buf2, buf3)


def test_abstract_schedule_matches_compiled_sweep_count():
    from repro.core.synth import elaborate_step_graph
    from repro.ft.recovery import _abstract_schedule
    top, args, _ = relay_pipeline(n_tokens=48, burst=8, capacity=8)
    plan, graph, _ = elaborate_step_graph(top, *args)
    cuts, stalled = _abstract_schedule(plan)
    assert not stalled
    top2, args2, _ = relay_pipeline(n_tokens=48, burst=8, capacity=8)
    rep = repro.ENGINES["compiled"]().run(top2, *args2)
    assert rep.ok
    assert rep.switches == len(cuts) - 1


# ---------------------------------------------------------------------------
# fault matrix: crash + supervised restart -> bit-identical outputs
# ---------------------------------------------------------------------------

_CRASHES = {
    # exact instance names (these graphs name instances explicitly)
    "gemm": [{"chunk": 1}, {"PE1_1": 4}],
    "page_rank": [{"chunk": 1}, {"Scatter0": 2}],
}


@pytest.mark.parametrize("app", ["gemm", "page_rank"])
@pytest.mark.parametrize("engine", ["coroutine", "compiled"])
def test_fault_matrix_recovery_parity(app, engine, tmp_path):
    golden = _golden(app)
    crashes = _CRASHES[app] if engine != "compiled" else \
        [c for c in _CRASHES[app] if "chunk" in c]
    for k, crash in enumerate(crashes):
        top, args, check = _build_app(app)
        store = SnapshotStore(tmp_path / f"{engine}_{k}")
        rep = run_supervised(engine, top, *args,
                             store=store, snapshot_every=2,
                             faults=FaultPlan(seed=SEED, crash=crash),
                             policy=RestartPolicy(max_restarts=2,
                                                  backoff_s=0.0))
        assert rep.ok, (crash, rep.error)
        got = _outputs(args)
        for a, b in zip(got, golden):
            assert np.array_equal(a, b), (crash, "output mismatch")
        ok, err = check()
        assert ok, (crash, err)


@pytest.mark.parametrize("app", ["gemm", "page_rank"])
@pytest.mark.parametrize("first,second", [("coroutine", "compiled"),
                                          ("compiled", "coroutine")])
def test_cross_engine_snapshot_restore_parity(app, first, second, tmp_path):
    """Crash under one engine, finish under the other, from the same
    persisted snapshot — outputs must be bit-identical to fault-free."""
    golden = _golden(app)
    store = SnapshotStore(tmp_path)
    top, args, _ = _build_app(app)
    inj = FaultPlan(seed=SEED, crash={"chunk": 1}).injector()
    with pytest.raises(CrashFault):
        run_recoverable(first, top, *args, store=store, snapshot_every=1,
                        faults=inj)
    top2, args2, check2 = _build_app(app)
    rep = run_recoverable(second, top2, *args2, store=store,
                          snapshot_every=1)
    assert rep.ok, rep.error
    got = _outputs(args2)
    for a, b in zip(got, golden):
        assert np.array_equal(a, b), "cross-engine output mismatch"
    ok, err = check2()
    assert ok, err


def test_supervisor_exhausts_restarts_and_raises():
    top, args, _ = relay_pipeline()
    # an unkeyed persistent crash: a fresh injector every attempt would
    # refire, but the SHARED injector fires once — so to exhaust restarts
    # we crash at three distinct boundaries
    with pytest.raises(CrashFault, match="still crashing"):
        run_supervised(
            "coroutine", top, *args,
            faults=FaultPlan(seed=SEED, crash={"Source": 0, "Relay": 0,
                                            "Sink": 0}),
            policy=RestartPolicy(max_restarts=1, backoff_s=0.0))


def test_supervisor_plain_delegation_without_store():
    """store=None is the zero-overhead path: plain engine run, and a
    crash restarts from scratch (shared injector fires once)."""
    top, args, buf = relay_pipeline()
    rep = run_supervised("coroutine", top, *args,
                         faults=FaultPlan(seed=SEED, crash={"Relay": 3}),
                         policy=RestartPolicy(max_restarts=2, backoff_s=0.0))
    assert rep.ok, rep.error
    top2, args2, buf2 = relay_pipeline()
    repro.ENGINES["coroutine"]().run(top2, *args2)
    assert np.array_equal(buf, buf2)


def test_supervisor_falls_back_for_non_step_graphs():
    """Outside the step subset (EoT termination) the supervisor degrades
    to restart-from-scratch — and still recovers from a crash."""
    got = []

    def producer(out):
        out.write_burst([1, 2, 3])
        out.close()

    def consumer(inp):
        got.append([int(t) for t in inp.read_transaction()])

    def Top():
        c = channel(8, "c", dtype=np.int32, shape=())
        repro.task().invoke(producer, c).invoke(consumer, c)

    rep = run_supervised("coroutine", Top,
                         store=None,
                         faults=FaultPlan(seed=SEED, crash={"producer": 1}),
                         policy=RestartPolicy(max_restarts=2, backoff_s=0.0))
    assert rep.ok, rep.error
    assert got[-1] == [1, 2, 3]


# ---------------------------------------------------------------------------
# edge-case capture/restore containers
# ---------------------------------------------------------------------------

def test_capture_restore_channel_mid_burst():
    """Freeze a channel halfway through a burst write (more tokens than a
    reader has consumed) and restore it into a fresh channel."""
    c = channel(8, "c", dtype=np.int32, shape=())
    for t in (1, 2, 3):
        c._push(t)
    st = capture_channel(c)
    c._pop(), c._push(9)                 # diverge after the capture
    c2 = channel(8, "c", dtype=np.int32, shape=())
    restore_channel(c2, st)
    assert list(c2._q) == [1, 2, 3]
    assert c2._eot_count == 0


def test_capture_restore_full_channel():
    c = channel(4, "c", dtype=np.int32, shape=())
    for t in range(4):
        c._push(t)
    st = capture_channel(c)
    c2 = channel(4, "c", dtype=np.int32, shape=())
    restore_channel(c2, st)
    assert len(c2._q) == c2.capacity == 4
    assert list(c2._q) == [0, 1, 2, 3]


def test_capture_restore_eot_propagated_but_unread():
    """EoT sits in the queue behind unread data: the restored channel
    must deliver the transaction then the EoT, exactly once."""
    c = channel(8, "c", dtype=np.int32, shape=())
    c._push(7)
    c._push(8)
    c._push(EOT)
    st = capture_channel(c)
    assert st.eot_count == 1
    c2 = channel(8, "c", dtype=np.int32, shape=())
    restore_channel(c2, st)
    assert c2._eot_count == 1
    got = []
    while c2._q:
        t = c2._pop()
        if t is EOT:
            break
        got.append(int(t))
    assert got == [7, 8]
    assert c2._eot_count == 0 and not c2._q       # EoT delivered exactly once


class _StubEngine:
    """Just enough engine surface for AsyncMMap.pump: a clock and an
    event list we can drain (or abandon, simulating a crash)."""
    clock = 0
    force_async = True
    faults = None

    def __init__(self):
        self.events = []

    def schedule_async(self, lat, fn):
        self.events.append(fn)

    def _iface_pop(self, ch):
        return ch._pop()

    def _iface_deliver(self, ch, v):
        ch._push(v)


def test_capture_restore_port_with_pending_response():
    data = np.arange(8, dtype=np.float32)
    port = async_mmap(data, name="m", latency=2, depth=4)
    eng = _StubEngine()
    port._raddr._push(3)
    port._raddr._push(5)
    port.pump(eng)
    assert port._pending_reads == 2            # accepted, not delivered
    assert port._inflight_reads == [3, 5]
    st = capture_port(port)

    # crash: the engine's event heap (delivery closures) is gone
    port2 = async_mmap(np.zeros(8, np.float32), name="m", latency=2, depth=4)
    restore_port(port2, st)
    assert np.array_equal(np.asarray(port2.data), data)
    assert port2._pending_reads == 0
    # the in-flight requests were re-queued ahead of anything unaccepted
    assert list(port2._raddr._q) == [3, 5]
    eng2 = _StubEngine()
    port2.pump(eng2)                           # re-accept
    for fn in list(eng2.events):               # deliver
        fn(eng2)
    assert [float(v) for v in port2._rdata._q] == [3.0, 5.0]
    assert port2._pending_reads == 0 and port2._inflight_reads == []


def test_capture_restore_port_inflight_write():
    data = np.zeros(8, np.float32)
    port = async_mmap(data, name="m", latency=1, depth=4)
    eng = _StubEngine()
    port._waddr._push(2)
    port._wdata._push(7.5)
    port.pump(eng)
    assert port._inflight_writes == [(2, 7.5)]
    st = capture_port(port)
    port2 = async_mmap(np.zeros(8, np.float32), name="m", latency=1, depth=4)
    restore_port(port2, st)
    eng2 = _StubEngine()
    port2.pump(eng2)
    for fn in list(eng2.events):
        fn(eng2)
    assert float(np.asarray(port2.data)[2]) == 7.5
    assert len(port2._wresp._q) == 1           # the ack materialized


# ---------------------------------------------------------------------------
# compiled latency queues in snapshots (resumable async_mmap)
# ---------------------------------------------------------------------------


def _async_gemm():
    from repro.apps import gemm
    return gemm.build_step_async(P=2, n=4, K=4, depth=4)


def _c_bytes(args):
    _, _, c_ports = args
    return np.stack([np.asarray(p.data) for p in c_ports]).tobytes()


@pytest.mark.slow
def test_python_engines_refuse_port_graphs(tmp_path):
    from repro.core import SynthesisError
    top, args, _ = _async_gemm()
    store = SnapshotStore(tmp_path)
    with pytest.raises(SynthesisError, match="async_mmap ports .*compiled"):
        run_recoverable("coroutine", top, *args, store=store,
                        snapshot_every=2)


@pytest.mark.slow
def test_compiled_port_chunks_match_plain(tmp_path):
    """Depth-4 async gemm run in snapshot chunks is a bit-twin of the
    unchunked compiled run, and the snapshot rows carry the four ports'
    full 16-row latency-queue carry."""
    top, args, check = _async_gemm()
    rep = repro.ENGINES["compiled"]().run(top, *args)
    assert rep.ok and check()[0]
    golden = _c_bytes(args)

    store = SnapshotStore(tmp_path)
    top2, args2, check2 = _async_gemm()
    rep2 = run_recoverable("compiled", top2, *args2, store=store,
                           snapshot_every=3)
    assert rep2.ok, rep2.error
    assert check2()[0]
    assert _c_bytes(args2) == golden

    from repro.core.synth import elaborate_step_graph
    plan, graph, _ = elaborate_step_graph(top2, *args2)
    snap = store.load_latest(plan, graph.structural_hash(),
                             [c.capacity for c in plan.channels])
    assert snap is not None
    assert len(snap.ports) == len(plan.ports) == 4
    assert all(len(pc) == 16 for pc in snap.ports)


@pytest.mark.slow
def test_compiled_port_crash_resume_supervised(tmp_path):
    """A crash between chunks resumes from the port-bearing snapshot and
    still produces the plain run's exact output bytes."""
    top, args, check = _async_gemm()
    rep = repro.ENGINES["compiled"]().run(top, *args)
    assert rep.ok and check()[0]
    golden = _c_bytes(args)

    store = SnapshotStore(tmp_path)
    top2, args2, check2 = _async_gemm()
    rep2 = run_supervised("compiled", top2, *args2, store=store,
                          snapshot_every=3,
                          faults=FaultPlan(seed=7, crash={"chunk": 2}),
                          policy=RestartPolicy(max_restarts=2, backoff_s=0.0))
    assert rep2.ok, rep2.error
    assert check2()[0]
    assert _c_bytes(args2) == golden


# ---------------------------------------------------------------------------
# serving journal
# ---------------------------------------------------------------------------

V = 16


def _toy_engine(scfg, journal=None, calls=None):
    def prefill(toks):
        if calls is not None:
            calls.append(("prefill", toks.shape))
        last = int(toks[0, -1]) % V
        return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

    def decode(tok, cache):
        return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

    return ServingEngine(scfg, prefill, decode, journal=journal)


def _reqs(n=6, max_new=5):
    return [Request(rid=i, prompt=[i, i + 1], max_new=max_new)
            for i in range(n)]


def test_journal_replay_folds_records(tmp_path):
    j = ServeJournal(tmp_path / "j.jsonl")
    j.admit(0, [1, 2], 4, None)
    j.tok(0, 3)
    j.tok(0, 4)
    j.admit(1, [5], 4, None)
    j.retire(0, toks=[3, 4, 9, 9])
    j.retire(2, status="deadline", detail="late")
    j.close()
    completed, inflight = ServeJournal.replay(tmp_path / "j.jsonl")
    assert completed == {0: [3, 4, 9, 9], 2: ("deadline", "late")}
    assert inflight == {1: {"prompt": [5], "max_new": 4, "deadline": None,
                            "toks": []}}


def test_journal_torn_tail_dropped_and_repaired(tmp_path):
    p = tmp_path / "j.jsonl"
    j = ServeJournal(p)
    j.admit(0, [1], 3, None)
    j.tok(0, 2)
    j.close()
    with open(p, "a") as f:
        f.write('{"t":"tok","rid":0,"to')      # crash mid-append
    completed, inflight = ServeJournal.replay(p)
    assert inflight[0]["toks"] == [2]          # torn record dropped
    j2 = ServeJournal(p)                       # reopen repairs the tail
    j2.tok(0, 5)
    j2.close()
    completed, inflight = ServeJournal.replay(p)
    assert inflight[0]["toks"] == [2, 5]       # appended record readable


def test_exactly_once_after_simulated_crash(tmp_path):
    scfg = ServeConfig(batch_slots=2, max_seq=64)
    oracle = serve_requests(_toy_engine(scfg), _reqs())

    jp = tmp_path / "j.jsonl"
    serve_requests(_toy_engine(scfg, journal=jp), _reqs())
    lines = open(jp).read().splitlines()
    # SIGKILL mid-stream: keep a prefix that leaves requests in flight
    cut = tmp_path / "cut.jsonl"
    cut.write_text("\n".join(lines[:9]) + "\n")
    completed, inflight = ServeJournal.replay(cut)
    assert inflight                            # something really in flight

    res = serve_requests(_toy_engine(scfg, journal=cut), _reqs())
    assert sorted(res) == sorted(oracle)       # every rid exactly once
    for rid in oracle:
        assert res[rid] == oracle[rid], rid


def test_completed_rids_answer_from_journal_without_recompute(tmp_path):
    scfg = ServeConfig(batch_slots=2, max_seq=64)
    jp = tmp_path / "j.jsonl"
    oracle = serve_requests(_toy_engine(scfg, journal=jp), _reqs())
    calls = []
    res = serve_requests(_toy_engine(scfg, journal=jp, calls=calls),
                         _reqs())
    assert res == oracle
    assert calls == []                         # zero prefill recompute


def test_seeded_resume_counts_seeded_tokens_once(tmp_path):
    """A request killed at its second-to-last token resumes for exactly
    one more token — max_new accounting spans the crash."""
    scfg = ServeConfig(batch_slots=1, max_seq=64)
    jp = tmp_path / "j.jsonl"
    j = ServeJournal(jp)
    j.admit(0, [4, 5], 3, None)
    j.tok(0, 6)
    j.tok(0, 7)
    j.close()
    res = serve_requests(_toy_engine(scfg, journal=jp),
                         [Request(rid=0, prompt=[4, 5], max_new=3)])
    assert res[0] == [6, 7, 8]
    completed, inflight = ServeJournal.replay(jp)
    assert completed[0] == [6, 7, 8] and not inflight


_SERVE_PROC = r"""
import json, sys, time
import numpy as np
from repro.serve import Request, ServeConfig, ServingEngine, serve_requests

V = 16
journal, slow = sys.argv[1], float(sys.argv[2])

def prefill(toks):
    last = int(toks[0, -1]) % V
    return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

def decode(tok, cache):
    time.sleep(slow)
    return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

scfg = ServeConfig(batch_slots=2, max_seq=64)
eng = ServingEngine(scfg, prefill, decode, journal=journal)
reqs = [Request(rid=i, prompt=[i, i + 1], max_new=6) for i in range(4)]
res = serve_requests(eng, reqs)
print("RESULTS " + json.dumps({str(k): v for k, v in res.items()}))
"""


def test_sigkill_mid_stream_exactly_once(tmp_path):
    """SIGKILL a serving process mid-decode; the restarted process drains
    the journal and delivers every result exactly once, matching the
    fault-free oracle."""
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": str(tmp_path)}
    jp = tmp_path / "j.jsonl"

    # oracle: no journal, no crash, instant decode
    oracle_j = tmp_path / "oracle.jsonl"
    r = subprocess.run([sys.executable, "-c", _SERVE_PROC,
                        str(oracle_j), "0"],
                       capture_output=True, text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    oracle = json.loads(r.stdout.split("RESULTS ", 1)[1])

    # victim: slow decode so the parent can kill it mid-stream
    p = subprocess.Popen([sys.executable, "-c", _SERVE_PROC,
                          str(jp), "0.05"],
                         stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                         text=True, env=env)
    deadline = time.time() + 120
    try:
        while time.time() < deadline:
            if jp.exists() and \
                    sum(1 for l in open(jp) if '"t":"tok"' in l) >= 5:
                break
            if p.poll() is not None:
                pytest.fail(f"victim exited early: "
                            f"{p.communicate()[1][-2000:]}")
            time.sleep(0.02)
        else:
            pytest.fail("victim made no journal progress")
        p.send_signal(signal.SIGKILL)
        p.wait(timeout=60)
    finally:
        if p.poll() is None:
            p.kill()
    completed, inflight = ServeJournal.replay(jp)
    assert inflight, "SIGKILL landed after all requests finished"

    # restart: same command, same journal
    r = subprocess.run([sys.executable, "-c", _SERVE_PROC, str(jp), "0"],
                       capture_output=True, text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.split("RESULTS ", 1)[1])
    assert res == oracle                       # exactly once, bit-for-bit


# ---------------------------------------------------------------------------
# train driver: kill-and-resume through resume_or_init
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_kill_and_resume_falls_past_corrupt_step(tmp_path):
    """SIGKILL a training run mid-flight, corrupt its newest checkpoint,
    and assert the rerun resumes from the previous verified step."""
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": str(tmp_path)}
    ckpt = tmp_path / "ckpt"
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen3-0.6b", "--reduced", "--steps", "400", "--batch", "2",
           "--seq", "32", "--ckpt-dir", str(ckpt), "--ckpt-every", "2",
           "--log-every", "1000"]
    p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True, env=env)
    deadline = time.time() + 240
    try:
        while time.time() < deadline:
            done = sorted(ckpt.glob("step_*/DONE"))
            if len(done) >= 2:
                break
            if p.poll() is not None:
                pytest.fail(f"train exited early: "
                            f"{p.communicate()[1][-3000:]}")
            time.sleep(0.1)
        else:
            pytest.fail("no checkpoints appeared before the deadline")
        p.send_signal(signal.SIGKILL)
        p.wait(timeout=60)
    finally:
        if p.poll() is None:
            p.kill()

    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(ckpt)
    steps = mgr.steps()
    assert len(steps) >= 2
    # corrupt the newest published step: truncate one leaf file
    victim = sorted((ckpt / f"step_{steps[-1]:08d}").rglob("*.npy"))[0]
    victim.write_bytes(victim.read_bytes()[:10])
    assert mgr.verify(steps[-1])               # really corrupt now

    r = subprocess.run(cmd[:cmd.index("400")] + [str(steps[-2] + 2)] +
                       cmd[cmd.index("400") + 1:],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode in (0, 1), r.stderr[-3000:]
    assert f"resumed from checkpoint step {steps[-2]}" in r.stdout
