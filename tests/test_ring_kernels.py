"""Ring-buffer kernel parity (repro.kernels.ring).

The compiled interconnect's channel operations — burst push/pop against
VMEM-resident ring state and the fused all-task guard evaluation — must
be bit-identical across every backend implementation: the XLA reference
path, the Pallas kernel under the interpreter (CI), and the Mosaic-
lowered kernel on a real TPU.  A Python deque is the oracle; the op
sequences force wraparound, capacity-1 rings, and full/empty boundaries.
"""

from collections import deque

import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.kernels import ring
from repro.kernels.dispatch import is_tpu, resolve_impl

IMPLS = ["xla", "interpret"] + (["pallas"] if is_tpu() else [])


def _mk(counter, n, elem, dtype):
    """n fresh tokens with distinct values (rows counter..counter+n-1)."""
    base = counter + np.arange(n)
    flat = (base[:, None] * 100 +
            np.arange(max(1, int(np.prod(elem, dtype=int))))[None, :])
    arr = flat.reshape((n,) + elem) if elem else flat[:, 0]
    if dtype == np.bool_:
        return (arr % 2).astype(np.bool_)
    return arr.astype(dtype)


def _run_ops(cap, elem, dtype, impl, n_ops=24, seed=0):
    rng = np.random.default_rng(seed)
    buf = jnp.zeros((cap,) + elem, dtype=dtype)
    head = jnp.int32(0)
    size = jnp.int32(0)
    oracle = deque()
    counter = 0
    for _ in range(n_ops):
        free = cap - len(oracle)
        if len(oracle) and (free == 0 or rng.random() < 0.5):
            n = int(rng.integers(1, len(oracle) + 1))
            toks, head, size = ring.ring_pop(buf, head, size, n, impl=impl)
            want = np.stack([oracle.popleft() for _ in range(n)])
            got = np.asarray(toks).reshape(want.shape)
            assert np.array_equal(got, want), (impl, cap, elem)
        else:
            n = int(rng.integers(1, free + 1))
            arr = _mk(counter, n, elem, dtype)
            counter += n
            buf, head, size = ring.ring_push(buf, head, size,
                                             jnp.asarray(arr), impl=impl)
            oracle.extend(arr)
        assert int(size) == len(oracle)


_ORACLE_CASES = [
    (1, (), np.int32),               # capacity-1 ring: every push wraps
    (5, (), np.int32),
    (5, (3,), np.int32),
    (4, (2, 2), np.float32),
    (3, (), np.bool_),               # rides the int32 kernel cast
    (7, (3,), np.float32),
]


def _oracle_params():
    # the sequential interpreter costs ~3s per op sequence, so tier-1
    # keeps two representative interpret combos (capacity-1 wraparound +
    # a 2-D float element) and the CI kernel job (-m "") runs the rest
    out = []
    for impl in IMPLS:
        for i, (cap, elem, dtype) in enumerate(_ORACLE_CASES):
            heavy = impl == "interpret" and i not in (0, 3)
            marks = (pytest.mark.slow,) if heavy else ()
            out.append(pytest.param(cap, elem, dtype, impl, marks=marks))
    return out


@pytest.mark.parametrize("cap,elem,dtype,impl", _oracle_params())
def test_ring_matches_deque_oracle(cap, elem, dtype, impl):
    _run_ops(cap, elem, dtype, impl)


@pytest.mark.parametrize("impl", IMPLS)
def test_ring_preserves_sentinel_bits(impl):
    # EoT/sentinel payloads: NaN, infinities and signed zero must round-
    # trip bit-exactly through the ring (no arithmetic on the payload)
    vals = np.array([np.nan, -np.inf, np.inf, -0.0, 1.5e-38],
                    np.float32)
    buf = jnp.zeros((5,), jnp.float32)
    buf, head, size = ring.ring_push(buf, jnp.int32(3), jnp.int32(0),
                                     jnp.asarray(vals), impl=impl)
    toks, _, size = ring.ring_pop(buf, jnp.int32(3), size, 5, impl=impl)
    assert np.asarray(toks).tobytes() == vals.tobytes()
    assert int(size) == 0


def _guards_ref(sizes, caps, need_r, need_w, live):
    t = need_r.shape[0]
    out = np.zeros(t, bool)
    for ti in range(t):
        out[ti] = bool(live[ti]) and \
            all(need_r[ti, c] <= sizes[c] for c in range(len(caps))) and \
            all(need_w[ti, c] <= caps[c] - sizes[c]
                for c in range(len(caps)))
    return out


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("t,c,seed", [(1, 1, 0), (3, 2, 1), (17, 9, 2),
                                      (8, 130, 3)])
def test_eval_guards_matches_reference(impl, t, c, seed):
    rng = np.random.default_rng(seed)
    caps = rng.integers(1, 6, c).astype(np.int32)
    sizes = np.array([rng.integers(0, k + 1) for k in caps], np.int32)
    need_r = rng.integers(0, 4, (t, c)).astype(np.int32)
    need_w = rng.integers(0, 4, (t, c)).astype(np.int32)
    live = rng.integers(0, 2, t).astype(bool)
    got = np.asarray(ring.eval_guards(jnp.asarray(sizes), jnp.asarray(caps),
                                      jnp.asarray(need_r),
                                      jnp.asarray(need_w),
                                      jnp.asarray(live), impl=impl))
    want = _guards_ref(sizes, caps, need_r, need_w, live)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("impl", IMPLS)
def test_ring_ops_trace_under_jit(impl):
    @jax.jit
    def f(buf, head, size, arr):
        buf, head, size = ring.ring_push(buf, head, size, arr, impl=impl)
        return ring.ring_pop(buf, head, size, 2, impl=impl)

    buf = jnp.zeros((4, 3), jnp.float32)
    arr = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    toks, head, size = f(buf, jnp.int32(2), jnp.int32(0), arr)
    assert np.array_equal(np.asarray(toks), np.asarray(arr))
    assert int(size) == 0


def test_dispatch_precedence(monkeypatch):
    # explicit arg > environment > backend fallback
    monkeypatch.setenv(ring.RING_ENV, "interpret")
    assert resolve_impl("ring", ring.RING_ENV, ring.RING_CHOICES,
                        fallback="xla") == "interpret"
    assert resolve_impl("ring", ring.RING_ENV, ring.RING_CHOICES,
                        fallback="xla", impl="xla") == "xla"
    monkeypatch.delenv(ring.RING_ENV)
    want = "pallas" if is_tpu() else "xla"
    assert resolve_impl("ring", ring.RING_ENV, ring.RING_CHOICES,
                        fallback="xla") == want


def test_dispatch_rejects_unknown_impl(monkeypatch):
    with pytest.raises(ValueError, match="ring"):
        ring.ring_pop(jnp.zeros(4), jnp.int32(0), jnp.int32(2), 1,
                      impl="cuda")
    monkeypatch.setenv(ring.RING_ENV, "nope")
    with pytest.raises(ValueError, match="REPRO_RING_IMPL"):
        ring.ring_pop(jnp.zeros(4), jnp.int32(0), jnp.int32(2), 1)
