"""Hypothesis property tests: engine equivalence, determinism, and
scalar-vs-burst channel-I/O equivalence.

The KPN-determinism property (paper Section 2.2): for programs whose tasks
read from statically-known channels (no select/try polling), every engine
that completes must produce the *identical* token streams — the schedule
may differ, the data may not.  The burst extension must preserve this:
moving the same tokens through ``write_burst``/``read_burst``/
``read_transaction`` yields byte-identical sequences to scalar ops under
all three engines.

Requires ``hypothesis`` (see requirements-dev.txt); the whole module is
skipped on a bare environment.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

import repro  # noqa: E402


# ---------------------------------------------------------------------------
# generated pipeline programs: Source -> N x Transform -> Sink
# ---------------------------------------------------------------------------

def build_pipeline(values, n_stages, capacity):
    def Source(o):
        for v in values:
            o.write(v)
        o.close()

    def Transform(i, o, mul, add):
        for v in i:
            o.write(v * mul + add)
        o.close()

    def Sink(i, out):
        for v in i:
            out.append(v)

    def Top(out):
        chans = [repro.channel(capacity=capacity) for _ in range(n_stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(n_stages):
            t = t.invoke(Transform, chans[s], chans[s + 1], s + 1, s)
        t.invoke(Sink, chans[n_stages], out)

    def expect():
        cur = list(values)
        for s in range(n_stages):
            cur = [v * (s + 1) + s for v in cur]
        return cur

    return Top, expect


@given(values=st.lists(st.integers(-100, 100), max_size=20),
       n_stages=st.integers(1, 4),
       capacity=st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_kpn_determinism_across_engines(values, n_stages, capacity):
    results = {}
    for eng in ("coroutine", "thread", "sequential"):
        top, expect = build_pipeline(values, n_stages, capacity)
        out = []
        rep = repro.run(top, out, engine=eng)
        assert rep.ok, (eng, rep.error)
        results[eng] = out
        assert out == expect(), eng
    assert results["coroutine"] == results["thread"] == results["sequential"]


@given(values=st.lists(st.integers(-10, 10), min_size=1, max_size=10),
       capacity=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_feedback_ring_only_parallel_engines(values, capacity):
    """A 2-task token ring (feedback): coroutine/thread simulate it,
    sequential must fail — the paper's central simulation claim."""
    def A(i, o, sink):
        o.write(values[0])                     # seed the ring
        for _ in range(len(values) - 1):
            v = i.read()
            sink.append(v)
            o.write(v + 1)
        sink.append(i.read())

    def Top(sink):
        c1 = repro.channel(capacity=capacity)
        c2 = repro.channel(capacity=capacity)

        def B(i, o):
            for _ in range(len(values)):
                o.write(i.read())

        repro.task().invoke(A, c2, c1, sink).invoke(B, c1, c2)

    for eng in ("coroutine", "thread"):
        sink = []
        rep = repro.run(Top, sink, engine=eng)
        assert rep.ok, (eng, rep.error)
        assert sink == [values[0] + k for k in range(len(values))]

    rep = repro.run(Top, [], engine="sequential")
    assert not rep.ok


# ---------------------------------------------------------------------------
# burst equivalence: same tokens, same order, every engine, every mix of
# scalar/burst producer and consumer
# ---------------------------------------------------------------------------

def build_burst_pipeline(transactions, capacity, wmode, rmode, burst):
    """Producer sends ``transactions`` (a list of token lists, one EoT
    each); a consumer drains them.  ``wmode``/``rmode`` select scalar,
    burst, or transaction-granular I/O on each side."""
    def Producer(o):
        for txn in transactions:
            if wmode == "scalar":
                for v in txn:
                    o.write(v)
            elif wmode == "burst":
                for base in range(0, len(txn), burst):
                    o.write_burst(txn[base:base + burst])
            else:                               # one burst per transaction
                o.write_burst(txn)
            o.close()

    def Consumer(i, out):
        for _ in transactions:
            if rmode == "scalar":
                got = [v for v in i]
            elif rmode == "burst":
                got = []
                while True:
                    chunk = i.read_burst(burst)
                    got.extend(chunk)
                    if len(chunk) < burst:
                        break
                i.open()
            else:
                got = i.read_transaction()
            out.append(got)

    def Top(out):
        ch = repro.channel(capacity=capacity)
        repro.task().invoke(Producer, ch).invoke(Consumer, ch, out)

    return Top


@given(transactions=st.lists(
           st.lists(st.integers(-1000, 1000), max_size=12),
           min_size=1, max_size=4),
       capacity=st.integers(1, 6),
       burst=st.integers(1, 8),
       wmode=st.sampled_from(["scalar", "burst", "txn"]),
       rmode=st.sampled_from(["scalar", "burst", "txn"]))
@settings(max_examples=40, deadline=None)
def test_burst_scalar_equivalence(transactions, capacity, burst,
                                  wmode, rmode):
    """Any mix of scalar/burst producer x scalar/burst consumer moves the
    identical token sequences under all three engines, with EoT boundaries
    preserved exactly."""
    for eng in ("coroutine", "thread", "sequential"):
        out = []
        top = build_burst_pipeline(transactions, capacity, wmode, rmode,
                                   burst)
        rep = repro.run(top, out, engine=eng)
        assert rep.ok, (eng, wmode, rmode, rep.error)
        assert out == transactions, (eng, wmode, rmode)


@given(values=st.lists(st.integers(-100, 100), min_size=1, max_size=30),
       capacity=st.integers(1, 5),
       burst=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_burst_stats_match_scalar(values, capacity, burst):
    """Burst-granular statistics (track_stats=True) count exactly the
    same tokens as per-token scalar accounting."""
    reports = {}
    for mode in ("scalar", "burst"):
        def Producer(o):
            if mode == "scalar":
                for v in values:
                    o.write(v)
            else:
                for base in range(0, len(values), burst):
                    o.write_burst(values[base:base + burst])
            o.close()

        def Consumer(i, out):
            out.extend(i.read_transaction() if mode == "burst"
                       else [v for v in i])

        def Top(out):
            ch = repro.channel(capacity=capacity, name="ch")
            repro.task().invoke(Producer, ch).invoke(Consumer, ch, out)

        out = []
        rep = repro.run(Top, out, engine="coroutine", track_stats=True)
        assert rep.ok and out == values
        reports[mode] = rep
    assert reports["scalar"].tokens == reports["burst"].tokens == \
        len(values) + 1                       # data + EoT
