"""Chaos-harness fault matrix: every fault kind, every engine, one outcome
contract — detected with structured diagnostics or recovered; never a
silent hang, never an unstructured crash; always replayable by seed.

CI runs this file under several ``REPRO_CHAOS_SEED`` values; every test
must hold for any seed (probabilistic faults use per-site hash draws, so
a different seed only moves *which* ops fault, not the invariants).
The compiled engine's structured stall report is covered in
``test_synth.py::test_compiled_deadlock_reports_blocked_task`` (slow tier)
— channel/task faults target the software engines' op paths and do not
apply to the whole-graph XLA program.
"""

import os

import numpy as np
import pytest

import repro
from repro import DeadlockReport, FaultPlan
from repro.core.compile_cache import CompileCache
from repro.serve import (Request, RequestError, ServeConfig, ServingEngine,
                         serve_requests)

SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
SW_ENGINES = ("sequential", "thread", "coroutine")


# ---------------------------------------------------------------------------
# programs
# ---------------------------------------------------------------------------

def _pipeline(n=40, capacity=4):
    """Source -> Relay -> Sink over channels named c0/c1; returns (Top, out)."""
    out: list = []

    def Source(o):
        for v in range(n):
            o.write(v)
        o.close()

    def Relay(i, o):
        for v in i:
            o.write(v)
        o.close()

    def Sink(i):
        for v in i:
            out.append(v)

    def Top():
        c0 = repro.channel(capacity=capacity, name="c0")
        c1 = repro.channel(capacity=capacity, name="c1")
        repro.task() \
            .invoke(Source, c0, name="Source") \
            .invoke(Relay, c0, c1, name="Relay") \
            .invoke(Sink, c1, name="Sink")

    return Top, out


def _deadlock_top():
    """Consumer reads a channel its producer never feeds: a genuine
    read-starvation deadlock under every engine."""

    def Producer(o):
        pass                              # never writes, never closes

    def Consumer(i):
        i.read()

    def Top():
        c0 = repro.channel(capacity=2, name="c0")
        repro.task() \
            .invoke(Producer, c0, name="Producer") \
            .invoke(Consumer, c0, name="Consumer")

    return Top


def _pingpong_top():
    """Two tasks echoing forever — livelock for the wall-clock watchdog."""

    def Ping(o, i):
        v = 0
        while True:
            o.write(v)
            v = i.read()

    def Pong(i, o):
        while True:
            o.write(i.read())

    def Top():
        a = repro.channel(capacity=1, name="a")
        b = repro.channel(capacity=1, name="b")
        repro.task() \
            .invoke(Ping, a, b, name="Ping") \
            .invoke(Pong, a, b, name="Pong")

    return Top


# ---------------------------------------------------------------------------
# channel stalls + delayed wakes: delayed, never lost
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", SW_ENGINES)
def test_chan_stall_recovers_everywhere(engine):
    plan = FaultPlan(seed=SEED,
                     chan_stall={"*": {"p": 0.3, "stall": 2, "wake": 1}})
    inj = plan.injector()
    top, out = _pipeline()
    rep = repro.ENGINES[engine](faults=inj).run(top)
    assert rep.ok, rep.error
    assert out == list(range(40))         # every token arrived, in order
    assert any(e[0] == "chan" for e in inj.log)   # faults actually fired


@pytest.mark.parametrize("engine", SW_ENGINES)
def test_task_raise_structured_failure(engine):
    plan = FaultPlan(seed=SEED, task_raise={"Relay": 5})
    top, _ = _pipeline()
    rep = repro.ENGINES[engine](faults=plan).run(top)
    assert not rep.ok
    assert "InjectedFault" in rep.error
    assert "Relay" in rep.error


# ---------------------------------------------------------------------------
# unified deadlock watchdog: same structured report, every engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", SW_ENGINES)
def test_deadlock_report_parity(engine):
    rep = repro.ENGINES[engine]().run(_deadlock_top())
    assert not rep.ok
    if engine == "sequential":
        # the paper-documented failure mode keeps its legacy message ...
        assert "cannot make progress" in rep.error
    else:
        assert "deadlock" in rep.error.lower()
    # ... while the structured report is unified across all engines
    d = rep.deadlock
    assert isinstance(d, DeadlockReport)
    assert d.engine == engine
    assert d.reason == ("sequential-read" if engine == "sequential"
                        else "deadlock")
    assert any(site == "read c0" and "Consumer" in t
               for t, site in d.blocked), d.blocked
    assert d.occupancy.get("c0", 0) == 0  # c0 never held a token
    assert d.format().startswith(f"deadlock[{d.reason}]")


@pytest.mark.parametrize("engine", ("thread", "coroutine"))
def test_wall_clock_watchdog_breaks_livelock(engine):
    rep = repro.ENGINES[engine](watchdog_s=0.2).run(_pingpong_top())
    assert not rep.ok
    assert rep.deadlock is not None
    assert rep.deadlock.reason == "watchdog"
    assert rep.deadlock.wall_s >= 0.2
    assert "deadlock[watchdog]" in rep.error


@pytest.mark.parametrize("engine", ("thread", "coroutine"))
def test_tick_budget_watchdog(engine):
    rep = repro.ENGINES[engine](max_ticks=50).run(_pingpong_top())
    assert not rep.ok
    assert rep.deadlock is not None
    assert rep.deadlock.reason == "tick-budget"


# ---------------------------------------------------------------------------
# determinism and replay
# ---------------------------------------------------------------------------

def test_replay_same_seed_same_log():
    plan = FaultPlan(seed=SEED,
                     chan_stall={"*": {"p": 0.4, "stall": 1, "wake": 1}})
    logs = []
    for _ in range(2):
        inj = plan.injector()
        top, out = _pipeline()
        rep = repro.ENGINES["coroutine"](faults=inj).run(top)
        assert rep.ok and out == list(range(40))
        logs.append(list(inj.log))
    assert logs[0] == logs[1]
    assert logs[0]                        # non-empty at p=0.4


def test_replay_decisions_are_engine_independent():
    """The k-th op at a site draws the same verdict under any engine, so
    the *set* of fired channel faults matches across engines (only the
    interleaving — the log order — may differ)."""
    plan = FaultPlan(seed=SEED,
                     chan_stall={"*": {"p": 0.4, "stall": 1, "wake": 1}})
    fired = []
    for engine in SW_ENGINES:
        inj = plan.injector()
        top, out = _pipeline()
        rep = repro.ENGINES[engine](faults=inj).run(top)
        assert rep.ok and out == list(range(40)), engine
        fired.append(sorted(e for e in inj.log if e[0] == "chan"))
    assert fired[0] == fired[1] == fired[2]


def test_different_seed_different_decisions():
    logs = []
    for seed in (SEED, SEED + 1):
        plan = FaultPlan(seed=seed,
                         chan_stall={"*": {"p": 0.5, "stall": 1, "wake": 0}})
        inj = plan.injector()
        top, _ = _pipeline()
        assert repro.ENGINES["coroutine"](faults=inj).run(top).ok
        logs.append(sorted(inj.log))
    assert logs[0] != logs[1]


def test_noop_plan_keeps_fast_path_and_semantics():
    """Zero-overhead contract: an armed-but-empty plan must not disable
    the coroutine fast path (the <5% bench gate is structural)."""
    eng = repro.ENGINES["coroutine"](faults=FaultPlan(seed=SEED))
    assert eng.fast_path
    top, out = _pipeline()
    assert eng.run(top).ok and out == list(range(40))
    armed = repro.ENGINES["coroutine"](
        faults=FaultPlan(chan_stall={"c0": {"p": 1.0, "stall": 1}}))
    assert not armed.fast_path


# ---------------------------------------------------------------------------
# memory-latency spikes: legal reordering only
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("thread", "coroutine"))
def test_mem_spike_preserves_port_fifo(engine):
    data = np.arange(100, 116, dtype=np.int64)
    port = repro.async_mmap(data, latency=2, depth=4, name="port")
    sink: list = []

    def Gather(mem, out):
        out.write_burst(mem.read_pipelined(range(16)))
        out.close()

    def Top(mem):
        ch = repro.channel(capacity=16)
        repro.task() \
            .invoke(Gather, mem, ch, name="Gather") \
            .invoke(lambda i, acc: acc.extend(i.read_transaction()),
                    ch, sink, name="Sink")

    plan = FaultPlan(seed=SEED, mem_spike={"*": {"p": 0.5, "extra": 7}})
    inj = plan.injector()
    rep = repro.ENGINES[engine](faults=inj).run(Top, port)
    assert rep.ok, rep.error
    # within one (port, direction) responses stay FIFO, so the pipelined
    # read returns every element in order despite the latency spikes
    assert sink == list(data)
    assert any(e[0] == "mem" for e in inj.log)


# ---------------------------------------------------------------------------
# artifact integrity: compile cache + checkpoints
# ---------------------------------------------------------------------------

def _tiny_fn(x):
    return x + 1


def test_cache_corruption_detected_and_recompiled(tmp_path):
    args = (np.zeros((2,), np.float32),)
    chaos = CompileCache(root=tmp_path, faults=FaultPlan(cache_corrupt=1))
    exe, src = chaos.compile_cached(_tiny_fn, args)
    assert src == "compiled"
    assert np.allclose(exe(*args), 1.0)
    # the disk entry was corrupted post-write; a fresh cache detects the
    # digest mismatch, deletes the entry and recompiles — never crashes,
    # never returns a bad executable
    clean = CompileCache(root=tmp_path)
    exe2, src2 = clean.compile_cached(_tiny_fn, args)
    assert src2 == "compiled"
    assert clean.stats.corrupt == 1
    assert np.allclose(exe2(*args), 1.0)
    # and the rewritten entry round-trips from disk
    again = CompileCache(root=tmp_path)
    _, src3 = again.compile_cached(_tiny_fn, args)
    assert src3 == "disk"


def test_cache_transient_io_retried(tmp_path):
    args = (np.zeros((3,), np.float32),)
    inj = FaultPlan(cache_io_errors=1).injector()
    cc = CompileCache(root=tmp_path, faults=inj)
    cc.compile_cached(_tiny_fn, args)
    assert any(e[0] == "io_error" for e in inj.log)
    # the retry landed the entry on disk despite the injected failure
    fresh = CompileCache(root=tmp_path)
    _, src = fresh.compile_cached(_tiny_fn, args)
    assert src == "disk"


def test_ckpt_truncation_skipped_io_retried(tmp_path):
    from repro.ckpt import CheckpointManager
    inj = FaultPlan(ckpt_io_errors=1, ckpt_truncate=(2,)).injector()
    mgr = CheckpointManager(tmp_path, keep=3, faults=inj)
    params = {"w": np.arange(8, dtype=np.float32)}
    opt = {"m": np.zeros(8, dtype=np.float32)}
    mgr.save(1, params, opt, extra={"step": 1})
    mgr.save(2, {"w": params["w"] * 2}, opt, extra={"step": 2})
    assert any(e[0] == "io_error" for e in inj.log)      # write retried
    assert any(e[0] == "ckpt_truncate" for e in inj.log)
    assert mgr.verify(2)                  # truncated step fails integrity
    assert mgr.verify(1) == []
    got = mgr.restore_latest(params, opt)
    assert got is not None
    step, p, _, extra = got
    assert step == 1 and extra["step"] == 1
    np.testing.assert_array_equal(p["w"], params["w"])


# ---------------------------------------------------------------------------
# serving: poison / transient / deadline / cancel / preemption / degrade
# ---------------------------------------------------------------------------

V = 16


def _toy_per_slot(scfg):
    def prefill(toks):
        last = int(toks[0, -1]) % V
        return np.eye(1, V, k=(last + 1) % V), {"n": toks.shape[1]}

    def decode(tok, cache):
        return np.eye(1, V, k=int(tok[0] + 1) % V), {"n": cache["n"] + 1}

    return ServingEngine(scfg, prefill, decode)


def _toy_batched(scfg):
    from test_serving import toy_batched_engine
    return toy_batched_engine(scfg)


def _expected(prompt, max_new):
    last = (prompt[-1] if prompt else 0) % V
    return [(last + 1 + k) % V for k in range(max_new)]


_SCFG = dict(batch_slots=2, max_seq=32, prefill_buckets=(8,))


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_serving_poison_and_transients_quarantine_only_victims(variant):
    scfg = ServeConfig(**_SCFG)
    eng = (_toy_per_slot if variant == "per_slot" else _toy_batched)(scfg)
    reqs = [Request(i, [(3 * i) % V], max_new=3) for i in range(6)]
    plan = FaultPlan(seed=SEED, poison={2: "decode", 5: "prefill"},
                     transient={"prefill": 2, "decode": 1})
    res = serve_requests(eng, reqs, faults=plan)
    assert set(res) == set(range(6))
    for rid in (2, 5):
        assert isinstance(res[rid], RequestError), res[rid]
        assert res[rid].status == "poisoned"
    for rid in (0, 1, 3, 4):
        assert res[rid] == _expected(reqs[rid].prompt, 3), rid
    # the transient budget was consumed by retries, not failures
    assert len(eng.retry_log) == 3


def test_serving_batched_vs_per_slot_parity_under_faults():
    """Graceful degradation must not change outcomes: the same requests
    under the same fault plan yield the same statuses and token lists on
    both decode paths."""
    reqs = [Request(i, [(5 * i + 1) % V], max_new=4) for i in range(7)]
    plan = dict(poison={3: "any"}, cancel={6: 2}, transient={"decode": 2})
    outs = []
    for mk in (_toy_per_slot, _toy_batched):
        res = serve_requests(mk(ServeConfig(**_SCFG)), reqs,
                             faults=FaultPlan(seed=SEED, **plan))
        outs.append({rid: (v.status if isinstance(v, RequestError) else v)
                     for rid, v in res.items()})
    assert outs[0] == outs[1]
    assert outs[0][3] == "poisoned"
    assert outs[0][6] == "cancelled"
    assert outs[0][0] == _expected(reqs[0].prompt, 4)


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_serving_deadline_retires_slot(variant):
    scfg = ServeConfig(**_SCFG)
    eng = (_toy_per_slot if variant == "per_slot" else _toy_batched)(scfg)
    res = serve_requests(eng, [Request(0, [1], max_new=4, deadline_s=0.0),
                               Request(1, [2], max_new=4)])
    assert isinstance(res[0], RequestError) and res[0].status == "deadline"
    assert res[1] == _expected([2], 4)


def test_serving_batched_unattributable_failure_degrades_cleanly():
    """A real exception inside the one jitted step cannot be pinned on a
    request: every live request gets a structured error, the packed cache
    is rebuilt, and the requests still queued are served normally."""
    scfg = ServeConfig(**_SCFG)
    eng = _toy_batched(scfg)
    step_exe = eng._exe[("step",)]
    state = {"fired": False}

    def exploding(*args):
        if not state["fired"]:
            state["fired"] = True
            raise RuntimeError("XLA step blew up")
        return step_exe(*args)

    eng._exe[("step",)] = exploding
    reqs = [Request(i, [i % V], max_new=3) for i in range(5)]
    res = serve_requests(eng, reqs)
    assert set(res) == set(range(5))
    failed = [r for r, v in res.items() if isinstance(v, RequestError)]
    served = [r for r, v in res.items() if not isinstance(v, RequestError)]
    assert failed and served              # first wave failed, rest served
    for rid in failed:
        assert res[rid].status == "error"
        assert "XLA step blew up" in res[rid].detail
    for rid in served:
        assert res[rid] == _expected(reqs[rid].prompt, 3), rid


def test_serving_preflight_degrades_batched_to_per_slot():
    """Degradation ladder: a broken batched adapter with per-slot closures
    available falls back instead of refusing."""
    scfg = ServeConfig(**_SCFG)
    per = _toy_per_slot(scfg)

    class BrokenAdapter:
        def init_slots(self, slots, abstract=False):
            raise RuntimeError("no packed cache today")

    eng = ServingEngine(scfg, per.prefill_fn, per.decode_fn,
                        batched=BrokenAdapter())
    reqs = [Request(i, [i % V], max_new=2) for i in range(3)]
    res = serve_requests(eng, reqs)
    assert eng.degraded is not None and eng.degraded[0] == "per-slot"
    for r in reqs:
        assert res[r.rid] == _expected(r.prompt, 2)


@pytest.mark.parametrize("variant", ["per_slot", "batched"])
def test_serving_preemption_drains_and_answers_everything(variant):
    scfg = ServeConfig(**_SCFG)
    eng = (_toy_per_slot if variant == "per_slot" else _toy_batched)(scfg)
    eng.stop_flag = lambda: True          # preempted before the first wave
    reqs = [Request(i, [i % V], max_new=3) for i in range(6)]
    res = serve_requests(eng, reqs)
    assert set(res) == set(range(6))      # no request goes unanswered
    assert all(isinstance(v, RequestError) and v.status == "preempted"
               for v in res.values())


def test_serving_under_channel_faults_still_completes():
    """The serving task graph itself runs under channel-level chaos: the
    request/output channels stall and wake late, yet every request
    completes with the right tokens."""
    scfg = ServeConfig(**_SCFG)
    eng = _toy_per_slot(scfg)
    reqs = [Request(i, [(2 * i) % V], max_new=3) for i in range(5)]
    plan = FaultPlan(seed=SEED,
                     chan_stall={"*": {"p": 0.3, "stall": 2, "wake": 1}})
    res = serve_requests(eng, reqs, faults=plan, watchdog_s=30.0)
    for r in reqs:
        assert res[r.rid] == _expected(r.prompt, 3), r.rid
